"""Hypothesis property tests over system invariants."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.signature import NDRange, _proportional_split
from repro.kernels import ops, ref

_SETTINGS = dict(max_examples=25, deadline=None)


# -- scheduler / NDRange invariants ------------------------------------------
@given(total=st.integers(1, 10_000),
       fracs=st.lists(st.floats(0.0, 1.0), min_size=1, max_size=8))
@settings(**_SETTINGS)
def test_proportional_split_partitions_total(total, fracs):
    s = sum(fracs)
    if s == 0:
        fracs = [1.0]
        s = 1.0
    fracs = [f / s for f in fracs]
    sizes = _proportional_split(total, fracs)
    assert sum(sizes) == total
    assert all(sz >= 0 for sz in sizes)


@given(n=st.integers(2, 512), cut=st.floats(0.01, 0.99))
@settings(**_SETTINGS)
def test_ndrange_split_covers_range(n, cut):
    r = NDRange((n,))
    a, b = r.split([cut, 1.0 - cut])
    parts = [p for p in (a, b) if p is not None]
    covered = sorted((p.offsets[0], p.offsets[0] + p.global_dims[0]) for p in parts)
    assert covered[0][0] == 0
    assert covered[-1][1] == n
    for (s0, e0), (s1, _) in zip(covered, covered[1:]):
        assert e0 == s1  # contiguous, no overlap


# -- compaction invariants ----------------------------------------------------
@given(data=st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=2048))
@settings(**_SETTINGS)
def test_stream_compact_matches_numpy_filter(data):
    x = np.array(data, np.uint32)
    pad = (-len(x)) % 256
    x = np.pad(x, (0, pad))
    got, cnt = ops.stream_compact(jnp.asarray(x), bs=256, impl="pallas")
    survivors = x[x != 0]
    assert int(cnt) == survivors.size
    np.testing.assert_array_equal(np.asarray(got)[:survivors.size], survivors)
    # tail is zero-filled
    assert (np.asarray(got)[survivors.size:] == 0).all()


# -- sort invariants ----------------------------------------------------------
@given(data=st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=1024))
@settings(**_SETTINGS)
def test_radix_sort_is_permutation_and_sorted(data):
    x = np.array(data, np.uint32)
    pad = (-len(x)) % 256
    # pad with max so padding sorts to the end deterministically
    x = np.pad(x, (0, pad), constant_values=np.uint32(2**32 - 1))
    got = np.asarray(ops.radix_sort(jnp.asarray(x), impl="pallas"))
    assert (np.diff(got.astype(np.uint64)) >= 0).all()
    np.testing.assert_array_equal(np.sort(got), np.sort(x))


# -- attention invariants -------------------------------------------------------
@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_attention_rows_are_convex_combinations(seed):
    """Each output row lies in the convex hull of V rows → bounded by V."""
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((1, 2, 64, 64)).astype(np.float32)
    k = rng.standard_normal((1, 2, 64, 64)).astype(np.float32)
    v = rng.standard_normal((1, 2, 64, 64)).astype(np.float32)
    out = np.asarray(ops.flash_attention(jnp.asarray(q), jnp.asarray(k),
                                         jnp.asarray(v), causal=True,
                                         impl="pallas", bq=64, bk=64))
    assert out.min() >= v.min() - 1e-4
    assert out.max() <= v.max() + 1e-4


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_full_window_equals_plain_causal(seed):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((1, 1, 128, 64)).astype(np.float32)
    k = rng.standard_normal((1, 1, 128, 64)).astype(np.float32)
    v = rng.standard_normal((1, 1, 128, 64)).astype(np.float32)
    a = ref.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=True, window=128)
    b = ref.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=True, window=None)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


# -- DeviceRef lifecycle state machine (ISSUE 3) -------------------------------
# Arbitrary interleavings of spill/unspill/donate/restrict/release/to_value
# against a pure-Python model of the documented state machine: registry
# bytes/refs never leak, and AccessViolation / donate-after-use surface
# exactly when specified.
_LIFECYCLE_OPS = ("spill", "unspill", "donate", "restrict_r", "restrict_rw",
                  "release", "to_value")


def _lifecycle_model_step(state, access, op):
    """→ (expected_exception_type|None, new_state, bytes_delta, refs_delta,
    derived_access|None) for one op, mirroring repro.core.memref exactly.
    Deltas are in units of the ref's nbytes / ref count."""
    from repro.core import AccessViolation
    live, spilled, donated, released = "live", "spilled", "donated", "released"
    usable_err = RuntimeError  # used-after-release / donate-after-use
    if op == "spill":
        if state in (donated, released):
            return usable_err, state, 0, 0, None
        if state == spilled:
            return None, spilled, 0, 0, None
        if "r" not in access:
            return AccessViolation, state, 0, 0, None
        return None, spilled, -1, 0, None
    if op == "unspill":
        if state == spilled:
            return None, live, +1, 0, None
        if state in (donated, released):
            return usable_err, state, 0, 0, None
        return None, live, 0, 0, None
    if op == "donate":
        if state in (donated, released):
            return usable_err, state, 0, 0, None
        if state == spilled:
            return RuntimeError, state, 0, 0, None
        if "w" not in access:
            return AccessViolation, state, 0, 0, None
        return None, donated, -1, -1, None
    if op in ("restrict_r", "restrict_rw"):
        target = "r" if op == "restrict_r" else "rw"
        if not set(target) <= set(access):   # widen check precedes usable
            return AccessViolation, state, 0, 0, None
        if state in (donated, released):
            return usable_err, state, 0, 0, None
        if state == spilled:
            return RuntimeError, state, 0, 0, None
        return None, state, +1, +1, target   # independent accounted view
    if op == "release":
        if state in (donated, released):
            return None, state, 0, 0, None   # idempotent no-op
        delta = -1 if state == live else 0   # spilled bytes already evicted
        return None, released, delta, -1, None
    if op == "to_value":
        if state in (donated, released):
            return usable_err, state, 0, 0, None
        if "r" not in access:
            return AccessViolation, state, 0, 0, None
        return None, state, 0, 0, None
    raise AssertionError(op)


@given(access=st.sampled_from(["r", "w", "rw"]),
       ops=st.lists(st.sampled_from(_LIFECYCLE_OPS), min_size=0,
                    max_size=12))
@settings(max_examples=60, deadline=None)
def test_deviceref_lifecycle_never_leaks_and_raises_exactly_when_specified(
        access, ops):
    import gc

    from repro.core import AccessViolation, DeviceRef
    from repro.core.memref import registry

    gc.collect()
    base_refs = registry.live_count()
    base_bytes = registry.live_bytes()

    ref = DeviceRef(jnp.arange(32, dtype=jnp.float32), access=access)
    nbytes = ref.nbytes
    state = "live"
    derived = []          # restrict() views: independently accounted refs
    model_bytes = 1       # in units of nbytes
    model_refs = 1

    for op in ops:
        expect_exc, state2, d_bytes, d_refs, derived_access = \
            _lifecycle_model_step(state, access, op)
        try:
            if op == "spill":
                ref.spill()
            elif op == "unspill":
                ref.unspill()
            elif op == "donate":
                ref.donate()
            elif op.startswith("restrict"):
                derived.append(
                    ref.restrict("r" if op == "restrict_r" else "rw"))
            elif op == "release":
                ref.release()
            elif op == "to_value":
                ref.to_value()
            raised = None
        except Exception as exc:
            raised = exc
        if expect_exc is None:
            assert raised is None, f"{op} in {state!r}: unexpected {raised!r}"
        else:
            assert raised is not None, f"{op} in {state!r}: should have raised"
            assert isinstance(raised, expect_exc), (op, state, raised)
            if expect_exc is AccessViolation:
                assert isinstance(raised, AccessViolation)
            if state == "donated" and op != "release" \
                    and expect_exc is RuntimeError \
                    and not isinstance(raised, AccessViolation):
                assert "donat" in str(raised)  # donate-after-use names itself
        state = state2
        model_bytes += d_bytes
        model_refs += d_refs
        assert registry.live_bytes() - base_bytes == model_bytes * nbytes, \
            f"byte accounting diverged after {op} (state {state!r})"
        assert registry.live_count() - base_refs == model_refs, \
            f"ref accounting diverged after {op} (state {state!r})"

    # teardown: releasing everything restores the registry exactly
    ref.release()
    for d in derived:
        d.release()
    gc.collect()
    assert registry.live_bytes() == base_bytes
    assert registry.live_count() == base_refs


# -- actor supervision invariants ---------------------------------------------
@given(n_watchers=st.integers(1, 6), registered_before=st.integers(0, 6))
@settings(max_examples=15, deadline=None)
def test_every_monitor_of_terminated_actor_gets_exactly_one_down(
        n_watchers, registered_before):
    """Supervision invariant (ISSUE 5): no matter how monitor registration
    interleaves with termination, every monitor receives exactly one
    DownMessage — never zero (the lost-registration race) and never two."""
    import threading
    import time

    from repro.core import ActorSystem, DownMessage

    registered_before = min(registered_before, n_watchers)
    system = ActorSystem(max_workers=4)
    try:
        target = system.spawn(lambda x: x)
        inboxes = [[] for _ in range(n_watchers)]
        events = [threading.Event() for _ in range(n_watchers)]

        def make_watcher(i):
            return lambda m: (inboxes[i].append(m), events[i].set())

        watchers = [system.spawn(make_watcher(i)) for i in range(n_watchers)]
        for w in watchers[:registered_before]:
            system.monitor(w, target)
        killer = threading.Thread(target=target.exit, args=(None,))
        killer.start()   # races the remaining registrations
        for w in watchers[registered_before:]:
            system.monitor(w, target)
        killer.join()
        for evt in events:
            assert evt.wait(10)
        time.sleep(0.05)   # grace for (hypothetical) duplicate deliveries
        for box in inboxes:
            assert len(box) == 1, box
            assert isinstance(box[0], DownMessage)
            assert box[0].actor_id == target.actor_id
    finally:
        system.shutdown()
