"""Typed dataflow-graph composition tests (ISSUE 4 acceptance surface).

Covers: build-time topology validation (cycles, dangling ports, arity and
dtype/shape mismatches — each a distinct GraphError subclass naming the
offending node path), the diamond acceptance criterion (6 nodes, zero
host transfers on interior edges), the combinators (broadcast, zip_join,
select/merge, map_over), Pipeline-as-linear-Graph compatibility, the
PipelineRunner/ServeEngine integration points, and the satellite fixes
(pool ask timeouts naming the routed worker, DeviceRef diagnostic repr).
"""
import gc
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ActorPool, ActorSystem, ArityMismatchError,
                        DanglingPortError, DeviceRef, Graph, GraphCycleError,
                        GraphError, GraphRef, In, NDRange, Out, Pipeline,
                        PortType, PortTypeMismatchError, dim_vec, kernel,
                        live_ref_count, memory_stats, reset_transfer_stats,
                        transfer_count)


@pytest.fixture(scope="module")
def system():
    s = ActorSystem(max_workers=8)
    yield s
    s.shutdown()


@pytest.fixture(scope="module")
def mngr(system):
    return system.opencl_manager()


@pytest.fixture()
def ref_baseline():
    gc.collect()
    return live_ref_count()


N = 16


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)),
        name="prep")
def prep(x):
    return x + 1.0


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)),
        name="double")
def double(x):
    return x * 2.0


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)),
        name="sub3")
def sub3(x):
    return x - 3.0


@kernel(In(jnp.float32), In(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(N)), name="add2")
def add2(a, b):
    return a + b


@kernel(In(jnp.float32), Out(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(N)), name="fork")
def fork(x):
    return x + 10.0, x - 10.0


def _diamond(system, name="diamond"):
    """The acceptance diamond, 6 nodes:
    source → broadcast(2) → double/sub3 branches → zip_join → add2 sink."""
    g = Graph(system, name=name)
    x = g.source("x", jnp.float32, shape=(N,))
    l, r = g.broadcast(x, 2)
    j1, j2 = g.zip_join(g.apply(double, l), g.apply(sub3, r))
    g.output(g.apply(add2, j1, j2))
    return g


def _diamond_expected(x):
    return x * 2 + x - 3


# ----------------------------------------------------------------------------
# the acceptance criterion: 6-node diamond, zero interior host transfers
# ----------------------------------------------------------------------------
def test_diamond_zero_host_transfers(system, ref_baseline):
    g = _diamond(system)
    assert len(g.nodes) == 6
    built = g.build()
    x = np.arange(N, dtype=np.float32)
    reset_transfer_stats()
    out = built.ask(x)
    np.testing.assert_allclose(out, _diamond_expected(x), rtol=1e-6)
    assert transfer_count() == 0, "an interior edge round-tripped the host"
    assert memory_stats()["readbacks"] == 1     # only the final output
    time.sleep(0.2)
    gc.collect()
    assert live_ref_count() == ref_baseline     # interior refs all released


def test_diamond_ref_output_stays_resident(system, ref_baseline):
    """With a ref-semantics sink the whole diamond does zero host traffic
    until the caller's explicit read-back."""
    sink = add2.with_options(
        specs=(In(jnp.float32), In(jnp.float32),
               Out(jnp.float32, as_ref=True)))
    g = Graph(system, name="diamond_ref")
    x = g.source("x", jnp.float32, shape=(N,))
    l, r = g.broadcast(x, 2)
    j1, j2 = g.zip_join(g.apply(double, l), g.apply(sub3, r))
    g.output(g.apply(sink, j1, j2))
    built = g.build()
    x_in = np.arange(N, dtype=np.float32)
    reset_transfer_stats()
    out = built.ask(x_in)
    assert isinstance(out, DeviceRef)
    assert transfer_count() == 0
    assert memory_stats()["readbacks"] == 0
    np.testing.assert_allclose(out.to_value(), _diamond_expected(x_in),
                               rtol=1e-6)
    assert transfer_count() == 1
    out.release()
    time.sleep(0.2)
    gc.collect()
    assert live_ref_count() == ref_baseline


def test_diamond_concurrent_runs(system):
    built = _diamond(system, name="diamond_cc").build()
    xs = [np.full(N, i, np.float32) for i in range(8)]
    futs = [built.request(x) for x in xs]
    for x, f in zip(xs, futs):
        np.testing.assert_allclose(f.result(30), _diamond_expected(x),
                                   rtol=1e-6)


# ----------------------------------------------------------------------------
# build-time topology validation (distinct GraphError subclasses)
# ----------------------------------------------------------------------------
def test_cycle_detection_names_nodes(system):
    g = Graph(system, name="cyclic")
    n1 = g.node(prep, name="p1")
    n2 = g.node(double, name="p2")
    g.bind(n1, 0, n2.out(0))
    g.bind(n2, 0, n1.out(0))
    g.output(n2.out(0))
    with pytest.raises(GraphCycleError, match=r"cyclic/p[12]"):
        g.build()


def test_unbound_input_slot_is_dangling(system):
    g = Graph(system, name="unbound")
    n = g.node(prep)                       # input slot never bound
    g.output(n.out(0))
    with pytest.raises(DanglingPortError, match="unbound/prep"):
        g.build()


def test_unconsumed_port_is_dangling(system):
    g = Graph(system, name="drop")
    x = g.source("x", jnp.float32, shape=(N,))
    l, r = g.broadcast(g.apply(prep, x), 2)
    g.output(g.apply(double, l))            # branch r never consumed
    with pytest.raises(DanglingPortError, match="drop/broadcast"):
        g.build()


def test_arity_mismatch_names_node(system):
    g = Graph(system, name="arity")
    x = g.source("x", jnp.float32, shape=(N,))
    g.output(g.apply(add2, x))              # add2 wants two inputs
    with pytest.raises(ArityMismatchError, match="arity/add2"):
        g.build()


def test_dtype_mismatch_names_edge(system):
    g = Graph(system, name="dtypes")
    x = g.source("x", jnp.int32, shape=(N,))
    g.output(g.apply(prep, x))              # prep wants float32
    with pytest.raises(PortTypeMismatchError, match="dtypes/prep"):
        g.build()


def test_shape_mismatch_names_edge(system):
    shaped = prep.with_options(
        specs=(In(jnp.float32, shape=(4,)), Out(jnp.float32)))
    g = Graph(system, name="shapes")
    x = g.source("x", jnp.float32, shape=(N,))
    g.output(g.apply(shaped, x))
    with pytest.raises(PortTypeMismatchError, match="shapes/prep"):
        g.build()


def test_no_outputs_is_an_error(system):
    g = Graph(system, name="noout")
    g.source("x", jnp.float32)
    with pytest.raises(GraphError, match="no outputs"):
        g.build()


def test_output_dtype_contradiction_caught_at_build(system):
    """eval_shape'd output dtype contradicting the declared Out spec is a
    build-time PortTypeMismatchError, not a runtime kernel death."""
    lying = kernel(In(jnp.float32), Out(jnp.int32),
                   nd_range=NDRange(dim_vec(N)),
                   name="lying")(lambda x: x + 1.0)   # computes float32
    g = Graph(system, name="liar")
    x = g.source("x", jnp.float32, shape=(N,))
    g.output(g.apply(lying, x))
    with pytest.raises(PortTypeMismatchError, match="liar/lying"):
        g.build()


def test_typed_ports_derived_via_eval_shape(system):
    g = _diamond(system, name="typed")
    g.validate()
    by_name = {n.name: n for n in g.nodes}
    assert by_name["double"].out_types == [PortType.of(jnp.float32, (N,))]
    assert by_name["zip_join"].out_types == [
        PortType.of(jnp.float32, (N,))] * 2
    assert by_name["add2"].out_types == [PortType.of(jnp.float32, (N,))]


# ----------------------------------------------------------------------------
# combinators
# ----------------------------------------------------------------------------
def test_multi_output_kernel_ports(system):
    g = Graph(system, name="fork2")
    x = g.source("x", jnp.float32, shape=(N,))
    hi, lo = g.apply(fork, x)
    g.output(g.apply(double, hi), g.apply(sub3, lo))
    built = g.build()
    xs = np.arange(N, dtype=np.float32)
    a, b = built.ask(xs)
    np.testing.assert_allclose(a, (xs + 10) * 2)
    np.testing.assert_allclose(b, (xs - 10) - 3)


def test_select_merge_routes_by_predicate(system):
    def pred(v):
        arr = v.to_value() if isinstance(v, DeviceRef) else np.asarray(v)
        return 0 if float(arr[0]) < 50 else 1

    g = Graph(system, name="route")
    x = g.source("x", jnp.float32, shape=(N,))
    t, f = g.select(x, pred)
    g.output(g.merge(g.apply(double, t), g.apply(sub3, f)))
    built = g.build()
    small = np.full(N, 1.0, np.float32)
    big = np.full(N, 100.0, np.float32)
    np.testing.assert_allclose(built.ask(small), small * 2)
    np.testing.assert_allclose(built.ask(big), big - 3)


def test_select_without_merge_yields_none_for_dead_output(system):
    g = Graph(system, name="deadout")
    x = g.source("x", jnp.float32, shape=(N,))
    t, f = g.select(x, lambda v: 0)          # branch 1 is always dead
    g.output(g.apply(double, t), g.apply(sub3, f))
    built = g.build()
    xs = np.ones(N, np.float32)
    taken, dead = built.ask(xs)
    np.testing.assert_allclose(taken, xs * 2)
    assert dead is None


def test_select_predicate_failure_fails_the_run_not_the_graph(system):
    g = Graph(system, name="badpred")
    x = g.source("x", jnp.float32, shape=(N,))
    t, f = g.select(x, lambda v: 1 / 0)
    g.output(g.merge(g.apply(double, t), g.apply(sub3, f)))
    built = g.build()
    with pytest.raises(ZeroDivisionError):
        built.ask(np.ones(N, np.float32))
    # the orchestrator survives: the next run is fine
    g2 = Graph(system, name="okpred")
    assert built.is_alive()


def test_map_over_chunks_through_scheduler(system, ref_baseline):
    g = Graph(system, name="mapped")
    x = g.source("x", jnp.float32)
    m = g.map_over(prep, x, chunks=4, replicas=3)
    g.output(g.apply(double, m))
    built = g.build()
    xs = np.arange(64, dtype=np.float32)
    reset_transfer_stats()
    out = built.ask(xs)
    np.testing.assert_allclose(out, (xs + 1) * 2)
    # chunk slices, per-chunk results, and the concat all stay on device
    assert transfer_count() == 0
    time.sleep(0.2)
    gc.collect()
    assert live_ref_count() == ref_baseline


def test_map_over_rejects_multi_arg_kernels(system):
    g = Graph(system, name="mapbad")
    x = g.source("x", jnp.float32)
    with pytest.raises(GraphError, match="exactly one input"):
        g.map_over(add2, x)


def test_map_over_rejects_preprocess_kernels(system):
    """Chunk payloads are DeviceRefs; a preprocess (which runs before ref
    unwrapping) would crash every replica — rejected at graph-build time."""
    pre = prep.with_options(preprocess=lambda x: x * 2.0)
    g = Graph(system, name="mappre")
    x = g.source("x", jnp.float32)
    with pytest.raises(GraphError, match="mappre/.*preprocess"):
        g.map_over(pre, x)


def test_zero_input_node_fires(system):
    """A no-input producer (constant source stage) must execute even
    though no delivery ever triggers it."""
    g = Graph(system, name="const")
    x = g.source("x", jnp.float32, shape=(N,))
    c = g.apply(lambda: np.full(N, 5.0, np.float32), name="five")
    g.output(g.apply(add2, x, c))
    built = g.build()
    xs = np.arange(N, dtype=np.float32)
    np.testing.assert_allclose(built.ask(xs), xs + 5.0)


def test_broadcast_is_read_sharing(system):
    """Ref fan-out hands branches read-only views: a donating InOut
    consumer fails its own branch deterministically (AccessViolation)
    instead of invalidating the buffer under its sibling."""
    from repro.core import InOut
    updater = kernel(InOut(jnp.float32, as_ref=True),
                     nd_range=NDRange(dim_vec(N)),
                     name="upd")(lambda x: x * 2.0)
    g = Graph(system, name="donor")
    x = g.source("x", jnp.float32, shape=(N,))
    a, b = g.broadcast(g.apply(prep, x), 2)
    j1, j2 = g.zip_join(g.apply(updater, a), g.apply(double, b))
    g.output(g.apply(add2, j1, j2))
    built = g.build()
    from repro.core import AccessViolation
    with pytest.raises(AccessViolation):
        built.ask(np.arange(N, dtype=np.float32))


def test_broadcast_feeds_both_branches_same_buffer(system):
    g = Graph(system, name="fan")
    x = g.source("x", jnp.float32, shape=(N,))
    a, b = g.broadcast(g.apply(prep, x), 2)
    g.output(g.apply(double, a), g.apply(double, b))
    built = g.build()
    xs = np.arange(N, dtype=np.float32)
    r1, r2 = built.ask(xs)
    np.testing.assert_allclose(r1, (xs + 1) * 2)
    np.testing.assert_allclose(r2, (xs + 1) * 2)


def test_graph_failure_releases_refs_and_keeps_orchestrator(system,
                                                            ref_baseline):
    boom = kernel(In(jnp.float32), Out(jnp.float32),
                  nd_range=NDRange(dim_vec(N)),
                  name="boom")(lambda x: (_ for _ in ()).throw(
                      ValueError("kaboom")))
    g = Graph(system, name="failing")
    x = g.source("x", jnp.float32, shape=(N,))
    l, r = g.broadcast(g.apply(prep, x), 2)
    j1, j2 = g.zip_join(g.apply(double, l), g.apply(boom, r))
    g.output(g.apply(add2, j1, j2))
    built = g.build()
    with pytest.raises(Exception):
        built.ask(np.arange(N, dtype=np.float32))
    time.sleep(0.3)
    gc.collect()
    assert live_ref_count() == ref_baseline
    assert built.is_alive()


# ----------------------------------------------------------------------------
# Pipeline is a thin linear-Graph wrapper (behavior compatibility)
# ----------------------------------------------------------------------------
def test_pipeline_staged_is_graph_backed(system):
    pipe = (Pipeline(system, mode="staged")
            .stage(prep).stage(double).stage(sub3).build())
    assert isinstance(pipe, GraphRef)
    assert pipe.plan.chain_refs and len(pipe.plan.chain_refs) == 3
    x = np.arange(N, dtype=np.float32)
    np.testing.assert_allclose(pipe.ask(x), (x + 1) * 2 - 3)


def test_linear_graph_matches_pipeline(system):
    x = np.arange(N, dtype=np.float32)
    pipe = (Pipeline(system, mode="staged")
            .stage(prep).stage(double).build())
    g = Graph(system, name="lin")
    s = g.source("x", jnp.float32, shape=(N,))
    g.output(g.apply(double, g.apply(prep, s)))
    np.testing.assert_array_equal(np.asarray(pipe.ask(x)),
                                  np.asarray(g.build().ask(x)))


def test_built_graph_usable_as_pipeline_stage(system):
    inner = _diamond(system, name="inner").build()
    outer = (Pipeline(system, mode="staged")
             .stage(prep).stage(inner).build())
    x = np.arange(N, dtype=np.float32)
    np.testing.assert_allclose(outer.ask(x), _diamond_expected(x + 1),
                               rtol=1e-6)


def test_graph_in_actor_pool(system):
    built = [_diamond(system, name=f"pooled{i}").build() for i in range(2)]
    pool = ActorPool(system, built, policy="round_robin")
    x = np.arange(N, dtype=np.float32)
    for _ in range(4):
        np.testing.assert_allclose(pool.ask(x), _diamond_expected(x),
                                   rtol=1e-6)


def test_source_arity_checked_at_request_time(system):
    built = _diamond(system, name="arityrt").build()
    with pytest.raises(GraphError, match="source"):
        built.ask(np.zeros(N, np.float32), np.zeros(N, np.float32))
    assert built.is_alive()


def test_graph_placements_reported(system, mngr):
    built = _diamond(system, name="placed").build()
    assert set(built.placements) == {
        "placed/double", "placed/sub3", "placed/add2"}
    devices = set(mngr.devices())
    assert all(d in devices for d in built.placements.values())


# ----------------------------------------------------------------------------
# dist/serve integration
# ----------------------------------------------------------------------------
def test_pipeline_runner_over_graph(system):
    from repro.dist.pipeline import PipelineRunner
    g = Graph(system, name="runner")
    s = g.source("x", jnp.float32, shape=(N,))
    l, r = g.broadcast(g.apply(prep, s), 2)
    j1, j2 = g.zip_join(g.apply(double, l), g.apply(sub3, r))
    g.output(g.apply(add2, j1, j2))
    runner = PipelineRunner(system, graph=g, depth=3)
    mbs = [np.full(N, i, np.float32) for i in range(6)]
    outs = runner.run(mbs)
    for mb, out in zip(mbs, outs):
        np.testing.assert_allclose(out, _diamond_expected(mb + 1), rtol=1e-6)


def test_pipeline_runner_rejects_both_or_neither(system):
    from repro.dist.pipeline import PipelineRunner
    with pytest.raises(ValueError):
        PipelineRunner(system)
    g = Graph(system, name="both")
    with pytest.raises(ValueError):
        PipelineRunner(system, [system.spawn(lambda x: x)], graph=g)


def test_serve_engine_with_graph_step(system):
    from repro.serve import ServeEngine

    @kernel(In(jnp.int32), In(jnp.float32), Out(jnp.int32),
            Out(jnp.float32, as_ref=True), nd_range=NDRange(dim_vec(4)),
            name="decode_step")
    def decode_step(tok, acc):
        return tok + 1, acc + tok.astype(jnp.float32)

    g = Graph(system, name="decoder")
    tk = g.source("tokens", jnp.int32)
    ac = g.source("acc", jnp.float32)
    o_tok, o_acc = g.apply(decode_step, tk, ac)
    g.output(o_tok, o_acc)
    step_graph = g.build()

    def init(prompt):
        return {"acc": jnp.zeros((), jnp.float32)}, int(prompt)

    eng = ServeEngine(system, init_fn=init, step_graph=step_graph,
                      n_workers=1, max_batch=4).start()
    try:
        futs = [eng.submit(i, max_new_tokens=3) for i in range(5)]
        for i, f in enumerate(futs):
            assert f.result(60).tokens == [i + 1, i + 2, i + 3]
    finally:
        eng.stop()
    assert eng.stats()["completed"] == 5


def test_serve_graph_step_with_passthrough_leaf(system):
    """A cache leaf the graph forwards unchanged (source wired straight to
    an output) must survive the decode step: the worker may not release
    the input column before reading the result."""
    from repro.serve import ServeEngine

    @kernel(In(jnp.int32), In(jnp.float32), Out(jnp.int32),
            Out(jnp.float32, as_ref=True), nd_range=NDRange(dim_vec(4)),
            name="pt_step")
    def pt_step(tok, acc):
        return tok + 1, acc + tok.astype(jnp.float32)

    g = Graph(system, name="pt_decoder")
    tk = g.source("tokens", jnp.int32)
    ac = g.source("acc", jnp.float32)
    st = g.source("static", jnp.float32)
    o_tok, o_acc = g.apply(pt_step, tk, ac)
    g.output(o_tok, o_acc, st)           # "static" leaf passes through
    step_graph = g.build()

    def init(prompt):
        return {"acc": jnp.zeros((), jnp.float32),
                "static": jnp.full((), 7.0, jnp.float32)}, int(prompt)

    eng = ServeEngine(system, init_fn=init, step_graph=step_graph,
                      n_workers=1, max_batch=4).start()
    try:
        futs = [eng.submit(i, max_new_tokens=3) for i in range(3)]
        for i, f in enumerate(futs):
            assert f.result(60).tokens == [i + 1, i + 2, i + 3]
    finally:
        eng.stop()


# ----------------------------------------------------------------------------
# satellites: pool ask timeout + DeviceRef diagnostic repr
# ----------------------------------------------------------------------------
def test_pool_ask_timeout_names_routed_worker(system):
    from concurrent.futures import TimeoutError as FuturesTimeout
    sleepy = system.spawn(lambda x: time.sleep(5) or x)
    pool = ActorPool(system, [sleepy], default_timeout=0.05)
    with pytest.raises(FuturesTimeout, match=rf"ActorRef#{sleepy.actor_id}"):
        pool.ask(1)          # default_timeout from the pool
    with pytest.raises(FuturesTimeout, match="0.01"):
        pool.ask(1, timeout=0.01)


def test_pool_ask_preserves_worker_raised_timeout(system):
    """A TimeoutError raised *by the worker itself* must surface verbatim,
    not be relabeled as a pool timeout pointing at a healthy replica."""
    def impatient(x):
        raise TimeoutError("inner deadline blew up")

    pool = ActorPool(system, [system.spawn(impatient)], default_timeout=30.0)
    with pytest.raises(TimeoutError, match="inner deadline"):
        pool.ask(1)


def test_map_over_empty_input(system):
    """An empty leading axis flows one empty chunk through the kernel,
    yielding an empty result instead of a concatenate crash."""
    g = Graph(system, name="mapempty")
    x = g.source("x", jnp.float32)
    g.output(g.map_over(prep, x, chunks=4, replicas=2))
    built = g.build()
    out = built.ask(np.zeros((0,), np.float32))
    assert np.asarray(out).shape == (0,)
    assert np.asarray(out).dtype == np.float32


def test_serve_engine_cacheless_graph_step(system):
    """A zero-leaf cache works: the single-output graph resolves to a
    bare value and the worker still honours the step contract."""
    from repro.serve import ServeEngine

    @kernel(In(jnp.int32), Out(jnp.int32), nd_range=NDRange(dim_vec(4)),
            name="stateless_step")
    def stateless_step(tok):
        return tok + 2

    g = Graph(system, name="stateless")
    tk = g.source("tokens", jnp.int32)
    g.output(g.apply(stateless_step, tk))
    step_graph = g.build()

    eng = ServeEngine(system, init_fn=lambda p: ({}, int(p)),
                      step_graph=step_graph, n_workers=1, max_batch=4,
                      ).start()
    try:
        fut = eng.submit(10, max_new_tokens=2)
        assert fut.result(60).tokens == [12, 14]
    finally:
        eng.stop()


def test_serve_engine_rejects_pool_plus_step(system):
    from repro.serve import ServeEngine
    pool = ActorPool(system, [system.spawn(lambda *a: a)])
    with pytest.raises(ValueError, match="adopted pool"):
        ServeEngine(system, init_fn=lambda p: ({}, 0), pool=pool,
                    step_fn=lambda c, t: (t, c))


def test_spawn_pool_threads_default_timeout(system, mngr):
    pool = mngr.spawn_pool(prep, 2, default_timeout=7.5)
    assert pool.default_timeout == 7.5


def test_deviceref_repr_diagnostics():
    ref = DeviceRef.put(np.ones(N, np.float32), access="rw")
    live = repr(ref)
    assert "float32" in live and "rw" in live and f"{N * 4}B" in live
    assert "live" in live
    ref.spill()
    spilled = repr(ref)
    assert "spilled" in spilled and "host" in spilled
    ref.release()
    assert "released" in repr(ref)
