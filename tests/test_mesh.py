"""Tests for the elastic serve mesh (``repro.serve.mesh``, ISSUE 8).

Fast tests run the router over in-process replicas (local ActorRefs) or
two in-process ``NodeRuntime``\\ s over a localhost socket — the network
transparency of the replica handle means the routing/replay logic under
test is the same code that runs cross-process. The ``slow``-marked test
at the bottom is the acceptance demo: a real 3-process mesh with a
worker SIGKILLed mid-sweep.

Also here: regression tests for the ISSUE 8 runtime-loop bugfixes that
live on the serve side (the O(1) LatencyStats percentile path); the
node-side ones (prompt shutdown, configurable peer_stats timeout) are in
``tests/test_net.py``.
"""
import threading
import time

import pytest

from repro.core import ActorSystem
from repro.core.errors import ActorError
from repro.net import NodeRuntime
from repro.launch.serve_mesh import expected_tokens, toy_engine
from repro.serve import (AdmissionError, EngineReplica, LatencyStats,
                         MeshDown, MeshRouter, ReplicaSpec, SLOExceeded,
                         local_replica_stats)


@pytest.fixture(scope="module")
def system():
    s = ActorSystem("mesh-test", max_workers=8)
    yield s
    s.shutdown()


def make_router(system, n_replicas=2, *, service_delay_s=0.005, **kw):
    spec = ReplicaSpec(toy_engine, service_delay_s=service_delay_s)
    kw.setdefault("control_interval", 0.05)
    kw.setdefault("max_attempts", 4)
    router = MeshRouter(system, None, spec=spec, **kw)
    for _ in range(n_replicas):
        router.spawn_replica()
    return router


def wait_for(pred, timeout=15.0, interval=0.02):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return pred()


# ----------------------------------------------------------------------------
# routing
# ----------------------------------------------------------------------------
def test_routing_spreads_keyless_load(system):
    with make_router(system, 2) as router:
        futs = [router.submit(i, max_new_tokens=2) for i in range(24)]
        for i, f in enumerate(futs):
            assert f.result(60).tokens == expected_tokens(i, 2)
        # both replicas served a share (the inflight term in the pick
        # score balances a tight submit loop even with stale EWMAs)
        loads = [rep.ref.ask("stats", timeout=30)
                 for rep in router._replicas.values()]
        assert all(l["completed"] > 0 for l in loads), loads
        assert sum(l["completed"] for l in loads) == 24


def test_session_affinity_pins_one_replica(system):
    with make_router(system, 3) as router:
        for _ in range(9):
            router.submit(5, max_new_tokens=1, session="sess-X").result(60)
        loads = [rep.ref.ask("stats", timeout=30)
                 for rep in router._replicas.values()]
        served = sorted(l["completed"] for l in loads)
        assert served == [0, 0, 9], served   # all nine on one replica
        assert router.stats()["prefix_routed"] == 9


def test_prefix_routing_groups_shared_prefixes(system):
    spec = ReplicaSpec(toy_engine, service_delay_s=0.0)
    router = MeshRouter(system, None, spec=spec, route_by_prefix=True,
                        prefix_tokens=4, control_interval=0.05)
    with router:
        router.spawn_replica()
        router.spawn_replica()
        # same prompt → same prefix key → same replica, every time
        for _ in range(6):
            router.submit(3, max_new_tokens=1).result(60)
        loads = [rep.ref.ask("stats", timeout=30)
                 for rep in router._replicas.values()]
        assert sorted(l["completed"] for l in loads) == [0, 6]


def test_mesh_down_when_no_replicas(system):
    router = MeshRouter(system, None)
    with pytest.raises(MeshDown):
        router.submit(1).result(10)
    assert router.stats()["failed"] == 1


# ----------------------------------------------------------------------------
# failure transparency
# ----------------------------------------------------------------------------
def test_replica_death_replays_inflight_exactly_once(system):
    """Kill one of two replicas with a deep backlog routed to it: every
    request still completes exactly once with the right tokens, and the
    router's replicas_lost/replayed counters record the event."""
    with make_router(system, 2, service_delay_s=0.01) as router:
        victim = next(iter(router._replicas.values()))
        futs = [router.submit(i, max_new_tokens=4) for i in range(32)]
        time.sleep(0.03)              # let some land in victim's queue
        victim.ref.exit(RuntimeError("simulated replica crash"))
        for i, f in enumerate(futs):
            assert f.result(60).tokens == expected_tokens(i, 4)
        assert wait_for(lambda: router.stats()["replicas_lost"] == 1)
        s = router.stats()
        assert s["completed"] == 32, s           # exactly once each
        assert s["failed"] == 0 and s["shed"] == 0, s
        assert s["replayed"] >= 1, s
        assert s["replicas"][victim.key]["state"] == "dead"


def test_all_replicas_dead_fails_requests_with_meshdown(system):
    with make_router(system, 1, service_delay_s=0.05) as router:
        rep = next(iter(router._replicas.values()))
        # more than one max_batch: the overflow sits queued in the dying
        # engine and has nowhere to replay
        futs = [router.submit(i, max_new_tokens=8) for i in range(14)]
        time.sleep(0.02)
        rep.ref.exit(RuntimeError("boom"))
        outcomes = []
        for f in futs:
            try:
                outcomes.append(("ok", f.result(60)))
            except (MeshDown, ActorError) as exc:
                outcomes.append(("err", exc))
        # nothing hangs; each request resolves exactly once (served by
        # the dying batch or failed) — never silently lost
        assert len(outcomes) == 14
        assert any(kind == "err" for kind, _ in outcomes)


def test_shed_is_not_replayed(system):
    """Admission errors are the overload policy answering, not a replica
    failure: the router forwards them to the caller without replay."""
    with make_router(system, 2, service_delay_s=0.05) as router:
        # deadline already busted at admission → SLOExceeded from the
        # replica's queue; must surface as shed, not burn replay attempts
        fut = router.submit(1, max_new_tokens=2, slo_ms=0.0)
        with pytest.raises(AdmissionError):
            fut.result(60)
        s = router.stats()
        assert s["shed"] == 1 and s["replayed"] == 0, s


# ----------------------------------------------------------------------------
# autoscaling
# ----------------------------------------------------------------------------
def test_scale_out_under_load_and_drain_release_when_idle(system):
    spec = ReplicaSpec(toy_engine, service_delay_s=0.02, max_batch=2)
    router = MeshRouter(system, None, spec=spec, control_interval=0.05,
                        slo_budget_s=0.05, scale_in_ratio=0.7,
                        min_replicas=1, max_replicas=3, cooldown_s=0.3,
                        max_attempts=4)
    with router:
        router.spawn_replica()
        futs, t_end, n = [], time.monotonic() + 3.0, 0
        while time.monotonic() < t_end:
            futs.append(router.submit(n, max_new_tokens=4))
            n += 1
            time.sleep(0.02)
        for f in futs:
            f.result(60)
        s = router.stats()
        assert s["scale_outs"] >= 1, s        # overload grew the mesh
        assert s["failed"] == 0 and s["shed"] == 0, s
        # idle: EWMA waits undershoot → drain-then-release scale-in
        assert wait_for(lambda: router.stats()["scale_ins"] >= 1
                        and any(v["state"] == "released"
                                for v in router.stats()["replicas"].values()),
                        timeout=20)
        s = router.stats()
        # a released replica exited on purpose: it is NOT a lost replica
        # and its death must not synthesize replays
        assert s["replicas_lost"] == 0, s
        assert len(router.live_replicas()) >= router.min_replicas


# ----------------------------------------------------------------------------
# the mesh over real node runtimes (in-process pair)
# ----------------------------------------------------------------------------
@pytest.fixture()
def pair():
    sa = ActorSystem("mesh-a", max_workers=4)
    sb = ActorSystem("mesh-b", max_workers=4)
    na = NodeRuntime(sa, name="a", listen=("127.0.0.1", 0),
                     heartbeat_interval=0.2, heartbeat_timeout=2.0)
    nb = NodeRuntime(sb, name="b", heartbeat_interval=0.2,
                     heartbeat_timeout=2.0)
    nb.connect(na.address)
    assert na.wait_for_peer("b", 10)
    yield sa, sb, na, nb
    na.shutdown()
    nb.shutdown()
    sa.shutdown()
    sb.shutdown()


def test_remote_replica_and_stats_provider(pair):
    """A replica spawned over the wire serves through the same router
    path, and the hosting node's peer_stats exposes its load snapshot
    via the registered provider."""
    sa, sb, na, nb = pair
    nb.add_stats_provider("serve", local_replica_stats)
    spec = ReplicaSpec(toy_engine, service_delay_s=0.0)
    router = MeshRouter(sa, na, spec=spec, control_interval=0.05)
    with router:
        router.spawn_replica("b")
        futs = [router.submit(i, max_new_tokens=3) for i in range(8)]
        for i, f in enumerate(futs):
            assert f.result(60).tokens == expected_tokens(i, 3)
        snap = na.peer_stats("b", timeout=30)
        assert "serve" in snap, snap
        # the provider registry is process-global (other in-process tests
        # may have left entries): key by this replica's worker-side id
        rep = next(iter(router._replicas.values()))
        load = snap["serve"][str(rep.ref.remote_id)]
        assert load["completed"] == 8, snap["serve"]


def test_node_death_replays_on_surviving_replica(pair):
    """The mesh failure-transparency contract across a real (simulated)
    node death: socket close mid-backlog → NodeDown → in-flight requests
    replay on the surviving local replica, exactly once."""
    sa, sb, na, nb = pair
    spec = ReplicaSpec(toy_engine, service_delay_s=0.01)
    router = MeshRouter(sa, na, spec=spec, control_interval=0.05,
                        max_attempts=4)
    with router:
        router.spawn_replica("b")     # remote replica
        router.spawn_replica()        # local survivor
        futs = [router.submit(i, max_new_tokens=4) for i in range(24)]
        time.sleep(0.05)
        nb._conns["a"].sock.close()   # abrupt node death (simulated crash)
        for i, f in enumerate(futs):
            assert f.result(60).tokens == expected_tokens(i, 4)
        assert wait_for(lambda: router.stats()["replicas_lost"] == 1)
        s = router.stats()
        assert s["completed"] == 24, s
        assert s["failed"] == 0 and s["shed"] == 0, s
        assert s["replayed"] >= 1, s


# ----------------------------------------------------------------------------
# the router as an actor
# ----------------------------------------------------------------------------
def test_front_end_actor_delegates_to_submit(system):
    with make_router(system, 1, service_delay_s=0.0) as router:
        front = router.actor_ref()
        res = front.ask("serve", 4, {"max_new_tokens": 3}, timeout=60)
        assert res.tokens == expected_tokens(4, 3)
        stats = front.ask("stats", timeout=30)
        assert stats["completed"] >= 1


# ----------------------------------------------------------------------------
# LatencyStats percentile cost (ISSUE 8 satellite regression)
# ----------------------------------------------------------------------------
def test_latency_stats_poll_is_sublinear_under_load():
    """percentile()/summary() read an incrementally maintained sorted
    view — a stats poll against a full 100k reservoir must stay cheap
    (the router polls every replica every scheduling tick)."""
    st = LatencyStats()
    for i in range(100_000):
        st.record((i % 977) * 1e-4)
    t0 = time.perf_counter()
    for _ in range(100):
        st.summary()
        st.percentile(99)
    per_poll = (time.perf_counter() - t0) / 100
    # generous bound: the old sort-per-call cost was ~10ms per poll on a
    # full reservoir; the incremental view is microseconds
    assert per_poll < 1e-3, f"stats poll took {per_poll * 1e3:.2f}ms"
    s = st.summary()
    assert s["count"] == 100_000
    assert s["max_ms"] == pytest.approx(976 * 1e-4 * 1e3)


def test_latency_stats_eviction_keeps_views_consistent():
    st = LatencyStats(maxlen=100)
    for i in range(150):               # crosses the eviction boundary
        st.record(float(i))
    assert st._samples == sorted(st._ordered)   # same multiset
    assert st.summary()["count"] == 150
    assert st.percentile(100) == 149.0
    assert st.percentile(0) == st._ordered[0]


# ----------------------------------------------------------------------------
# acceptance: real 3-process mesh, SIGKILL mid-sweep (slow job)
# ----------------------------------------------------------------------------
@pytest.mark.slow
def test_three_process_mesh_survives_worker_sigkill():
    """ISSUE 8 acceptance: driver + 2 worker processes, offered-load
    sweep, one worker SIGKILLed mid-run. run_demo asserts zero lost /
    duplicated requests and ≥80% RPS recovery internally."""
    from repro.launch.serve_mesh import run_demo

    summary = run_demo(2, rps=30.0, duration_s=5.0, kill_at_s=1.5,
                       recover_window_s=1.5)
    assert summary["lost"] == 0
    assert summary["replicas_lost"] == 1
    assert summary["completed"] == summary["submitted"]
    pre, during, post = summary["windows"]
    assert post["achieved_rps"] >= 0.8 * pre["achieved_rps"]
