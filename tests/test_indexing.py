"""End-to-end WAH indexing tests (paper §4): build on 'device', decode on
host, verify round-trip against the raw data."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ActorSystem
from repro.indexing import (build_wah_index, build_wah_index_numpy,
                            decode_wah_bitmap, wah_index_pipeline_actors)


@pytest.mark.parametrize("n,card,seed", [(1024, 8, 0), (4096, 64, 1),
                                         (2048, 3, 2)])
def test_wah_roundtrip(n, card, seed):
    rng = np.random.default_rng(seed)
    values = rng.integers(0, card, n).astype(np.uint32)
    words, n_words, starts, counts = build_wah_index(jnp.asarray(values), card)
    words = np.asarray(words)[:int(n_words)]
    starts, counts = np.asarray(starts), np.asarray(counts)
    for v in range(card):
        got = decode_wah_bitmap(words, starts[v], counts[v])
        want = np.flatnonzero(values == v)
        np.testing.assert_array_equal(got, want)


def test_wah_skewed_distribution():
    rng = np.random.default_rng(7)
    values = (rng.pareto(1.5, 4096) * 3).astype(np.uint32)
    values = np.clip(values, 0, 31)
    words, n_words, starts, counts = build_wah_index(jnp.asarray(values), 32)
    words = np.asarray(words)[:int(n_words)]
    for v in range(32):
        got = decode_wah_bitmap(words, int(np.asarray(starts)[v]),
                                int(np.asarray(counts)[v]))
        np.testing.assert_array_equal(got, np.flatnonzero(values == v))


def test_wah_matches_numpy_reference_word_count():
    """The data-parallel index and the sequential CPU builder agree on the
    per-value word streams (same WAH encoding)."""
    rng = np.random.default_rng(3)
    values = rng.integers(0, 16, 2048).astype(np.uint32)
    words, n_words, starts, counts = build_wah_index(jnp.asarray(values), 16)
    words = np.asarray(words)[:int(n_words)]
    ref_words, ref_n, ref_starts, ref_counts = build_wah_index_numpy(values, 16)
    assert int(n_words) == ref_n
    np.testing.assert_array_equal(np.asarray(counts), ref_counts)
    for v in range(16):
        a = words[int(np.asarray(starts)[v]):][:int(np.asarray(counts)[v])]
        b = ref_words[ref_starts[v]:ref_starts[v] + ref_counts[v]]
        np.testing.assert_array_equal(a, b)


def test_wah_compresses_sparse_data():
    """A rare value's bitmap must be ≪ the dense bitmap size."""
    values = np.zeros(31 * 1000, np.uint32)
    values[31 * 999] = 1  # single set bit at the end for value 1
    words, n_words, starts, counts = build_wah_index(jnp.asarray(values), 2)
    counts = np.asarray(counts)
    assert counts[1] == 2  # one fill (999 chunks) + one literal


def test_actor_pipeline_matches_fused(tmp_path):
    """Paper Listing 5: the 3-stage composed actor produces the same fused
    index as the direct computation."""
    rng = np.random.default_rng(11)
    k = 1024
    fills = (rng.integers(0, 2, k) * ((1 << 31) | rng.integers(1, 100, k))).astype(
        np.uint32)
    literals = rng.integers(1, 2**31, k).astype(np.uint32)

    from repro.kernels import ops
    want_fused = np.asarray(ops.wah_interleave(jnp.asarray(fills),
                                               jnp.asarray(literals)))
    want_comp, want_n = ops.stream_compact(jnp.asarray(want_fused))

    with ActorSystem() as system:
        pipe = wah_index_pipeline_actors(system, k)
        out, n = pipe.ask(fills, literals)
        assert int(n) == int(want_n)
        np.testing.assert_array_equal(out, np.asarray(want_comp))
