"""Passing fixture for blocking-call-in-behavior (never imported)."""
import threading
import time

_pause = threading.Event()


def worker(msg):
    _pause.wait(0.1)           # event wait: interruptible, compliant
    return msg


def start(system):
    return system.spawn(worker)


def make_poller(ref):
    def poll(tag):
        fut = ref.request(tag)
        fut.add_done_callback(lambda f: None)
        return fut
    return poll


def helper_outside_behavior():
    time.sleep(0.01)           # not a behavior: nothing spawns/targets this
    return True


class Service:
    def _run(self):
        time.sleep(0.1)  # lint: simulated device latency, test-only service
        return None

    def go(self):
        threading.Thread(target=self._run).start()
