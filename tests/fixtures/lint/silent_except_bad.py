"""Failing fixture for the silent-except rule (never imported)."""


def poll(sock):
    try:
        return sock.recv(1)
    except Exception:
        pass


def drain(items):
    for it in items:
        try:
            it.close()
        except:  # noqa: E722
            continue
