"""Failing fixture for the ref-lifecycle rule (never imported)."""
import pickle

from repro.core import DeviceRef


def use_after_donate(arr, kernel):
    ref = DeviceRef(arr)
    ref.donate()
    return ref.to_value()      # use-after-donate


def double_release(arr):
    ref = DeviceRef(arr)
    ref.release()
    ref.release()              # use-after-release (double release)


def pickle_no_spill(arr):
    ref = DeviceRef(arr)
    blob = pickle.dumps(ref)   # pickle-without-spill
    ref.release()
    return blob


def dropped(arr):
    ref = DeviceRef(arr)       # unreleased-ref: bound, never mentioned again
    return None


def ask_emit_ref(system, kernel, x):
    w = system.spawn(kernel, emit="ref")
    r = w.ask(x)
    r.release()
    return r.shape             # use-after-release on an emit="ref" result
