"""Passing fixture for the static lock-order rule (never imported)."""
import threading

from repro.analysis.runtime import make_lock, make_rlock


class WellOrdered:
    """Pool before registry, consistently — matches ORDER.md and never
    nests the pair in the opposite order."""

    def __init__(self):
        self._pool = make_rlock("PagePool")
        self._reg = make_lock("RefRegistry")
        self._cv = threading.Condition(self._pool)

    def allocate(self):
        with self._pool:
            with self._reg:
                return 1

    def account(self):
        with self._reg:
            return 2
