"""Failing fixture for blocking-call-in-behavior (never imported)."""
import threading
import time


def worker(msg):
    time.sleep(0.1)            # blocking: behavior passed to spawn below
    return msg


def start(system):
    return system.spawn(worker)


def make_poller(ref):
    def poll(tag):
        return ref.ask(tag)    # blocking: synchronous ask in a behavior
    return poll


class Service:
    def _run(self):
        fut = self.submit()
        fut.result()           # blocking: join inside a Thread target

    def go(self):
        threading.Thread(target=self._run).start()
