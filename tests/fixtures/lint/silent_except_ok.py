"""Passing fixture for the silent-except rule (never imported)."""
import logging

log = logging.getLogger(__name__)


def narrow(sock):
    try:
        return sock.recv(1)
    except OSError:
        pass  # narrow catch states its intent


def logged(sock):
    try:
        return sock.recv(1)
    except Exception:
        log.warning("recv failed", exc_info=True)


def counted(sock, stats):
    try:
        return sock.recv(1)
    except Exception:
        stats["recv_errors"] += 1


def tagged(sock):
    try:
        return sock.recv(1)
    except Exception:  # lint: probe socket; any failure means not-ready
        pass
