"""Passing fixture for the ref-lifecycle rule (never imported)."""
import pickle

from repro.core import DeviceRef


def release_after_use(arr):
    ref = DeviceRef(arr)
    val = ref.to_value()
    ref.release()
    return val


def spill_then_pickle(arr):
    ref = DeviceRef(arr)
    ref.spill()
    blob = pickle.dumps(ref)
    ref.release()
    return blob


def escapes_to_caller(arr):
    ref = DeviceRef(arr)
    return ref                 # ownership transferred out


def stored_for_later(arr, cache):
    ref = DeviceRef(arr)
    cache.append(ref)          # ownership transferred to the cache


def emit_ref_released(system, kernel, x):
    w = system.spawn(kernel, emit="ref")
    r = w.ask(x)
    val = r.to_value()
    r.release()
    return val
