"""Failing fixture for the static lock-order rule (never imported)."""
from repro.analysis.runtime import make_lock


class Crossed:
    """Two methods nest the same pair of locks in opposite orders — the
    classic deadlock seed the rule must report as a cycle."""

    def __init__(self):
        self._la = make_lock("FixtureA")
        self._lb = make_lock("FixtureB")

    def one(self):
        with self._la:
            with self._lb:
                return 1

    def two(self):
        with self._lb:
            with self._la:
                return 2


class Inverted:
    """Nests two ORDER.md-ranked locks inside-out: PagePool (rank 9)
    acquired while holding RefRegistry (rank 18)."""

    def __init__(self):
        self._reg = make_lock("RefRegistry")
        self._pool = make_lock("PagePool")

    def bad(self):
        with self._reg:
            with self._pool:
                return 0
