"""Graph fusion pass + inline-dispatch fast path (ISSUE 7 acceptance).

Covers: fused-vs-staged numerical equivalence, region reporting
(``plan.fused_regions``) and single-actor lowering, fusion-boundary
correctness (broadcast / select / merge / opaque-actor / cross-device
edges break regions), ``emit="ref"`` preservation at region boundaries,
the inline-dispatch counters (single-consumer same-device edges bypass
the mailbox on ``ask``; shared/monitored edges keep it), supervision
semantics under inline dispatch (DownMessage still delivered), crash
replay staying exactly-once, and run-scoped ref accounting for fused
runs on success and failure.
"""
import gc
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ActorSystem, DownMessage, Graph, GraphRef, In,
                        KernelActor, NDRange, Out, Pipeline, dim_vec, kernel,
                        live_ref_count, memory_stats, reset_transfer_stats,
                        transfer_count)


@pytest.fixture(scope="module")
def system():
    s = ActorSystem(max_workers=8)
    yield s
    s.shutdown()


@pytest.fixture(scope="module")
def mngr(system):
    return system.opencl_manager()


@pytest.fixture()
def ref_baseline():
    gc.collect()
    return live_ref_count()


N = 16


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)),
        name="prep")
def prep(x):
    return x + 1.0


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)),
        name="double")
def double(x):
    return x * 2.0


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)),
        name="sub3")
def sub3(x):
    return x - 3.0


@kernel(In(jnp.float32), In(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(N)), name="add2")
def add2(a, b):
    return a + b


def _chain(system, kernels, name="chain"):
    g = Graph(system, name=name)
    cur = g.source("x", jnp.float32, shape=(N,))
    for k in kernels:
        cur = g.apply(k, cur)
    g.output(cur)
    return g


def _prefixed_diamond(system, name="pdiamond"):
    """source → prep → double → broadcast(2) → double/sub3 → zip → add2:
    a two-kernel fusible prefix in front of the PR 4 diamond shape."""
    g = Graph(system, name=name)
    x = g.source("x", jnp.float32, shape=(N,))
    h = g.apply(double, g.apply(prep, x))
    l, r = g.broadcast(h, 2)
    j1, j2 = g.zip_join(g.apply(double, l), g.apply(sub3, r))
    g.output(g.apply(add2, j1, j2))
    return g


def _prefixed_diamond_expected(x):
    h = (x + 1) * 2
    return h * 2 + h - 3


# ----------------------------------------------------------------------------
# the fusion pass: regions, single-actor lowering, equivalence
# ----------------------------------------------------------------------------
def test_fused_chain_is_one_region_one_actor(system):
    built = _chain(system, [prep, double, sub3], name="fc").build(fuse=True)
    assert built.plan.fused_regions == [
        ["fc/prep", "fc/double", "fc/sub3"]]
    # one spawned node actor for the whole chain
    assert len(built.node_refs) == 1
    (path, ref), = built.node_refs.items()
    actor = system._actors[ref.actor_id].actor
    assert isinstance(actor, KernelActor)
    assert actor.fused_from == ("fc/prep", "fc/double", "fc/sub3")
    x = np.arange(N, dtype=np.float32)
    np.testing.assert_allclose(built.ask(x), (x + 1) * 2 - 3, rtol=1e-6)


def test_fused_vs_staged_equivalence_on_diamond(system):
    x = np.arange(N, dtype=np.float32)
    staged = _prefixed_diamond(system, "pd_s").build()
    fused = _prefixed_diamond(system, "pd_f").build(fuse=True)
    assert staged.plan.fused_regions == []
    assert fused.plan.fused_regions == [["pd_f/prep", "pd_f/double"]]
    r_staged, r_fused = staged.ask(x), fused.ask(x)
    np.testing.assert_allclose(r_staged, _prefixed_diamond_expected(x),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(r_staged), np.asarray(r_fused))


def test_fused_boundary_emits_ref(system, ref_baseline):
    """A fused region feeding ref-capable consumers keeps emit="ref": the
    whole graph still moves zero bytes through the host."""
    built = _prefixed_diamond(system, "pd_ref").build(fuse=True)
    x = np.arange(N, dtype=np.float32)
    reset_transfer_stats()
    out = built.ask(x)
    np.testing.assert_allclose(out, _prefixed_diamond_expected(x), rtol=1e-6)
    assert transfer_count() == 0, "an interior edge round-tripped the host"
    assert memory_stats()["readbacks"] == 1     # only the final output
    time.sleep(0.2)
    gc.collect()
    assert live_ref_count() == ref_baseline


def test_pipeline_fused_mode_routes_through_graph_pass(system):
    pipe = (Pipeline(system, mode="fused", name="fp")
            .stage(prep).stage(double).stage(sub3).build())
    assert isinstance(pipe, GraphRef)
    assert len(pipe.plan.fused_regions) == 1
    assert len(pipe.plan.fused_regions[0]) == 3
    x = np.arange(N, dtype=np.float32)
    np.testing.assert_allclose(pipe.ask(x), (x + 1) * 2 - 3, rtol=1e-6)


def test_traceable_adapter_fuses_into_region(system):
    g = Graph(system, name="adapt")
    cur = g.chain_source()
    cur = g.chain(prep, cur)
    cur = g.chain(lambda x: x * 10.0, cur, traceable=True)
    cur = g.chain(double, cur)
    g.output(cur)
    built = g.build(fuse=True)
    assert len(built.plan.fused_regions) == 1
    assert len(built.plan.fused_regions[0]) == 3
    x = np.ones(N, np.float32)
    np.testing.assert_allclose(built.ask(x), (x + 1) * 10 * 2, rtol=1e-6)


# ----------------------------------------------------------------------------
# fusion boundaries: what must NOT fuse
# ----------------------------------------------------------------------------
def test_broadcast_breaks_region(system):
    built = _prefixed_diamond(system, "pd_b").build(fuse=True)
    # only the prefix fuses; the broadcast arms and the sink stay separate
    assert built.plan.fused_regions == [["pd_b/prep", "pd_b/double"]]
    assert len(built.node_refs) == 4    # fused prefix + 2 arms + sink


def test_select_and_merge_break_regions(system):
    g = Graph(system, name="sm")
    x = g.source("x", jnp.float32, shape=(N,))
    h = g.apply(prep, x)
    hi, lo = g.select(h, lambda v: 0, 2)
    m = g.merge(g.apply(double, hi), g.apply(sub3, lo))
    g.output(g.apply(double, m))
    built = g.build(fuse=True)
    assert built.plan.fused_regions == []
    xs = np.arange(N, dtype=np.float32)
    np.testing.assert_allclose(built.ask(xs), (xs + 1) * 2 * 2, rtol=1e-6)


def test_opaque_actor_node_breaks_region(system):
    opaque = system.spawn(lambda x: x * 3.0)        # not traceable
    g = Graph(system, name="op")
    cur = g.chain_source()
    cur = g.chain(prep, cur)
    cur = g.chain(opaque, cur)
    cur = g.chain(double, cur)
    g.output(cur)
    built = g.build(fuse=True)
    assert built.plan.fused_regions == []
    x = np.arange(N, dtype=np.float32)
    np.testing.assert_allclose(built.ask(x), (x + 1) * 3 * 2, rtol=1e-6)


def test_untraceable_python_stage_breaks_region(system):
    g = Graph(system, name="py")
    cur = g.chain_source()
    cur = g.chain(prep, cur)
    cur = g.chain(lambda x: x * 3.0, cur)       # no traceable=True
    cur = g.chain(double, cur)
    g.output(cur)
    assert g.build(fuse=True).plan.fused_regions == []


def test_cross_device_edge_breaks_region(system):
    class _FakeDev:
        def __init__(self):
            self.jax_device = object()

        def live_bytes(self):
            return 0

        def queue_depth(self):
            return 0

    d0, d1 = _FakeDev(), _FakeDev()
    g = Graph(system, name="xdev")
    x = g.source("x", jnp.float32, shape=(N,))
    cur = g.apply(prep, x, device=d0)
    cur = g.apply(double, cur, device=d1)
    g.output(cur)
    built = g.build(fuse=True)      # build-time only: never dispatched
    assert built.plan.fused_regions == []
    assert len(built.node_refs) == 2


# ----------------------------------------------------------------------------
# inline-dispatch fast path
# ----------------------------------------------------------------------------
def test_chain_ask_dispatches_inline(system):
    built = _chain(system, [prep, double, sub3], name="inl").build()
    x = np.arange(N, dtype=np.float32)
    np.testing.assert_allclose(built.ask(x), (x + 1) * 2 - 3, rtol=1e-6)
    stats = built.dispatch_stats
    assert stats == {"inline": 3, "mailbox": 0}


def test_request_keeps_mailbox_path(system):
    built = _chain(system, [prep, double], name="mbx").build()
    x = np.arange(N, dtype=np.float32)
    fut = built.request(x)
    np.testing.assert_allclose(fut.result(timeout=30), (x + 1) * 2, rtol=1e-6)
    assert built.dispatch_stats == {"inline": 0, "mailbox": 2}


def test_broadcast_arms_keep_mailbox(system):
    built = _prefixed_diamond(system, "pd_c").build(fuse=True)
    x = np.arange(N, dtype=np.float32)
    built.ask(x)
    stats = built.dispatch_stats
    # fused prefix + sink dispatch inline; the two broadcast arms are
    # shared-producer edges and must keep the mailbox
    assert stats["inline"] == 2
    assert stats["mailbox"] == 2


def test_monitor_forces_mailbox_and_down_message(system):
    built = _chain(system, [prep, double], name="mon").build()
    seen = []
    watcher = system.spawn(lambda msg: seen.append(msg))
    stage1 = built.node_refs["mon/prep"]
    system.monitor(watcher, stage1)
    x = np.arange(N, dtype=np.float32)
    np.testing.assert_allclose(built.ask(x), (x + 1) * 2, rtol=1e-6)
    stats = built.dispatch_stats
    # the monitored stage falls back to the mailbox; the other stays inline
    assert stats == {"inline": 1, "mailbox": 1}
    # crash the monitored stage: supervision semantics intact
    with pytest.raises(Exception):
        built.ask(np.arange(4, dtype=np.int64))
    deadline = time.monotonic() + 10
    while not seen and time.monotonic() < deadline:
        time.sleep(0.02)
    assert seen and isinstance(seen[0], DownMessage)
    assert seen[0].actor_id == stage1.actor_id


def test_inline_crash_replay_exactly_once(system):
    state = {"crashed": False, "runs": []}

    def flaky_pre(x):
        if not state["crashed"]:
            state["crashed"] = True
            raise RuntimeError("injected crash")
        state["runs"].append(float(np.asarray(x)[0]))
        return (x,)

    flaky = prep.with_options(name="flaky", preprocess=flaky_pre)
    workers = [_chain(system, [flaky], name=f"flk{i}").build()
               for i in range(2)]
    payloads = [np.full(N, float(i), np.float32) for i in range(4)]
    results = []
    for x in payloads:
        for w in workers:
            try:
                results.append(w.ask(x))
                break
            except Exception:
                continue        # failover: re-issue on the next worker
        else:
            pytest.fail("payload lost: every worker failed")
    for i, r in enumerate(results):
        np.testing.assert_allclose(r, payloads[i] + 1, rtol=1e-6)
    # the crashed attempt was replayed exactly once: every payload ran to
    # completion on exactly one worker, no duplicates
    assert sorted(state["runs"]) == [0.0, 1.0, 2.0, 3.0]
    # the crash happened on the inline path of worker 0
    assert workers[0].dispatch_stats["inline"] >= 1


# ----------------------------------------------------------------------------
# ref accounting for fused runs
# ----------------------------------------------------------------------------
def test_fused_run_releases_refs_on_failure(system, ref_baseline):
    @kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)),
            name="boom")
    def boom(x):
        raise RuntimeError("downstream failure")

    g = Graph(system, name="leak")
    x = g.source("x", jnp.float32, shape=(N,))
    cur = g.apply(double, g.apply(prep, x))     # fusible prefix, emits a ref
    l, r = g.broadcast(cur, 2)                  # boundary: prefix stays fused
    j1, j2 = g.zip_join(g.apply(boom, l), g.apply(sub3, r))
    g.output(g.apply(add2, j1, j2))
    built = g.build(fuse=True)
    assert built.plan.fused_regions == [["leak/prep", "leak/double"]]
    with pytest.raises(Exception):
        built.ask(np.arange(N, dtype=np.float32))
    time.sleep(0.2)
    gc.collect()
    assert live_ref_count() == ref_baseline


def test_fused_run_releases_refs_on_success(system, ref_baseline):
    built = _chain(system, [prep, double, sub3], name="ok").build(fuse=True)
    x = np.arange(N, dtype=np.float32)
    for _ in range(3):
        np.testing.assert_allclose(built.ask(x), (x + 1) * 2 - 3, rtol=1e-6)
    time.sleep(0.2)
    gc.collect()
    assert live_ref_count() == ref_baseline
