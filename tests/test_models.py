"""Per-architecture smoke tests: reduced same-family config, one forward +
loss + grad step + one decode step on CPU; output shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import Model, train_input_specs

ARCHS = configs.list_archs()


def _make_batch(cfg, batch=2, seq=16, key=0):
    rng = np.random.default_rng(key)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                              jnp.int32),
    }
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.encdec.n_frames, cfg.d_model)),
            jnp.dtype(cfg.compute_dtype))
    if cfg.family == "vlm":
        b["vision_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_vision_tokens, cfg.d_model)),
            jnp.dtype(cfg.compute_dtype))
        b["positions"] = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32),
                                          (3, batch, seq))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = configs.get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = _make_batch(cfg)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["ce"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_grad_step(arch):
    cfg = configs.get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    batch = _make_batch(cfg)

    def loss(p):
        return model.loss(p, batch)[0]

    g = jax.grad(loss)(params)
    flat = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(x, np.float32)).all() for x in flat)
    # at least one non-zero gradient
    assert any(float(jnp.abs(x).max()) > 0 for x in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = configs.get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(2))
    batch_size, max_len = 2, 32
    if cfg.family == "encdec":
        rng = np.random.default_rng(0)
        frames = jnp.asarray(
            rng.standard_normal((batch_size, cfg.encdec.n_frames, cfg.d_model)),
            jnp.dtype(cfg.compute_dtype))
        cache = model.init_cache(batch_size, max_len, params=params,
                                 frames=frames)
    else:
        cache = model.init_cache(batch_size, max_len)
    tok = jnp.zeros((batch_size, 1), jnp.int32)
    for step in range(3):
        logits, cache = model.decode_step(params, tok, cache)
        assert logits.shape == (batch_size, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert int(cache["len"]) == step + 1
        tok = jnp.argmax(logits[:, :, :], axis=-1).astype(jnp.int32)


def test_decode_matches_forward_dense():
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = configs.get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(jax.random.key(3))
    rng = np.random.default_rng(5)
    seq = 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, seq)), jnp.int32)
    full_logits, _ = model.forward(params, {"tokens": tokens})

    cache = model.init_cache(1, seq)
    step_logits = []
    for t in range(seq):
        lg, cache = model.decode_step(params, tokens[:, t:t + 1], cache)
        step_logits.append(lg[:, 0])
    step_logits = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["mamba2-130m", "recurrentgemma-9b"])
def test_decode_matches_forward_recurrent(arch):
    """Recurrent/hybrid decode must agree with the parallel (scan) path —
    validates SSD chunking and the associative-scan RG-LRU."""
    cfg = configs.get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(4))
    rng = np.random.default_rng(6)
    seq = 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, seq)), jnp.int32)
    full_logits, _ = model.forward(params, {"tokens": tokens})

    cache = model.init_cache(1, seq)
    step_logits = []
    for t in range(seq):
        lg, cache = model.decode_step(params, tokens[:, t:t + 1], cache)
        step_logits.append(lg[:, 0])
    step_logits = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits), rtol=5e-3, atol=5e-3)


def test_ssd_chunking_invariance():
    """SSD output must not depend on the chunk size (state passing exact)."""
    import dataclasses
    from repro.models import ssm as ssm_mod
    cfg16 = configs.get_smoke_config("mamba2-130m")
    cfg4 = dataclasses.replace(
        cfg16, ssm=dataclasses.replace(cfg16.ssm, chunk=4))
    key = jax.random.key(0)
    p = ssm_mod.init_ssm(key, cfg16, jnp.float32)
    u = jax.random.normal(jax.random.key(1), (2, 16, cfg16.d_model), jnp.float32)
    y16 = ssm_mod.apply_ssm(p, cfg16, u)
    y4 = ssm_mod.apply_ssm(p, cfg4, u)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y4),
                               rtol=2e-4, atol=2e-4)


def test_param_count_sanity():
    """Full configs must land near their published parameter counts."""
    approx = {
        "llama3-8b": 8.0e9,
        "dbrx-132b": 132e9,
        "phi3.5-moe-42b-a6.6b": 42e9,
        "nemotron-4-340b": 340e9,
        "qwen1.5-32b": 32e9,
        "recurrentgemma-9b": 9e9,
        "mamba2-130m": 130e6,
        "qwen3-1.7b": 1.7e9,
        "qwen2-vl-2b": 1.5e9,  # LM backbone only (vision tower stubbed)
        "whisper-tiny": 37e6,
    }
    for arch, want in approx.items():
        got = configs.get_config(arch).param_count()
        assert 0.5 * want < got < 1.6 * want, (arch, got, want)


def test_chunked_prefill_matches_plain():
    """xla_chunked (Sarathi-style prefill) must equal plain attention."""
    cfg = configs.get_smoke_config("llama3-8b")
    m_plain = Model(cfg, attn_impl="xla")
    m_chunk = Model(cfg, attn_impl="xla_chunked:8")
    params = m_plain.init(jax.random.key(7))
    batch = _make_batch(cfg, batch=2, seq=32)
    a, _ = m_plain.forward(params, batch)
    b, _ = m_chunk.forward(params, batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_moe_group_size_invariance():
    """Routing in groups must keep outputs finite and change only capacity
    truncation; with generous capacity, outputs match exactly."""
    import dataclasses
    from repro.models import moe as moe_mod
    cfg = configs.get_smoke_config("phi3.5-moe-42b-a6.6b")
    cfg_big = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0, group_size=4096))
    cfg_grp = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0, group_size=8))
    p = moe_mod.init_moe(jax.random.key(0), cfg_big, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    y_full, _ = moe_mod.apply_moe(p, cfg_big, x)
    y_grp, _ = moe_mod.apply_moe(p, cfg_grp, x)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_grp),
                               rtol=1e-4, atol=1e-5)
