"""Static HLO analyzer tests: trip-count recovery, loop-scaled FLOPs,
collective parsing — validated against programs with known costs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hlo_stats
from repro.roofline.analysis import parse_collectives


def _compiled(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_flops_plain_matmul():
    n = 256
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    c = _compiled(lambda a, b: a @ b, spec, spec)
    stats = hlo_stats.analyze_module(c.as_text())
    want = 2 * n ** 3
    assert want * 0.99 <= stats.flops <= want * 1.05, stats.flops


def test_flops_scanned_matmul_counts_trip_count():
    """10-step scan of a 256³ matmul must count 10× — the exact failure
    mode of cost_analysis() this analyzer exists to fix."""
    n, steps = 256, 10
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=steps)
        return out

    c = _compiled(f, spec)
    stats = hlo_stats.analyze_module(c.as_text())
    want = steps * 2 * n ** 3
    assert want * 0.9 <= stats.flops <= want * 1.1, (stats.flops, want)
    # and XLA's own count misses the trip count
    xla_flops = float(c.cost_analysis().get("flops", 0))
    assert xla_flops < want * 0.5


def test_flops_nested_scan_multiplies():
    n, inner, outer = 128, 4, 3
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def f(x):
        def outer_body(c, _):
            def inner_body(c2, _):
                return c2 @ c2, None
            c2, _ = jax.lax.scan(inner_body, c, None, length=inner)
            return c2, None
        out, _ = jax.lax.scan(outer_body, x, None, length=outer)
        return out

    c = _compiled(f, spec)
    stats = hlo_stats.analyze_module(c.as_text())
    want = outer * inner * 2 * n ** 3
    assert want * 0.9 <= stats.flops <= want * 1.1, (stats.flops, want)


def test_bytes_accessed_scales_with_loop():
    n, steps = 512, 8
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=steps)
        return out

    c = _compiled(f, spec)
    stats = hlo_stats.analyze_module(c.as_text())
    # at least steps × (2 reads + 1 write) of the matrix
    assert stats.bytes_accessed >= steps * 3 * n * n * 4


def test_collective_parse_psum():
    import subprocess, sys, os
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.roofline import hlo_stats
mesh = jax.make_mesh((4,), ("d",))
def f(x):
    return jax.lax.psum(x, "d")
c = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P()),
            ).lower(jax.ShapeDtypeStruct((4, 1024), jnp.float32)).compile()
stats = hlo_stats.analyze_module(c.as_text())
assert "all-reduce" in stats.collective_bytes, stats.collective_bytes
assert stats.collective_bytes["all-reduce"] >= 1024 * 4
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout


def test_legacy_collective_parser_shapes():
    txt = ("%ag = bf16[128,1024]{1,0} all-gather(bf16[8,1024]{1,0} %x), "
           "replica_groups=[16,16]<=[256], dimensions={0}")
    st = parse_collectives(txt)
    assert st.bytes_by_kind["all-gather"] == 128 * 1024 * 2
    assert st.count == 1
