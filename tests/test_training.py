"""Training-substrate tests: data determinism, checkpoints, optimizer,
end-to-end loss decrease, grad-accum equivalence."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import checkpoint as ckpt
from repro.data import Prefetcher, SyntheticLM
from repro.dist import step as step_mod
from repro.models import Model
from repro.optim import AdamWConfig, adamw, schedule


@pytest.fixture(scope="module")
def small():
    cfg = configs.get_smoke_config("llama3-8b")
    model = Model(cfg)
    ocfg = AdamWConfig(lr=1e-2, weight_decay=0.0)
    state = step_mod.init_train_state(model, jax.random.key(0), ocfg)
    return cfg, model, ocfg, state


def test_data_deterministic_and_sharded():
    cfg = configs.get_smoke_config("llama3-8b")
    a = SyntheticLM(cfg, batch=8, seq=16, seed=3)
    b = SyntheticLM(cfg, batch=8, seq=16, seed=3)
    np.testing.assert_array_equal(a.batch_at(7)["tokens"], b.batch_at(7)["tokens"])
    assert not np.array_equal(a.batch_at(7)["tokens"], a.batch_at(8)["tokens"])
    # shard streams are disjoint slices of the deterministic global stream
    s0 = SyntheticLM(cfg, batch=8, seq=16, seed=3, shard=0, num_shards=2)
    s1 = SyntheticLM(cfg, batch=8, seq=16, seed=3, shard=1, num_shards=2)
    assert s0.batch_at(0)["tokens"].shape == (4, 16)
    assert not np.array_equal(s0.batch_at(0)["tokens"], s1.batch_at(0)["tokens"])


def test_prefetcher_orders_batches():
    cfg = configs.get_smoke_config("llama3-8b")
    src = SyntheticLM(cfg, batch=4, seq=8, seed=0)
    pf = Prefetcher(src, depth=2)
    try:
        for want in range(4):
            step, batch = pf.next()
            assert step == want
            np.testing.assert_array_equal(batch["tokens"],
                                          src.batch_at(want)["tokens"])
    finally:
        pf.close()


def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.int32), {"c": jnp.zeros((), jnp.float32)}]}
    d = str(tmp_path)
    for s in (1, 2, 3, 4):
        ckpt.save(d, s, tree, keep=2)
    assert ckpt.all_steps(d) == [3, 4]
    restored, manifest = ckpt.restore(d, target=tree)
    assert manifest["step"] == 4
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert x.dtype == y.dtype


def test_adamw_converges_quadratic():
    ocfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params, ocfg)
    for _ in range(200):
        grads = jax.tree.map(lambda w: 2 * w, params)  # d/dw w^2
        params, state, _ = adamw.update(grads, state, params, ocfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_accum_matches_full_batch(small):
    """Microbatch-accumulated gradients equal the full-batch gradient.

    (Compared pre-optimizer: first-step Adam normalizes by √v ≈ |g|, which
    amplifies float noise on near-zero grads into sign flips.)
    """
    cfg, model, ocfg, state = small
    data = SyntheticLM(cfg, batch=8, seq=16, seed=1)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    params = state["params"]

    loss = lambda p, b: model.loss(p, b)[0]
    l_full, g_full = jax.value_and_grad(loss)(params, batch)

    accum = 4
    mbs = step_mod._split_microbatches(batch, accum)
    g_acc = jax.tree.map(jnp.zeros_like, params)
    l_acc = 0.0
    for i in range(accum):
        mb = {k: v[i] for k, v in mbs.items()}
        l, g = jax.value_and_grad(loss)(params, mb)
        l_acc += float(l) / accum
        g_acc = jax.tree.map(lambda a, b: a + b / accum, g_acc, g)
    np.testing.assert_allclose(l_acc, float(l_full), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g_acc), jax.tree.leaves(g_full)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-5)

    # the two train steps agree on the loss metric
    step4 = step_mod.build_train_step(model, ocfg, grad_accum=4)
    _, m4 = jax.jit(step4)(state, batch)
    np.testing.assert_allclose(float(m4["loss"]), float(l_full), rtol=1e-5)


def test_loss_decreases_over_training(small):
    cfg, model, ocfg, state = small
    data = SyntheticLM(cfg, batch=8, seq=32, seed=2, noise=0.02)
    sched = schedule.warmup_cosine(5, 60)
    tstep = jax.jit(step_mod.build_train_step(model, ocfg, lr_schedule=sched))
    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = tstep(state, batch)
        losses.append(float(metrics["loss"]))
    assert int(state["step"]) == 60
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first * 0.8, (first, last)


def test_serve_step_greedy(small):
    cfg, model, ocfg, state = small
    serve = jax.jit(step_mod.build_serve_step(model))
    cache = model.init_cache(2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    nxt, logits, cache = serve(state["params"], cache, tok)
    assert nxt.shape == (2, 1)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert int(cache["len"]) == 1
