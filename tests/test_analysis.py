"""Tests for repro.analysis: the AST lint rules (driven by the fixture
snippets under tests/fixtures/lint/), the baseline workflow, the CLI,
and the dynamic TrackedLock / leak-sentinel runtime."""
import os
import subprocess
import sys
import threading

import pytest

from repro.analysis import order as order_mod
from repro.analysis import runtime as rt
from repro.analysis.lint import (compare, fingerprints, load_baseline,
                                 run_rules, write_baseline)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "lint")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint(name):
    findings, errors = run_rules([os.path.join(FIXTURES, name)])
    assert not errors, errors
    return findings


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ----------------------------------------------------------------------------
# rule fixtures: one failing + one passing file per rule
# ----------------------------------------------------------------------------
def test_silent_except_fixture():
    bad = lint("silent_except_bad.py")
    assert rules_of(bad) == ["silent-except"]
    assert len(bad) == 2          # except Exception: pass + bare except
    assert {f.detail for f in bad} == {"Exception", "bare"}
    assert lint("silent_except_ok.py") == []


def test_blocking_call_fixture():
    bad = lint("blocking_call_bad.py")
    assert rules_of(bad) == ["blocking-call-in-behavior"]
    assert {f.detail for f in bad} == {"time.sleep", ".ask()", ".result()"}
    assert {f.qualname for f in bad} == {
        "worker", "make_poller.poll", "Service._run"}
    assert lint("blocking_call_ok.py") == []


def test_ref_lifecycle_fixture():
    bad = lint("ref_lifecycle_bad.py")
    assert rules_of(bad) == ["ref-lifecycle"]
    details = {f.detail for f in bad}
    assert details == {
        "use-after-donate:ref", "use-after-release:ref",
        "pickle-without-spill:ref", "unreleased-ref:ref",
        "use-after-release:r",
    }
    assert lint("ref_lifecycle_ok.py") == []


def test_lock_order_fixture():
    bad = lint("lock_order_bad.py")
    assert rules_of(bad) == ["lock-order"]
    details = sorted(f.detail for f in bad)
    assert any(d.startswith("cycle:") and "FixtureA" in d and
               "FixtureB" in d for d in details), details
    assert "inversion:RefRegistry->PagePool" in details
    assert lint("lock_order_ok.py") == []


# ----------------------------------------------------------------------------
# baseline workflow
# ----------------------------------------------------------------------------
def test_baseline_roundtrip(tmp_path):
    findings = lint("silent_except_bad.py")
    bl = tmp_path / "baseline.txt"
    write_baseline(str(bl), findings)
    loaded = load_baseline(str(bl))
    assert loaded == fingerprints(findings)

    new, stale = compare(findings, loaded)
    assert new == [] and stale == []

    # deleting any one entry resurfaces exactly that finding
    new, stale = compare(findings, loaded[1:])
    assert len(new) == 1 and stale == []

    # fixing a finding leaves a stale entry (warning, not failure)
    new, stale = compare(findings[1:], loaded)
    assert new == [] and len(stale) == 1


def test_fingerprints_are_line_free_and_deduped():
    findings = lint("ref_lifecycle_bad.py")
    fps = fingerprints(findings)
    assert len(set(fps)) == len(fps)
    for fp in fps:
        relpath, rule, qual, detail = fp.split("::")
        assert relpath.endswith("ref_lifecycle_bad.py")
        assert not any(ch.isdigit() and "#" not in fp for ch in ())  # shape only
        assert rule == "ref-lifecycle" and qual and detail


def test_cli_gate(tmp_path):
    """End-to-end: bad fixture fails, --write-baseline then passes, and
    deleting a baseline line fails again."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"), REPRO_ANALYSIS="")
    bad = os.path.join(FIXTURES, "silent_except_bad.py")
    bl = str(tmp_path / "bl.txt")

    def cli(*args):
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis", *args],
            cwd=REPO, env=env, capture_output=True, text=True)

    assert cli(bad).returncode == 1
    assert cli(bad, "--baseline", bl, "--write-baseline").returncode == 0
    assert cli(bad, "--baseline", bl).returncode == 0
    lines = open(bl).read().splitlines()
    open(bl, "w").write("\n".join(lines[:-1]) + "\n")
    assert cli(bad, "--baseline", bl).returncode == 1


def test_repo_tree_is_clean_under_checked_in_baseline():
    findings, errors = run_rules([os.path.join(REPO, "src", "repro")])
    assert not errors, errors
    baseline = load_baseline(os.path.join(REPO, "analysis-baseline.txt"))
    new, _stale = compare(findings, baseline)
    assert new == [], [f.render() for f in new]


# ----------------------------------------------------------------------------
# ORDER.md <-> order.py
# ----------------------------------------------------------------------------
def test_canonical_order_parses_order_md():
    names = order_mod.CANONICAL_LOCK_ORDER
    assert names[0] == "MeshRouter"
    assert names[-1] == "RefRegistry"
    assert len(names) == len(set(names)) >= 20
    for expected in ("ChunkScheduler", "PagePool", "ActorState",
                     "NodeRuntime", "GraphRun", "PlacementService"):
        assert expected in names
    assert order_mod.rank_of("PagePool") < order_mod.rank_of("RefRegistry")
    # the placement service is queried by every dispatcher (pool,
    # scheduler, router, node runtime — all while holding their own
    # locks) and reads live-bytes through the registry while held: its
    # rank must sit strictly between DeviceManager and RefRegistry
    assert (order_mod.rank_of("DeviceManager")
            < order_mod.rank_of("PlacementService")
            < order_mod.rank_of("RefRegistry"))
    for outer in ("ActorPool", "ChunkScheduler", "MeshRouter",
                  "NodeRuntime"):
        assert order_mod.rank_of(outer) < \
            order_mod.rank_of("PlacementService")
    assert order_mod.rank_of("not-a-lock") is None
    assert os.path.exists(order_mod.order_path())


# ----------------------------------------------------------------------------
# dynamic runtime: TrackedLock / TrackedRLock
# ----------------------------------------------------------------------------
@pytest.fixture
def clean_lock_graph():
    """Deliberate-violation tests must not leave cycles/violations in
    the process-wide graph: the REPRO_ANALYSIS sessionfinish gate would
    (correctly) fail the whole run on them."""
    rt.reset_lock_graph()
    yield
    rt.reset_lock_graph()


def test_tracked_lock_cycle_fires(clean_lock_graph):
    a, b = rt.TrackedLock("CycA"), rt.TrackedLock("CycB")
    with a:
        with b:                       # records CycA -> CycB
            pass
    with b:
        with pytest.raises(rt.LockOrderViolation, match="cycle"):
            a.acquire()               # CycB -> CycA closes the cycle
    assert rt.recorded_violations()


def test_tracked_lock_canonical_rank_fires(clean_lock_graph):
    reg = rt.TrackedLock("RefRegistry")   # rank 20
    pool = rt.TrackedLock("PagePool")     # rank 11: must be taken first
    with reg:
        with pytest.raises(rt.LockOrderViolation, match="canonical"):
            pool.acquire()
    # the documented order is fine
    with pool:
        with reg:
            pass


def test_tracked_lock_failed_try_lock_leaves_no_edge(clean_lock_graph):
    """A non-blocking acquire that loses the race must not seed a
    phantom edge — try-lock fallback patterns would otherwise surface
    as false cycles."""
    a, b = rt.TrackedLock("ProbeA"), rt.TrackedLock("ProbeB")
    b._inner.acquire()                 # make b contended
    try:
        with a:
            assert not b.acquire(blocking=False)
    finally:
        b._inner.release()
    assert rt.lock_order_graph() == {}
    # a *successful* non-blocking acquire still records the edge
    with a:
        assert b.acquire(blocking=False)
        b.release()
    assert "ProbeB" in rt.lock_order_graph().get("ProbeA", {})


def test_tracked_lock_rank_check_sees_past_unranked(clean_lock_graph):
    """An unranked lock on top of the stack must not mask an inversion
    against a ranked lock held beneath it."""
    inner = rt.TrackedLock("RefRegistry")       # innermost rank
    mid = rt.TrackedLock("UnrankedMiddle")
    outer = rt.TrackedLock("PagePool")          # outer rank
    with inner:
        with mid:
            with pytest.raises(rt.LockOrderViolation, match="canonical"):
                outer.acquire()


def test_tracked_lock_self_deadlock_fires(clean_lock_graph):
    l = rt.TrackedLock("SelfL")
    with l:
        with pytest.raises(rt.LockOrderViolation, match="re-acquired"):
            l.acquire()


def test_tracked_rlock_reentrant_and_condition(clean_lock_graph):
    l = rt.TrackedRLock("ReentL")
    with l:
        with l:                       # reentrancy is fine
            assert l._is_owned()
    cv = threading.Condition(l)
    fired = []

    def waiter():
        with cv:
            fired.append(cv.wait(5.0))

    t = threading.Thread(target=waiter)
    t.start()
    for _ in range(500):
        with cv:
            if fired or cv._waiters:  # wait until the waiter is parked
                cv.notify_all()
                break
        threading.Event().wait(0.01)
    t.join(5.0)
    assert fired == [True]


def test_tracked_graph_snapshot_and_reset(clean_lock_graph):
    a, b = rt.TrackedLock("SnapA"), rt.TrackedLock("SnapB")
    with a:
        with b:
            pass
    graph = rt.lock_order_graph()
    assert "SnapB" in graph.get("SnapA", {})
    assert rt.lock_order_cycles() == []
    rt.reset_lock_graph()
    assert rt.lock_order_graph() == {}


def test_make_lock_seam_respects_env(monkeypatch):
    monkeypatch.setenv("REPRO_ANALYSIS", "1")
    assert isinstance(rt.make_lock("X"), rt.TrackedLock)
    assert isinstance(rt.make_rlock("X"), rt.TrackedRLock)
    monkeypatch.setenv("REPRO_ANALYSIS", "0")
    assert not isinstance(rt.make_lock("X"), rt.TrackedLock)
    assert not isinstance(rt.make_rlock("X"), rt.TrackedRLock)


# ----------------------------------------------------------------------------
# leak sentinel
# ----------------------------------------------------------------------------
def test_settled_ref_growth_counts_leaks():
    import jax.numpy as jnp

    from repro.core.memref import DeviceRef, live_ref_count

    before = live_ref_count()
    ref = DeviceRef(jnp.arange(8.0))
    assert rt.settled_ref_growth(before, timeout=0.2) == 1
    ref.release()
    assert rt.settled_ref_growth(before, timeout=2.0) <= 0


@pytest.mark.ref_leak_ok
def test_ref_leak_ok_marker_opts_out():
    """Holds a ref past the test body on purpose; the sentinel must not
    fail it (module-level holder released by the next test)."""
    import jax.numpy as jnp

    from repro.core.memref import DeviceRef

    _leaky.append(DeviceRef(jnp.arange(4.0)))


_leaky = []


def test_ref_leak_ok_cleanup():
    while _leaky:
        _leaky.pop().release()
