"""Tests for the kernel-actor facade, mem_refs, composition, scheduler
(paper §3.2–3.6)."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ActorSystem, ChunkScheduler, DeviceRef, In, InOut,
                        NDRange, Out, SignatureMismatch, compose, dim_vec,
                        fuse, split_offload)


@pytest.fixture(scope="module")
def system():
    s = ActorSystem(max_workers=4)
    yield s
    s.shutdown()


@pytest.fixture(scope="module")
def mngr(system):
    return system.opencl_manager()


def _mm(a, b):
    return a @ b


def test_matmul_facade_value_semantics(mngr):
    n = 32
    w = mngr.spawn(_mm, "m_mult", NDRange(dim_vec(n, n)),
                   In(jnp.float32), In(jnp.float32),
                   Out(jnp.float32, shape=(n, n)))
    a = np.random.default_rng(0).random((n, n), np.float32)
    b = np.random.default_rng(1).random((n, n), np.float32)
    r = w.ask(a, b)
    assert isinstance(r, np.ndarray)
    np.testing.assert_allclose(r, a @ b, rtol=1e-5)


def test_out_ref_returns_deviceref(mngr):
    w = mngr.spawn(lambda x: x * 3.0, "scale", NDRange(dim_vec(8)),
                   In(jnp.float32), Out(jnp.float32, as_ref=True))
    r = w.ask(np.ones(8, np.float32))
    assert isinstance(r, DeviceRef)
    np.testing.assert_allclose(r.to_value(), 3.0)
    r.release()
    with pytest.raises(RuntimeError):
        _ = r.array


def test_deviceref_not_serializable(mngr):
    import pickle
    w = mngr.spawn(lambda x: x, "id", NDRange(dim_vec(4)),
                   In(jnp.float32), Out(jnp.float32, as_ref=True))
    r = w.ask(np.zeros(4, np.float32))
    with pytest.raises(TypeError):
        pickle.dumps(r)


def test_inout_consumes_incoming_ref(mngr):
    producer = mngr.spawn(lambda x: x + 1.0, "p", NDRange(dim_vec(4)),
                          In(jnp.float32), Out(jnp.float32, as_ref=True))
    updater = mngr.spawn(lambda x: x * 2.0, "u", NDRange(dim_vec(4)),
                         InOut(jnp.float32, as_ref=True))
    ref = producer.ask(np.zeros(4, np.float32))
    out = updater.ask(ref)
    np.testing.assert_allclose(out.to_value(), 2.0)
    # incoming in_out ref has been consumed (buffer ownership transferred)
    with pytest.raises(RuntimeError):
        _ = ref.array


def test_dtype_mismatch_raises(mngr):
    w = mngr.spawn(lambda x: x, "id2", NDRange(dim_vec(4)),
                   In(jnp.float32), Out(jnp.float32))
    with pytest.raises(SignatureMismatch):
        w.ask(np.zeros(4, np.int32))


def test_wrong_arity_raises(mngr):
    w = mngr.spawn(lambda x: x, "id3", NDRange(dim_vec(4)),
                   In(jnp.float32), Out(jnp.float32))
    with pytest.raises(SignatureMismatch):
        w.ask(np.zeros(4, np.float32), np.zeros(4, np.float32))


def test_pre_post_processing(mngr):
    """Paper Listing 3: conversion functions around the kernel."""
    def pre(matrix_pair):
        a, b = matrix_pair
        return (a.astype(np.float32), b.astype(np.float32))

    def post(result):
        return {"matrix": result}

    n = 8
    w = mngr.spawn(_mm, "mm_pp", NDRange(dim_vec(n, n)),
                   In(jnp.float32), In(jnp.float32),
                   Out(jnp.float32, shape=(n, n)),
                   preprocess=pre, postprocess=post)
    a = np.eye(n)
    out = w.ask((a, a))
    np.testing.assert_allclose(out["matrix"], a, rtol=1e-6)


def test_ndrange_validation():
    with pytest.raises(ValueError):
        NDRange(dim_vec(8), local_dims=(3,))
    with pytest.raises(ValueError):
        dim_vec(1, 2, 3, 4)
    r = NDRange(dim_vec(16, 8), local_dims=(4, 4))
    assert r.grid() == (4, 2)
    assert r.total_items == 128


def test_ndrange_split_fractions():
    r = NDRange(dim_vec(10))
    parts = r.split([0.5, 0.3, 0.2])
    sizes = [p.global_dims[0] for p in parts if p]
    assert sum(sizes) == 10
    offs = [p.offsets[0] for p in parts if p]
    assert offs == [0, sizes[0], sizes[0] + sizes[1]]
    parts = r.split([1.0, 0.0])
    assert parts[1] is None and parts[0].global_dims == (10,)


def test_staged_composition_device_resident(mngr, system):
    """Paper §3.5: references flow between stages, data stays on device."""
    s1 = mngr.spawn(lambda x: x + 1.0, "s1", NDRange(dim_vec(16)),
                    In(jnp.float32), Out(jnp.float32, as_ref=True))
    s2 = mngr.spawn(lambda x: x * 2.0, "s2", NDRange(dim_vec(16)),
                    In(jnp.float32), Out(jnp.float32, as_ref=True))
    s3 = mngr.spawn(lambda x: x - 3.0, "s3", NDRange(dim_vec(16)),
                    In(jnp.float32), Out(jnp.float32))
    pipe = s3 * s2 * s1  # s3(s2(s1(x)))
    x = np.arange(16, dtype=np.float32)
    np.testing.assert_allclose(pipe.ask(x), (x + 1) * 2 - 3)


def test_fused_composition_single_program(mngr, system):
    s1 = mngr.spawn(lambda x: x + 1.0, "f1", NDRange(dim_vec(16)),
                    In(jnp.float32), Out(jnp.float32, as_ref=True))
    s2 = mngr.spawn(lambda x: x * 2.0, "f2", NDRange(dim_vec(16)),
                    In(jnp.float32), Out(jnp.float32))
    fused = fuse(system, s1, s2, name="f12")
    x = np.arange(16, dtype=np.float32)
    np.testing.assert_allclose(fused.ask(x), (x + 1) * 2)


def test_fuse_with_adapter(mngr, system):
    a = mngr.spawn(lambda x: (x, x + 1.0), "a", NDRange(dim_vec(4)),
                   In(jnp.float32), Out(jnp.float32, as_ref=True),
                   Out(jnp.float32, as_ref=True))
    b = mngr.spawn(lambda x: x * 10.0, "b", NDRange(dim_vec(4)),
                   In(jnp.float32), Out(jnp.float32))
    fused = fuse(system, a, lambda x, y: x + y, b, name="ab")
    x = np.ones(4, np.float32)
    np.testing.assert_allclose(fused.ask(x), 30.0)


def test_split_offload_sweep(mngr):
    """Paper Fig. 7: fraction sweep across two heterogeneous workers."""
    def work(x):
        return x * x

    w1 = mngr.spawn(work, "w1", NDRange(dim_vec(64)),
                    In(jnp.float32), Out(jnp.float32))
    w2 = mngr.spawn(work, "w2", NDRange(dim_vec(64)),
                    In(jnp.float32), Out(jnp.float32))
    data = np.arange(64, dtype=np.float32)

    for frac in [0.0, 0.3, 0.5, 1.0]:
        def sizes_of(fr):
            a = int(64 * fr[0])
            return [a, 64 - a]

        out = split_offload(
            [w1, w2], [frac, 1.0 - frac],
            make_payload=lambda s, n: (data[s:s + n],),
            sizes_of=sizes_of,
            combine=lambda rs: np.concatenate(rs))
        np.testing.assert_allclose(out, data * data)


def test_chunk_scheduler_straggler_and_failure(mngr, system):
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient")
        return x + 1.0

    def steady(x):
        return x + 1.0

    # flaky dies after its first failure (actor semantics) — scheduler must
    # finish all chunks on the surviving worker.
    wf = mngr.spawn(flaky, "flaky", NDRange(dim_vec(4)),
                    In(jnp.float32), Out(jnp.float32))
    ws = mngr.spawn(steady, "steady", NDRange(dim_vec(4)),
                    In(jnp.float32), Out(jnp.float32))
    sched = ChunkScheduler([wf, ws])
    payloads = [(np.full(4, i, np.float32),) for i in range(6)]
    res = sched.run(payloads, timeout=60)
    for i, r in enumerate(res):
        np.testing.assert_allclose(r, i + 1)
    assert sched.stats["failed"] >= 1


def test_chunk_scheduler_speculative_reissue_beats_straggler(system):
    """A deliberately slow straggler must lose to the speculatively
    re-issued copy, and the chunk's result must appear exactly once."""
    def slow(x):
        time.sleep(1.0)          # the straggler: ~1000x the median
        return ("slow", x + 1)

    def fast(x):
        time.sleep(0.001)
        return ("fast", x + 1)

    ws, wf = system.spawn(slow), system.spawn(fast)
    sched = ChunkScheduler([ws, wf], straggler_factor=3.0, drain_grace=3.0)
    res = sched.run([(i,) for i in range(8)], timeout=60)
    # every chunk present exactly once, in order, with the right value —
    # the straggler's late duplicate completion must not double-record
    assert [v for _, v in res] == [i + 1 for i in range(8)]
    # the chunk the slow worker grabbed was re-issued and won by the fast
    # worker; the slow worker contributes no result
    assert all(tag == "fast" for tag, _ in res), res
    assert sched.stats["speculative"] >= 1
    assert sched.stats["dispatched"] >= 9    # 8 fresh + >=1 speculative


def test_chunk_scheduler_elastic_add_remove(mngr):
    w1 = mngr.spawn(lambda x: x, "e1", NDRange(dim_vec(2)),
                    In(jnp.float32), Out(jnp.float32))
    sched = ChunkScheduler([w1])
    w2 = mngr.spawn(lambda x: x, "e2", NDRange(dim_vec(2)),
                    In(jnp.float32), Out(jnp.float32))
    sched.add_worker(w2)
    assert len(sched.workers) == 2
    res = sched.run([(np.full(2, i, np.float32),) for i in range(4)])
    assert len(res) == 4
    sched.remove_worker(w1)
    assert len(sched.workers) == 1
