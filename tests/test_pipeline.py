"""Pipeline-parallelism-from-actors tests (DESIGN.md §4): stage actors
must reproduce the fused forward exactly, overlap across microbatches,
and respect the in-flight depth bound."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import ActorSystem
from repro.dist.pipeline import PipelineRunner, make_layer_stage_actors
from repro.models import Model


@pytest.fixture(scope="module")
def system():
    s = ActorSystem(max_workers=6)
    yield s
    s.shutdown()


def test_stage_actors_match_fused_forward(system):
    cfg = configs.get_smoke_config("llama3-8b")  # 2 layers → 2 stages
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    stages = make_layer_stage_actors(system, model, params, n_stages=2)
    runner = PipelineRunner(system, stages)

    rng = np.random.default_rng(0)
    mbs = [jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
           for _ in range(4)]
    outs = runner.run(mbs)
    for mb, got in zip(mbs, outs):
        want, _ = model.forward(params, {"tokens": mb})
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=2e-4, atol=2e-4)


def test_pipeline_overlaps_stages(system):
    """With M microbatches in flight, different stages must be active
    concurrently — the paper's async event-chain claim."""
    active = []
    lock = threading.Lock()
    overlap_seen = threading.Event()

    def make_stage(i):
        def fn(x):
            with lock:
                active.append(i)
                if len(set(active)) > 1:
                    overlap_seen.set()
            time.sleep(0.03)
            with lock:
                active.remove(i)
            return x + 1
        return fn

    s0 = system.spawn(make_stage(0))
    s1 = system.spawn(make_stage(1))
    runner = PipelineRunner(system, [s0, s1], depth=4)
    outs = runner.run(list(range(8)))
    assert outs == [x + 2 for x in range(8)]
    assert overlap_seen.is_set(), "stages never ran concurrently"


def test_pipeline_depth_bound(system):
    """No more than ``depth`` microbatches may be in flight at once."""
    peak = [0]
    inflight = [0]
    lock = threading.Lock()

    def slow_first(x):
        with lock:
            inflight[0] += 1
            peak[0] = max(peak[0], inflight[0])
        time.sleep(0.02)
        with lock:
            inflight[0] -= 1
        return x

    s0 = system.spawn(slow_first)
    s1 = system.spawn(lambda x: x)
    runner = PipelineRunner(system, [s0, s1], depth=2)
    runner.run(list(range(10)))
    assert peak[0] <= 2, peak[0]


def test_pipeline_propagates_stage_failure(system):
    s0 = system.spawn(lambda x: x)
    bad = system.spawn(lambda x: 1 / 0)
    runner = PipelineRunner(system, [s0, bad])
    with pytest.raises(Exception):
        runner.run([1, 2, 3])
