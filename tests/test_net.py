"""Tests for the network-transparent node layer (``repro.net``).

Most tests run two :class:`NodeRuntime`\\ s **in one process** over a
localhost socket — that exercises the full wire path (framing, spill
boundary, broker, supervision relays, heartbeats) fast. The process-wide
ref registry is shared between such nodes, so counter assertions check
*deltas across both sides*. The ``slow``-marked tests at the bottom use a
real second process (per-process registries, SIGKILL node death).
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (ActorFailed, ActorPool, ActorSystem, ChunkScheduler,
                        DeviceRef, DownMessage, ExitMessage, memory_stats,
                        reset_transfer_stats)
from repro.core.actor import Actor
from repro.net import NodeDown, NodeRuntime, RemoteActorRef, wire


# -- module-level behaviors (spawn_remote pickles by reference) --------------
def remote_triple(x):
    return x * 3


def remote_ref_inc(ref):
    return DeviceRef(ref.array + 1)


@pytest.fixture()
def pair():
    sa = ActorSystem("node-a", max_workers=4)
    sb = ActorSystem("node-b", max_workers=4)
    na = NodeRuntime(sa, name="a", listen=("127.0.0.1", 0),
                     heartbeat_interval=0.2, heartbeat_timeout=2.0)
    nb = NodeRuntime(sb, name="b", heartbeat_interval=0.2,
                     heartbeat_timeout=2.0)
    nb.connect(na.address)
    assert na.wait_for_peer("b", 10)
    yield sa, sb, na, nb
    na.shutdown()
    nb.shutdown()
    sa.shutdown()
    sb.shutdown()


# ----------------------------------------------------------------------------
# wire format
# ----------------------------------------------------------------------------
def test_wire_roundtrip_plain_containers():
    obj = ("tag", [1, 2.5, "s"], {"k": (None, True)}, np.arange(3))
    out = wire.decode(wire.encode(obj))
    assert out[0] == "tag" and out[1] == [1, 2.5, "s"]
    assert out[2]["k"] == (None, True)
    np.testing.assert_array_equal(out[3], np.arange(3))


def test_wire_request_payload_spill_is_a_copy():
    reset_transfer_stats()
    ref = DeviceRef.put(np.arange(8, dtype=np.float32))
    data = wire.encode((ref,))          # request direction: clone
    assert not ref.is_spilled           # sender keeps residency for replay
    out = wire.decode(data)
    np.testing.assert_array_equal(out[0].to_value(), ref.to_value())
    stats = memory_stats()
    assert stats["spills"] == 1 and stats["unspills"] == 1


def test_wire_reply_spill_consumes():
    ref = DeviceRef.put(np.arange(8, dtype=np.float32))
    wire.encode((ref,), consume=True)   # reply direction: ownership moves
    assert ref.is_spilled


def test_wire_already_spilled_ref_travels_without_extra_spill():
    ref = DeviceRef.put(np.arange(8, dtype=np.float32)).spill()
    reset_transfer_stats()
    out = wire.decode(wire.encode((ref,)))
    stats = memory_stats()
    assert stats["spills"] == 0 and stats["unspills"] == 1
    assert not out[0].is_spilled


def test_wire_int8_compression_shrinks_and_bounds_error():
    x = np.random.RandomState(0).randn(2048).astype(np.float32)
    ref = DeviceRef.put(x)
    raw = wire.encoded_size((ref,))
    comp = wire.encoded_size((ref,), compress=True)
    assert comp < raw / 2.5, (raw, comp)
    out = wire.decode(wire.encode((ref,), compress=True))
    got = out[0].to_value()
    assert got.dtype == np.float32
    rel = np.max(np.abs(got - x)) / np.max(np.abs(x))
    assert rel <= 1 / 120


def test_wire_compression_skips_integer_refs():
    ref = DeviceRef.put(np.arange(16, dtype=np.int32))
    out = wire.decode(wire.encode((ref,), compress=True))
    np.testing.assert_array_equal(out[0].to_value(), np.arange(16))
    assert out[0].to_value().dtype == np.int32


# ----------------------------------------------------------------------------
# two nodes, one process: messaging
# ----------------------------------------------------------------------------
def test_remote_lookup_ask(pair):
    sa, sb, na, nb = pair
    nb.publish("double", sb.spawn(lambda x: x * 2))
    ref = na.remote_actor("b", "double")
    assert isinstance(ref, RemoteActorRef)
    assert ref.ask(21) == 42
    assert ref.is_alive()


def test_remote_send_fire_and_forget(pair):
    sa, sb, na, nb = pair
    seen, evt = [], threading.Event()
    nb.publish("sink", sb.spawn(lambda x: (seen.append(x), evt.set())))
    ref = na.remote_actor("b", "sink")
    ref.send("hello")
    assert evt.wait(10)
    assert seen == ["hello"]


def test_remote_spawn_and_publish(pair):
    sa, sb, na, nb = pair
    ref = na.spawn_remote("b", remote_triple, publish="triple")
    assert ref.ask(5) == 15
    again = na.remote_actor("b", "triple")
    assert again.remote_id == ref.remote_id


def test_lookup_unknown_name_raises(pair):
    sa, sb, na, nb = pair
    with pytest.raises(LookupError, match="publishes no actor"):
        na.remote_actor("b", "nope")


def test_remote_ref_hop_spills_once_per_hop(pair):
    sa, sb, na, nb = pair
    nb.publish("inc", sb.spawn(remote_ref_inc))
    ref = na.remote_actor("b", "inc")
    d = DeviceRef.put(np.arange(4, dtype=np.float32))
    reset_transfer_stats()
    out = ref.ask(d)
    # request hop: 1 spill (driver) + 1 unspill (worker); reply hop: 1 + 1.
    # Shared in-process registry → assert the sum over both sides.
    stats = memory_stats()
    assert stats["spills"] == 2 and stats["unspills"] == 2, stats
    assert not d.is_spilled        # request payloads are spill *copies*
    np.testing.assert_array_equal(out.to_value(),
                                  np.arange(4, dtype=np.float32) + 1)


def test_remote_request_failure_propagates(pair):
    sa, sb, na, nb = pair
    nb.publish("bad", sb.spawn(lambda: 1 / 0))
    ref = na.remote_actor("b", "bad")
    with pytest.raises(ZeroDivisionError):
        ref.ask()
    # the runtime-level refusal after death marks the remote dead
    with pytest.raises(ActorFailed):
        ref.ask()
    assert not ref.is_alive()


# ----------------------------------------------------------------------------
# cross-node supervision
# ----------------------------------------------------------------------------
def test_remote_monitor_delivers_down(pair):
    sa, sb, na, nb = pair
    nb.publish("victim", sb.spawn(lambda: 1 / 0))
    ref = na.remote_actor("b", "victim")
    inbox, got = [], threading.Event()
    w = sa.spawn(lambda m: (inbox.append(m), got.set()))
    sa.monitor(w, ref)            # network-transparent dispatch
    ref.send()
    assert got.wait(10)
    assert isinstance(inbox[0], DownMessage)
    assert inbox[0].actor_id == ref.actor_id
    assert isinstance(inbox[0].reason, ZeroDivisionError)
    assert not ref.is_alive()


def test_monitor_already_dead_remote_fires_immediately(pair):
    sa, sb, na, nb = pair
    victim = sb.spawn(lambda x: x)
    nb.publish("gone", victim)
    ref = na.remote_actor("b", "gone")
    victim.exit(None)
    inbox, got = [], threading.Event()
    w = sa.spawn(lambda m: (inbox.append(m), got.set()))
    sa.monitor(w, ref)
    assert got.wait(10)
    assert isinstance(inbox[0], DownMessage)


def test_remote_link_kills_local_on_remote_death(pair):
    sa, sb, na, nb = pair
    nb.publish("victim", sb.spawn(lambda: 1 / 0))
    ref = na.remote_actor("b", "victim")
    local = sa.spawn(lambda x: x)
    sa.link(local, ref)           # dispatches through the remote ref
    ref.send()
    deadline = time.monotonic() + 10
    while local.is_alive() and time.monotonic() < deadline:
        time.sleep(0.02)
    assert not local.is_alive()


def test_remote_link_trapper_receives_exit(pair):
    sa, sb, na, nb = pair

    class Trapper(Actor):
        def __init__(self):
            super().__init__()
            self.trap_exit = True
            self.exits = []
            self.got = threading.Event()

        def receive(self, msg):
            if isinstance(msg, ExitMessage):
                self.exits.append(msg)
                self.got.set()

    nb.publish("victim", sb.spawn(lambda: 1 / 0))
    ref = na.remote_actor("b", "victim")
    trapper = Trapper()
    t = sa.spawn(trapper)
    sa.link(t, ref)
    ref.send()
    assert trapper.got.wait(10)
    assert trapper.exits[0].actor_id == ref.actor_id


def test_remote_link_reverse_kills_remote_on_local_death(pair):
    sa, sb, na, nb = pair
    victim = sb.spawn(lambda x: x)
    nb.publish("v", victim)
    ref = na.remote_actor("b", "v")
    local = sa.spawn(lambda: 1 / 0)
    sa.link(ref, local)
    local.send()
    deadline = time.monotonic() + 10
    while victim.is_alive() and time.monotonic() < deadline:
        time.sleep(0.02)
    assert not victim.is_alive()


# ----------------------------------------------------------------------------
# peer death
# ----------------------------------------------------------------------------
def test_peer_death_fails_pending_and_notifies(pair):
    sa, sb, na, nb = pair
    nb.publish("slow", sb.spawn(lambda x: (time.sleep(5), x)[1]))
    ref = na.remote_actor("b", "slow")
    inbox, got = [], threading.Event()
    w = sa.spawn(lambda m: (inbox.append(m), got.set()))
    sa.monitor(w, ref)
    fut = ref.request(1)
    time.sleep(0.1)
    nb._conns["a"].sock.close()   # abrupt death (simulated crash)
    with pytest.raises(NodeDown):
        fut.result(timeout=10)
    assert got.wait(10)
    assert isinstance(inbox[0], DownMessage)
    assert inbox[0].actor_id == ref.actor_id
    assert isinstance(inbox[0].reason, NodeDown)
    assert not ref.is_alive()
    with pytest.raises(ActorFailed):
        ref.ask(2, timeout=5)


def test_scheduler_reissues_dead_node_chunks_exactly_once(pair):
    sa, sb, na, nb = pair
    nb.publish("w", sb.spawn(lambda i: (time.sleep(0.1), ("remote", i))[1]))
    remote = na.remote_actor("b", "w")
    local = sa.spawn(lambda i: (time.sleep(0.02), ("local", i))[1])
    pool = ActorPool(sa, [local, remote])
    sched = ChunkScheduler(pool, max_attempts=4)
    killer = threading.Timer(0.25, nb._conns["a"].sock.close)
    killer.start()
    try:
        results = sched.run([(i,) for i in range(16)], timeout=60)
    finally:
        killer.cancel()
    assert sorted(i for _, i in results) == list(range(16))
    assert not remote.is_alive()


def test_pool_round_robin_spreads_over_remote_members(pair):
    sa, sb, na, nb = pair
    hits = {"local": 0, "remote": 0}
    nb.publish("w", sb.spawn(lambda x: "remote"))
    remote = na.remote_actor("b", "w")
    local = sa.spawn(lambda x: "local")
    pool = ActorPool(sa, [local, remote], policy="round_robin")
    # payload carries a device-resident ref no member's placement matches:
    # round-robin pools fall back to round-robin, not fake load ranking
    for _ in range(6):
        hits[pool.ask(DeviceRef.put(np.arange(2, dtype=np.float32)))] += 1
    assert hits["local"] == 3 and hits["remote"] == 3, hits


def test_node_shutdown_is_graceful_down(pair):
    sa, sb, na, nb = pair
    nb.publish("x", sb.spawn(lambda v: v))
    ref = na.remote_actor("b", "x")
    inbox, got = [], threading.Event()
    w = sa.spawn(lambda m: (inbox.append(m), got.set()))
    sa.monitor(w, ref)
    nb.shutdown()
    assert got.wait(10)
    assert isinstance(inbox[0], DownMessage)
    assert not ref.is_alive()


# ----------------------------------------------------------------------------
# two real processes (slow job)
# ----------------------------------------------------------------------------
@pytest.mark.slow
def test_two_process_pipeline_demo():
    """The PR's acceptance demo: 3-stage cross-node pipeline with one
    compressed spill/unspill pair per hop asserted on both per-process
    registries, then SIGKILL mid-run → DownMessage + exactly-once."""
    from repro.net import demo
    summary = demo.main(n=1024, chunks=10, compress=True, timeout=120.0)
    assert summary["driver_stats"]["spills"] == 1
    assert summary["worker_stats"]["unspills"] == 1
    assert summary["sources"] >= {"local"}


@pytest.mark.slow
def test_two_process_generic_worker_spawn_remote():
    """A bare ``repro.launch.node`` worker is populated from the driver
    via spawn_remote (behavior pickled by reference)."""
    import multiprocessing as mp

    from repro.launch.node import run_worker

    system = ActorSystem("driver")
    node = NodeRuntime(system, name="driver", listen=("127.0.0.1", 0))
    ctx = mp.get_context("spawn")
    child = ctx.Process(target=run_worker,
                        args=(node.address, "generic"), daemon=True)
    child.start()
    try:
        assert node.wait_for_peer("generic", 120)
        ref = node.spawn_remote("generic", remote_triple, timeout=60)
        assert ref.ask(7, timeout=60) == 21
    finally:
        node.shutdown()
        system.shutdown()
        if child.is_alive():
            child.kill()
        child.join(timeout=30)


# ----------------------------------------------------------------------------
# transport robustness (code-review regressions)
# ----------------------------------------------------------------------------
def test_undecodable_payload_fails_only_that_request(pair):
    """A payload blob the receiver cannot decode (e.g. a __main__-defined
    spawn_remote behavior) must fail its own request with PayloadError —
    not tear down the connection or mark the target actor dead."""
    from repro.net import PayloadError

    sa, sb, na, nb = pair
    nb.publish("ok", sb.spawn(lambda x: x + 1))
    ref = na.remote_actor("b", "ok")
    fut = na._pending_request(
        "b", ref.remote_id,
        lambda rid: ("request", rid, ref.remote_id, b"\x80not-a-pickle"))
    with pytest.raises(PayloadError):
        fut.result(10)
    assert ref.is_alive()            # not marked dead
    assert ref.ask(1, timeout=10) == 2   # connection still healthy


def test_unencodable_request_payload_fails_future_not_caller(pair):
    """A payload that cannot even be encoded locally (function-scoped
    class) fails the returned future instead of raising into the caller
    (the scheduler dispatch path relies on failures surfacing there)."""
    sa, sb, na, nb = pair
    nb.publish("ok", sb.spawn(lambda x: x))
    ref = na.remote_actor("b", "ok")

    class Unpicklable:               # function-scoped: pickle refuses
        pass

    fut = ref.request(Unpicklable())
    with pytest.raises(Exception):
        fut.result(10)
    assert ref.ask(3, timeout=10) == 3


def test_reconnect_clears_stale_death_state(pair):
    """A restarted same-named peer is a fresh incarnation: its actor ids
    restart at 1, so per-actor death state from the dead incarnation must
    not shadow the new one."""
    sa, sb, na, nb = pair
    nb.publish("x", sb.spawn(lambda v: v))
    ref = na.remote_actor("b", "x")
    nb._conns["a"].sock.close()      # incarnation 1 dies
    deadline = time.monotonic() + 10
    while ref.is_alive() and time.monotonic() < deadline:
        time.sleep(0.02)
    assert not ref.is_alive()

    sb2 = ActorSystem("node-b2", max_workers=2)
    nb2 = NodeRuntime(sb2, name="b")   # same node name, new process-alike
    try:
        nb2.connect(na.address)
        assert na.wait_for_peer("b", 10)
        nb2.publish("x", sb2.spawn(lambda v: v * 2))
        ref2 = na.remote_actor("b", "x")
        assert ref2.is_alive()       # would be False with stale _dead_remote
        assert ref2.ask(4, timeout=10) == 8
    finally:
        nb2.shutdown()
        sb2.shutdown()


def test_delegated_failure_does_not_mark_remote_dead(pair):
    """A remote actor that delegates to a dead actor replies ActorFailed
    while staying alive itself — the requester must key death off the
    reply's liveness flag, not the error type."""
    sa, sb, na, nb = pair
    dead_inner = sb.spawn(lambda x: x)
    dead_inner.exit(None)
    forwarder = sb.spawn(lambda x: dead_inner.request(x))
    nb.publish("fw", forwarder)
    ref = na.remote_actor("b", "fw")
    with pytest.raises(ActorFailed):
        ref.ask(1, timeout=10)
    assert forwarder.is_alive()
    assert ref.is_alive()            # healthy replica must not be dropped


def test_wire_compression_preserves_access_rights():
    """The int8 wire path must not widen a restricted view back to rw."""
    ref = DeviceRef.put(np.random.RandomState(1).randn(64)
                        .astype(np.float32)).restrict("r")
    out = wire.decode(wire.encode((ref,), compress=True))
    assert out[0].access == "r"


def test_actor_ref_refuses_pickle():
    """Process-local handles refuse the wire with an actionable message,
    mirroring the DeviceRef explicit-spill policy."""
    import pickle

    s = ActorSystem("pickle-guard", max_workers=2)
    try:
        ref = s.spawn(lambda x: x)
        with pytest.raises(TypeError, match="process-local"):
            pickle.dumps(ref)
    finally:
        s.shutdown()


# ----------------------------------------------------------------------------
# runtime-loop bugfix regressions (ISSUE 8 satellites)
# ----------------------------------------------------------------------------
def test_shutdown_returns_promptly_despite_long_heartbeat_interval():
    """shutdown() must not linger in the heartbeat loop's sleep: the loop
    waits on an Event that shutdown sets, so a node with a 5 s interval
    still leaves in milliseconds (mesh scale-in releases nodes on this
    path, one per replica)."""
    s = ActorSystem("hb-shutdown", max_workers=2)
    node = NodeRuntime(s, name="hb", heartbeat_interval=5.0)
    try:
        time.sleep(0.05)             # heartbeat thread is mid-wait now
        t0 = time.monotonic()
        node.shutdown()
        elapsed = time.monotonic() - t0
        assert elapsed < 0.5, f"shutdown took {elapsed:.2f}s"
        assert not node._hb_thread.is_alive()   # joined, not abandoned
    finally:
        node.shutdown()
        s.shutdown()


def test_peer_stats_timeout_honors_node_config():
    """peer_stats used to hardcode timeout=30.0 (the ActorPool-120s /
    ask-120s class of bug); it now defaults from the node's rpc_timeout
    (itself from the system's default_ask_timeout), and the TimeoutError
    names the unresponsive peer and its last-rx age."""
    from concurrent.futures import TimeoutError as FuturesTimeout

    sa = ActorSystem("rpc-a", max_workers=2)
    sb = ActorSystem("rpc-b", max_workers=2)
    na = NodeRuntime(sa, name="a", listen=("127.0.0.1", 0),
                     rpc_timeout=0.3)
    nb = NodeRuntime(sb, name="b")
    try:
        nb.connect(na.address)
        assert na.wait_for_peer("b", 10)
        nb._on_rpc = lambda *a, **k: None     # b goes mute on rpcs
        t0 = time.monotonic()
        with pytest.raises(FuturesTimeout) as ei:
            na.peer_stats("b")
        elapsed = time.monotonic() - t0
        assert elapsed < 5.0, f"configured 0.3s timeout took {elapsed:.2f}s"
        msg = str(ei.value)
        assert "'b'" in msg and "last rx" in msg and "0.3" in msg, msg
        # an explicit per-call timeout still overrides the node default
        t0 = time.monotonic()
        with pytest.raises(FuturesTimeout):
            na.peer_stats("b", timeout=0.1)
        assert time.monotonic() - t0 < 5.0
    finally:
        na.shutdown()
        nb.shutdown()
        sa.shutdown()
        sb.shutdown()


def test_rpc_timeout_inherits_system_default_ask_timeout():
    s = ActorSystem("rpc-default", max_workers=2, default_ask_timeout=7.5)
    node = NodeRuntime(s, name="n")
    try:
        assert node.rpc_timeout == 7.5
    finally:
        node.shutdown()
        s.shutdown()


def test_stats_provider_merges_and_survives_broken_provider(pair):
    sa, sb, na, nb = pair
    nb.add_stats_provider("good", lambda: {"v": 1})
    nb.add_stats_provider("bad", lambda: 1 / 0)
    snap = na.peer_stats("b", timeout=30)
    assert snap["good"] == {"v": 1}
    assert "error" in snap["bad"]          # one broken provider is isolated
    assert "spills" in snap                # base memory_stats still present
