"""Tests for the declarative v2 kernel-actor API: @kernel declaration
capture, Pipeline staged/fused/auto equivalence, pool routing, and the
v1 shim compatibility (ISSUE 1 acceptance surface)."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ActorPool, ActorSystem, ChunkScheduler, In, KernelDecl,
                        NDRange, Out, Pipeline, compose, dim_vec, fuse, kernel)


@pytest.fixture(scope="module")
def system():
    s = ActorSystem(max_workers=6)
    yield s
    s.shutdown()


@pytest.fixture(scope="module")
def mngr(system):
    return system.opencl_manager()


N = 16


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)),
        name="add_one")
def add_one(x):
    return x + 1.0


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)))
def double(x):
    return x * 2.0


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)),
        name="sub_three")
def sub_three(x):
    return x - 3.0


# ----------------------------------------------------------------------------
# @kernel declaration capture
# ----------------------------------------------------------------------------
def test_kernel_decorator_captures_signature():
    assert isinstance(add_one, KernelDecl)
    assert add_one.name == "add_one"
    assert double.name == "double"          # defaults to fn.__name__
    assert add_one.nd_range == NDRange(dim_vec(N))
    assert len(add_one.signature.input_specs) == 1
    assert len(add_one.signature.output_specs) == 1
    # still directly callable (undecorated behavior)
    np.testing.assert_allclose(np.asarray(add_one(jnp.zeros(4))), 1.0)


def test_kernel_with_options_is_a_copy():
    wider = add_one.with_options(nd_range=NDRange(dim_vec(64)))
    assert wider.nd_range == NDRange(dim_vec(64))
    assert add_one.nd_range == NDRange(dim_vec(N))  # original untouched
    assert wider.fn is add_one.fn
    with pytest.raises(TypeError):
        add_one.with_options(bogus=1)


def test_spawn_decorated_kernel_from_system(system):
    worker = system.spawn(add_one)
    x = np.arange(N, dtype=np.float32)
    np.testing.assert_allclose(worker.ask(x), x + 1)


def test_spawn_decorated_kernel_from_manager_with_overrides(system, mngr):
    dev = mngr.find_device()
    worker = mngr.spawn(double, device=dev)
    x = np.arange(N, dtype=np.float32)
    np.testing.assert_allclose(worker.ask(x), x * 2)


def test_spawn_rejects_unknown_kwargs(mngr):
    with pytest.raises(TypeError):
        mngr.spawn(add_one, bogus_option=1)


# ----------------------------------------------------------------------------
# Pipeline: staged / fused / auto equivalence (acceptance criterion)
# ----------------------------------------------------------------------------
def _expected(x):
    return (x + 1) * 2 - 3


def test_pipeline_modes_agree_on_three_stage_chain(system):
    x = np.arange(N, dtype=np.float32)
    staged = (Pipeline(system, mode="staged")
              .stage(add_one).stage(double).stage(sub_three).build())
    fused = (Pipeline(system, mode="fused")
             .stage(add_one).stage(double).stage(sub_three).build())
    auto = (Pipeline(system, mode="auto")
            .stage(add_one).stage(double).stage(sub_three).build())
    r_staged, r_fused, r_auto = staged.ask(x), fused.ask(x), auto.ask(x)
    np.testing.assert_allclose(r_staged, _expected(x))
    np.testing.assert_array_equal(np.asarray(r_staged), np.asarray(r_fused))
    np.testing.assert_array_equal(np.asarray(r_staged), np.asarray(r_auto))


def test_pipeline_auto_resolution(system):
    all_kernels = (Pipeline(system, mode="auto")
                   .stage(add_one).stage(double))
    assert all_kernels.resolved_mode() == "fused"

    opaque = system.spawn(lambda x: x + 1)  # plain actor: not traceable
    mixed = Pipeline(system, mode="auto").stage(opaque).stage(double)
    assert mixed.resolved_mode() == "staged"
    x = np.arange(N, dtype=np.float32)
    np.testing.assert_allclose(mixed.build().ask(x), (x + 1) * 2)


def test_pipeline_with_adapter_callable(system):
    """Bare callables act as traceable adapters between kernel stages."""
    pipe = (Pipeline(system, mode="fused")
            .stage(add_one).stage(lambda x: x * 10.0).stage(double).build())
    x = np.ones(N, np.float32)
    np.testing.assert_allclose(pipe.ask(x), (x + 1) * 10 * 2)


def test_pipeline_accepts_existing_kernel_actor_refs(system):
    a = system.spawn(add_one)
    d = system.spawn(double)
    for mode in ("staged", "fused", "auto"):
        pipe = Pipeline(system, mode=mode).stages([a, d]).build()
        x = np.arange(N, dtype=np.float32)
        np.testing.assert_allclose(pipe.ask(x), (x + 1) * 2)


def test_pipeline_empty_or_bad_stage_raises(system):
    with pytest.raises(ValueError):
        Pipeline(system).build()
    with pytest.raises(TypeError):
        Pipeline(system).stage(42)
    with pytest.raises(ValueError):
        Pipeline(system, mode="bogus")


# ----------------------------------------------------------------------------
# v1 shims stay equivalent to the v2 builder
# ----------------------------------------------------------------------------
def test_v1_shims_match_pipeline(system):
    a = system.spawn(add_one)
    d = system.spawn(double)
    x = np.arange(N, dtype=np.float32)
    composed = compose(system, a, d)          # staged shim
    fused = fuse(system, a, d, name="f2")     # fused shim
    infix = d * a                             # paper's Listing 5 form
    np.testing.assert_allclose(composed.ask(x), (x + 1) * 2)
    np.testing.assert_allclose(fused.ask(x), (x + 1) * 2)
    np.testing.assert_allclose(infix.ask(x), (x + 1) * 2)


# ----------------------------------------------------------------------------
# pools
# ----------------------------------------------------------------------------
def test_spawn_pool_round_robin_and_scheduler(system, mngr):
    pool = mngr.spawn_pool(add_one, 3, policy="round_robin")
    assert len(pool.workers) == 3
    x = np.arange(N, dtype=np.float32)
    np.testing.assert_allclose(pool.ask(x), x + 1)
    # plugs into ChunkScheduler (pull-based balancing over the replicas)
    payloads = [(np.full(N, i, np.float32),) for i in range(9)]
    res = ChunkScheduler(pool).run(payloads, timeout=60)
    for i, r in enumerate(res):
        np.testing.assert_allclose(r, i + 1)
    # pool.map is the one-call version of the same thing
    res2 = pool.map(payloads, timeout=60)
    for i, r in enumerate(res2):
        np.testing.assert_allclose(r, i + 1)


def test_pool_round_robin_cycles_workers(system):
    counts = [0, 0, 0]

    def make(i):
        def fn(x):
            counts[i] += 1
            return x
        return fn

    pool = ActorPool(system, [system.spawn(make(i)) for i in range(3)],
                     policy="round_robin")
    for i in range(9):
        pool.ask(i)
    assert counts == [3, 3, 3]


def test_pool_least_loaded_routes_around_slow_worker(system):
    """Under unequal worker speeds the load-aware policy must push most
    of the work to the fast replica (the backed-up one stops winning)."""
    counts = {"slow": 0, "fast": 0}
    lock = threading.Lock()

    def slow(x):
        with lock:
            counts["slow"] += 1
        time.sleep(0.05)
        return x

    def fast(x):
        with lock:
            counts["fast"] += 1
        time.sleep(0.001)
        return x

    pool = ActorPool(system, [system.spawn(slow), system.spawn(fast)],
                     policy="least_loaded")
    futs = []
    for i in range(30):
        futs.append(pool.request(i))
        time.sleep(0.002)
    for f in futs:
        f.result(30)
    assert counts["slow"] + counts["fast"] == 30
    assert counts["fast"] > counts["slow"], counts


def test_pool_outstanding_consistent_under_hammer(system):
    """Regression for the _pick/outstanding race: 8 threads hammering a
    4-worker pool must never lose or double-count an outstanding slot —
    the decrement runs in the done-callback under the pool lock."""
    pool = ActorPool(system, [system.spawn(lambda x: x + 1)
                              for _ in range(4)], policy="least_loaded")
    errors = []

    def hammer():
        try:
            for i in range(50):
                assert pool.ask(i, timeout=30) == i + 1
        except Exception as e:      # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    deadline = time.monotonic() + 5
    while time.monotonic() < deadline:
        counts = [pool.outstanding(w) for w in pool.workers]
        if all(c == 0 for c in counts):
            break
        time.sleep(0.01)
    assert all(c == 0 for c in counts), counts


def test_v1_compose_fuse_emit_deprecation_warning(system):
    a = system.spawn(add_one)
    d = system.spawn(double)
    with pytest.warns(DeprecationWarning, match="compose"):
        compose(system, a, d)
    with pytest.warns(DeprecationWarning, match="fuse"):
        fuse(system, a, d, name="dep")


def test_pool_survives_dead_worker(system):
    def bad(x):
        raise RuntimeError("boom")

    good = system.spawn(lambda x: x + 1)
    dead = system.spawn(bad)
    pool = ActorPool(system, [dead, good], policy="round_robin")
    with pytest.raises(RuntimeError):
        pool.ask(0)          # routed to the bad worker, which dies
    # every subsequent message lands on the survivor
    assert [pool.ask(i) for i in range(4)] == [1, 2, 3, 4]
    assert pool.is_alive()
