"""Tests for the unified cost-model scheduler (``repro.core.placement``).

Everything placement-related is testable here with *fake cost tables*:
duck-typed devices expose ``live_bytes()``/``queue_depth()``/``name``,
``WireCostModel`` instances are built with crafted latency/throughput so
raw vs int8 outcomes are deterministic, and ``NodeTarget`` only needs an
object with a ``compress`` attribute until a spawn actually happens. The
final section swaps the process-wide service (``set_placement_service``)
and places a real graph across two in-process ``NodeRuntime``\\ s,
asserting a cross-node edge is chosen exactly when the wire model says
int8 compression amortizes the hop.
"""
import gc
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ActorSystem, DeviceRef, Graph, In, NDRange, Out,
                        dim_vec, kernel, payload_nbytes, placement_service,
                        set_placement_service)
from repro.core.placement import (GraphSite, NodeTarget, PlacementDecision,
                                  PlacementService, WireCostModel)
from repro.net import NodeRuntime


# ----------------------------------------------------------------------------
# fakes
# ----------------------------------------------------------------------------
class FakeDev:
    """Duck-typed stand-in for :class:`repro.core.manager.Device`."""

    def __init__(self, name, live=0, queue=0, jax_device=None):
        self.name = name
        self._live = live
        self._queue = queue
        self.jax_device = jax_device

    def live_bytes(self):
        return self._live

    def queue_depth(self):
        return self._queue

    def __repr__(self):
        return f"FakeDev({self.name})"


class FakeNode:
    """Just enough node for a NodeTarget that never spawns."""

    def __init__(self, compress=False):
        self.compress = compress


def svc(**kw):
    kw.setdefault("audit", 64)
    return PlacementService(**kw)


# ----------------------------------------------------------------------------
# WireCostModel
# ----------------------------------------------------------------------------
BENCH = {"sizes": {
    "n1024": {"local_hop_us": 310.0, "remote_hop_us": 4631.4,
              "wire_raw_bytes": 4284, "wire_int8_bytes": 1308,
              "compression_ratio": 3.3},
    "n262144": {"local_hop_us": 600.0, "remote_hop_us": 14654.4,
                "wire_raw_bytes": 1048777, "wire_int8_bytes": 262345,
                "compression_ratio": 4.0},
}}


def test_wire_model_from_bench_pins_latency_and_throughput():
    m = WireCostModel.from_bench(BENCH)
    assert m.latency_s == pytest.approx(4631.4e-6)
    span_s = (14654.4 - 4631.4) * 1e-6
    assert m.bytes_per_s == pytest.approx((1048777 - 4284) / span_s)
    assert m.int8_ratio == 4.0


def test_wire_model_from_bench_file_and_overrides(tmp_path):
    import json
    p = tmp_path / "bench.json"
    p.write_text(json.dumps(BENCH))
    m = WireCostModel.from_bench(str(p), int8_ratio=2.0)
    assert m.int8_ratio == 2.0
    assert m.latency_s == pytest.approx(4631.4e-6)


def test_wire_model_hop_and_roundtrip_prefer_int8_when_amortized():
    # slow wire, cheap compression: int8 must win when allowed
    m = WireCostModel(latency_s=1e-3, bytes_per_s=1e6, int8_ratio=4.0,
                      compress_overhead_s=1e-5, compress_bytes_per_s=1e9)
    n = 1 << 20
    assert m.hop_seconds(n, compressed=True) < m.hop_seconds(n)
    assert m.amortizes(n)
    s, enc = m.round_trip_seconds(n, n, allow_compress=True)
    assert enc == "int8"
    raw_s, raw_enc = m.round_trip_seconds(n, n, allow_compress=False)
    assert raw_enc == "raw" and s < raw_s


def test_wire_model_fast_wire_keeps_raw():
    # wire so fast the quantize pass never pays for itself
    m = WireCostModel(latency_s=1e-6, bytes_per_s=1e12,
                      compress_overhead_s=1e-2)
    _, enc = m.round_trip_seconds(1 << 20, 1 << 20, allow_compress=True)
    assert enc == "raw"
    assert not m.amortizes(1 << 20)


def test_wire_model_choose_compress_respects_min_bytes():
    m = WireCostModel(latency_s=1e-3, bytes_per_s=1e6,
                      compress_overhead_s=1e-5, min_compress_bytes=4096)
    assert not m.choose_compress(1024)      # below the floor
    assert m.choose_compress(1 << 20)


def test_wire_model_observe_small_updates_latency_large_updates_rate():
    m = WireCostModel(latency_s=1e-3, bytes_per_s=1e6, alpha=0.5)
    m.observe(512, 3e-3)                    # latency probe
    assert m.latency_s == pytest.approx(2e-3)
    rate0 = m.bytes_per_s
    m.observe(1 << 20, 2.0)                 # throughput sample
    assert m.bytes_per_s != rate0
    assert m.observations == 2


def test_wire_model_observe_per_peer_cells():
    m = WireCostModel(latency_s=1e-3, bytes_per_s=1e6, alpha=1.0)
    m.observe(256, 0.5, peer="slow")
    m.observe(256, 0.002, peer="fast")
    # per-peer cells diverge even though both fold into the global EWMA
    assert m.hop_seconds(256, peer="slow") > m.hop_seconds(256, peer="fast")
    snap = m.snapshot()
    assert snap["peers"]["slow"]["latency_s"] == pytest.approx(0.5)
    assert snap["peers"]["fast"]["latency_s"] == pytest.approx(0.002)


# ----------------------------------------------------------------------------
# rank(): the ActorPool query
# ----------------------------------------------------------------------------
def test_rank_least_loaded_orders_by_outstanding_then_queue_then_live():
    s = svc()
    cands = [("w0", FakeDev("d0", live=100, queue=5)),
             ("w1", FakeDev("d1", live=999, queue=0)),
             ("w2", FakeDev("d2", live=0, queue=0))]
    d = s.rank(cands, outstanding={"w0": 0, "w1": 0, "w2": 3})
    assert d.chosen == "w1"                 # w2 loses on outstanding
    assert d.reason == "least-loaded"
    d = s.rank(cands, outstanding={"w1": 1})
    assert d.chosen == "w2"


def test_rank_tie_keeps_candidate_order():
    s = svc()
    cands = [("a", FakeDev("d", 0, 0)), ("b", FakeDev("d", 0, 0))]
    assert s.rank(cands).chosen == "a"      # first-wins, like the old min()


def test_rank_residency_prefers_payload_device():
    s = svc()
    ref = DeviceRef(jnp.arange(8.0))
    try:
        home = FakeDev("home", live=10**9, queue=99, jax_device=ref.device)
        away = FakeDev("away", live=0, queue=0, jax_device=None)
        d = s.rank([("away", away), ("home", home)], payload=(ref,))
        # resident worker wins despite being far more loaded
        assert d.chosen == "home" and d.reason == "residency"
        assert d.terms["resident"] is True
    finally:
        ref.release()


def test_rank_round_robin_ticks_only_on_fallback():
    s = svc()
    ticks = itertools.count()
    cands = [("a", FakeDev("d0")), ("b", FakeDev("d1")), ("c", FakeDev("d2"))]
    picks = [s.rank(cands, policy="round_robin",
                    rr_tick=lambda: next(ticks)).chosen for _ in range(4)]
    assert picks == ["a", "b", "c", "a"]
    assert next(ticks) == 4                 # one tick per pick, no extras
    # a residency match must NOT consume a tick
    ref = DeviceRef(jnp.arange(4.0))
    try:
        resident = [("res", FakeDev("dr", jax_device=ref.device))]
        d = s.rank(resident, payload=(ref,), policy="round_robin",
                   rr_tick=lambda: next(ticks))
        assert d.chosen == "res"
        assert next(ticks) == 5             # counter untouched by rank()
    finally:
        ref.release()


def test_rank_decision_records_all_alternatives():
    s = svc()
    cands = [("w0", FakeDev("d0", live=5)), ("w1", FakeDev("d1", live=0))]
    d = s.rank(cands, context="pool:test")
    assert isinstance(d, PlacementDecision)
    assert {a.target for a in d.alternatives} == {"w0", "w1"}
    loser = next(a for a in d.alternatives if a.target == "w0")
    assert loser.terms["live_bytes"] == 5   # loser's terms reconstructible
    assert "pool:test" in d.explain()
    assert s.decisions("pool")[-1] is d


def test_rank_empty_candidates_raises():
    with pytest.raises(ValueError):
        svc().rank([])


# ----------------------------------------------------------------------------
# pick_device: deterministic tie-break (satellite fix)
# ----------------------------------------------------------------------------
def test_pick_device_name_tiebreak_is_deterministic():
    s = svc()
    # equal load in both orders: the *name* must decide, not list order
    for order in ([FakeDev("zz"), FakeDev("aa")],
                  [FakeDev("aa"), FakeDev("zz")]):
        assert s.pick_device(order).chosen.name == "aa"


def test_pick_device_load_beats_name():
    s = svc()
    d = s.pick_device([FakeDev("aa", live=100), FakeDev("zz", live=0)])
    assert d.chosen.name == "zz"
    assert d.terms == {"live_bytes": 0, "queue_depth": 0}


def test_pick_device_empty_raises():
    with pytest.raises(LookupError):
        svc().pick_device([])


# ----------------------------------------------------------------------------
# classify_chunks: the ChunkScheduler query
# ----------------------------------------------------------------------------
def test_classify_chunks_partitions_by_residency():
    s = svc()
    ref = DeviceRef(jnp.arange(8.0))
    try:
        payloads = [(ref,), ("opaque",), (np.arange(3),)]
        local, neutral = s.classify_chunks(payloads, ref.device)
        assert local == [0]
        assert neutral == [1, 2]
        # a worker on some other device sees no local chunks
        local, neutral = s.classify_chunks(payloads, None)
        assert local == [] and neutral == [1, 2]
    finally:
        ref.release()


# ----------------------------------------------------------------------------
# rank_replicas: the MeshRouter query
# ----------------------------------------------------------------------------
def test_rank_replicas_least_expected_wait():
    s = svc()
    d = s.rank_replicas([("r0", 0.5, 0), ("r1", 0.1, 1), ("r2", 0.1, 0)])
    assert d.chosen == "r2"
    assert d.reason == "least-expected-wait"
    assert len(d.alternatives) == 3
    # ties keep snapshot order
    assert s.rank_replicas([("x", 0.1, 0), ("y", 0.1, 0)]).chosen == "x"


def test_observe_replica_feeds_peer_load_into_graph_placement():
    s = svc()
    s.observe_replica("rep-1", wait_s=3.0, inflight=1, peer="b",
                      load={"queue_depth": 7})
    assert s.peer_load_s("b") == pytest.approx((3.0 + 1e-3) * 2)
    assert s.replica_load()["rep-1"]["queue_depth"] == 7
    # a loaded peer loses a hop it would otherwise win
    s.wire = WireCostModel(latency_s=1e-6, bytes_per_s=1e12)
    site = GraphSite(idx=0, path="g/k", in_bytes=1024, out_bytes=1024,
                     remote_ok=True)
    placements, _ = s.place_graph([site], [FakeDev("local")],
                                  remotes=[NodeTarget(FakeNode(), "b")])
    assert placements[0].name == "local"


def test_observe_hop_refines_wire_model():
    s = svc(wire=WireCostModel(latency_s=1e-3, alpha=0.5))
    s.observe_hop("b", 256, 5e-3)
    assert s.wire.observations == 1
    assert s.wire.snapshot()["peers"]["b"]["latency_s"] > 1e-3
    assert s.choose_compress(64, "b") is False   # below min_compress_bytes


# ----------------------------------------------------------------------------
# place_graph against fake cost tables
# ----------------------------------------------------------------------------
def _chain_sites(**kw):
    """source-fed kernel chain: k0 -> k1 (k1 inherits from k0)."""
    return [GraphSite(idx=1, path="g/k0", in_bytes=4096, out_bytes=4096,
                      remote_ok=True, **kw),
            GraphSite(idx=2, path="g/k1", producers=(1,), in_bytes=4096,
                      out_bytes=4096, remote_ok=True)]


def test_place_graph_local_only_inherits_upstream():
    s = svc()
    devs = [FakeDev("d0", live=50), FakeDev("d1", live=0)]
    placements, decisions = s.place_graph(_chain_sites(), devs)
    assert placements[1].name == "d1"       # least loaded
    assert placements[2].name == "d1"       # inherited, zero-move
    assert decisions[1].terms["reason"] == "inherit-upstream"


def test_place_graph_fallback_name_tiebreak():
    s = svc()
    devs = [FakeDev("zz"), FakeDev("aa")]   # equal load, adversarial order
    placements, _ = s.place_graph(
        [GraphSite(idx=0, path="g/k")], devs)
    assert placements[0].name == "aa"


def test_place_graph_pinned_and_fixed_sites():
    s = svc()
    pin = FakeDev("pinned")
    sites = [GraphSite(idx=0, path="g/pin", pinned=pin),
             GraphSite(idx=1, path="g/actor", fixed=True)]
    placements, decisions = s.place_graph(sites, [FakeDev("other")])
    assert placements[0] is pin
    assert decisions[0].reason == "explicit"
    assert 1 not in placements              # existing actor: left alone


def test_place_graph_cheap_wire_goes_remote():
    s = svc(mem_s_per_byte=1e-6)            # local pressure is expensive
    devs = [FakeDev("d0", live=10**7)]      # 10 s of modeled local cost
    s.wire = WireCostModel(latency_s=1e-4, bytes_per_s=1e9)
    target = NodeTarget(FakeNode(), "b")
    placements, decisions = s.place_graph(_chain_sites(), devs,
                                          remotes=[target])
    assert placements[1] is target
    assert decisions[0].reason == "wire-amortized:raw"
    # the losing local device is in the audit record
    assert any(a.target == "d0" for a in decisions[0].alternatives)


def test_place_graph_expensive_wire_stays_local():
    s = svc(mem_s_per_byte=1e-6)
    devs = [FakeDev("d0", live=10**7)]
    s.wire = WireCostModel(latency_s=1e3, bytes_per_s=1e6)  # 1000 s hops
    placements, decisions = s.place_graph(
        _chain_sites(), devs, remotes=[NodeTarget(FakeNode(), "b")])
    assert placements[1].name == "d0"
    # the rejected hop is still auditable
    remote_alt = next(a for a in decisions[0].alternatives
                      if a.target == "node:b")
    assert remote_alt.cost > decisions[0].cost


def test_place_graph_int8_amortization_decides_the_hop():
    """The acceptance shape: raw round trip costs MORE than local, int8
    costs LESS — so the cross-node edge is chosen iff the target's node
    allows compression."""
    nbytes = 1 << 20
    site = GraphSite(idx=0, path="g/k", in_bytes=nbytes, out_bytes=nbytes,
                     remote_ok=True)
    # raw round trip: 2*(0.1 + 1M/4e6)s ~ 0.72s; int8: 2*(0.1+0.25M/4e6+
    # ~0.001)s ~ 0.33s; local modeled cost pinned between the two
    wire = WireCostModel(latency_s=0.1, bytes_per_s=4e6, int8_ratio=4.0,
                         compress_overhead_s=1e-3,
                         compress_bytes_per_s=1e9, envelope_bytes=0)
    local = FakeDev("d0", live=5 * 10**5)
    raw_s, _ = wire.round_trip_seconds(nbytes, nbytes)
    int8_s, enc = wire.round_trip_seconds(nbytes, nbytes,
                                          allow_compress=True)
    s = svc(wire=wire, mem_s_per_byte=1e-6)
    local_s = local.live_bytes() * s.mem_s_per_byte
    assert int8_s < local_s < raw_s and enc == "int8"   # the setup holds

    plain = NodeTarget(FakeNode(compress=False), "plain")
    compressing = NodeTarget(FakeNode(compress="auto"), "zipped")
    placements, decisions = s.place_graph([site], [local], remotes=[plain])
    assert placements[0] is local           # raw hop never amortizes
    placements, decisions = s.place_graph([site], [local],
                                          remotes=[compressing])
    assert placements[0] is compressing     # int8 does
    assert decisions[0].reason == "wire-amortized:int8"
    assert decisions[0].terms["encoding"] == "int8"
    # audit: both the local device and the hop were scored
    assert {a.target for a in decisions[0].alternatives} >= \
        {"d0", "node:zipped"}


def test_place_graph_untyped_edges_never_remote():
    s = svc(mem_s_per_byte=1e-3)
    s.wire = WireCostModel(latency_s=1e-9, bytes_per_s=1e15)  # free hops
    devs = [FakeDev("d0", live=10**9)]
    sites = [GraphSite(idx=0, path="g/untyped", in_bytes=None,
                       out_bytes=4096, remote_ok=True),
             GraphSite(idx=1, path="g/noremote", in_bytes=4096,
                       out_bytes=4096, remote_ok=False)]
    placements, _ = s.place_graph(sites, devs,
                                  remotes=[NodeTarget(FakeNode(), "b")])
    assert placements[0].name == "d0"
    assert placements[1].name == "d0"


def test_place_graph_remote_never_inherited_downstream():
    """A node fed by a remotely placed producer does not 'inherit' the
    NodeTarget — inheritance is a zero-copy argument, which only holds
    for local devices."""
    s = svc(mem_s_per_byte=1e-6)
    devs = [FakeDev("d0", live=10**7)]
    s.wire = WireCostModel(latency_s=1e-4, bytes_per_s=1e9)
    target = NodeTarget(FakeNode(), "b")
    sites = [GraphSite(idx=0, path="g/k0", in_bytes=4096, out_bytes=4096,
                       remote_ok=True),
             GraphSite(idx=1, path="g/k1", producers=(0,))]  # untyped
    placements, decisions = s.place_graph(sites, devs, remotes=[target])
    assert placements[0] is target
    assert placements[1].name == "d0"
    assert decisions[1].terms["reason"] == "least-loaded"


def test_decisions_ring_filters_and_clears():
    s = svc(audit=4)
    s.pick_device([FakeDev("a")], context="serve-engine")
    s.rank([("w", FakeDev("a"))], context="pool:least_loaded")
    assert len(s.decisions()) == 2
    assert [d.context for d in s.decisions("pool")] == ["pool:least_loaded"]
    for _ in range(10):                     # ring is bounded
        s.pick_device([FakeDev("a")])
    assert len(s.decisions()) == 4
    s.clear_decisions()
    assert s.decisions() == []


def test_payload_nbytes_walks_containers():
    ref = DeviceRef(jnp.arange(16.0))       # 64 bytes f32
    try:
        assert payload_nbytes((ref,)) == 64
        assert payload_nbytes(((ref, [np.zeros(4, np.float32)]),
                               {"k": "opaque"})) == 64 + 16
        assert payload_nbytes(("a", 3, None)) == 0
    finally:
        ref.release()


# ----------------------------------------------------------------------------
# end to end: a Graph placed across two in-process nodes
# ----------------------------------------------------------------------------
N = 64


# the decl must wrap a function that is still reachable by reference
# (spawn_remote pickles the declaration, and pickle resolves the wrapped
# function through its module attribute — which the decorator form shadows)
def _scale_impl(x):
    return x * 2.0


p_scale = kernel(In(jnp.float32), Out(jnp.float32),
                 nd_range=NDRange(dim_vec(N)), name="p_scale")(_scale_impl)


@pytest.fixture()
def node_pair():
    sa = ActorSystem("place-a", max_workers=4)
    sb = ActorSystem("place-b", max_workers=4)
    na = NodeRuntime(sa, name="a", listen=("127.0.0.1", 0),
                     heartbeat_interval=0.2, heartbeat_timeout=2.0,
                     compress="auto")
    nb = NodeRuntime(sb, name="b", heartbeat_interval=0.2,
                     heartbeat_timeout=2.0)
    nb.connect(na.address)
    assert na.wait_for_peer("b", 10)
    yield sa, sb, na, nb
    na.shutdown()
    nb.shutdown()
    sa.shutdown()
    sb.shutdown()


def _scale_graph(system, name):
    g = Graph(system, name=name)
    x = g.source("x", jnp.float32, shape=(N,))
    g.output(g.apply(p_scale, x))
    return g


def test_graph_cross_node_edge_only_when_amortized(node_pair):
    """Acceptance: the same graph over the same node pair goes remote
    under a wire model where int8 amortizes the hop, and stays local
    under one where it doesn't — with the audit trail proving why."""
    sa, sb, na, nb = node_pair
    ballast = DeviceRef(jnp.zeros(1 << 18, jnp.float32))  # 1 MiB live
    x = np.arange(N, dtype=np.float32)

    cheap = PlacementService(
        wire=WireCostModel(latency_s=1e-6, bytes_per_s=1e12,
                           compress_overhead_s=0.0, min_compress_bytes=1),
        mem_s_per_byte=1e-3)                # >= ~1000 s modeled local cost
    dear = PlacementService(
        wire=WireCostModel(latency_s=1e6, bytes_per_s=1.0))
    prev = set_placement_service(cheap)
    try:
        target = NodeTarget(na, "b")
        remote_before = len(sb._actors)
        built = _scale_graph(sa, "xnode").build(remotes=[target])
        assert built.placements["xnode/p_scale"] is target
        assert len(sb._actors) == remote_before + 1    # spawned on the peer
        np.testing.assert_allclose(built.ask(x), x * 2.0, rtol=1e-6)
        dec = built.placement_decisions[0]
        assert dec.reason.startswith("wire-amortized")
        assert any(a.target == "node:b" for a in dec.alternatives)

        # identical graph, punitive wire: stays local, hop still audited
        set_placement_service(dear)
        built2 = _scale_graph(sa, "local").build(remotes=[target])
        placed = built2.placements["local/p_scale"]
        assert not isinstance(placed, NodeTarget)
        np.testing.assert_allclose(built2.ask(x), x * 2.0, rtol=1e-6)
        dec2 = built2.placement_decisions[0]
        assert dec2.reason in ("least-loaded", "inherit-upstream")
        rejected = next(a for a in dec2.alternatives
                        if a.target == "node:b")
        assert rejected.cost > dec2.cost
    finally:
        set_placement_service(prev)
        ballast.release()
        gc.collect()


def test_default_service_is_process_wide():
    a = placement_service()
    assert a is placement_service()
    assert isinstance(a, PlacementService)
    assert isinstance(a.wire, WireCostModel)
