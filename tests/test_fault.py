"""Fault-tolerance tests: supervised recovery is bit-exact; elastic DP
re-splits over survivors; int8-compressed all-reduce (multi-device via
subprocess so the 512-device XLA flag never leaks into this process)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import ActorSystem
from repro.data import SyntheticLM
from repro.dist import fault, step as step_mod
from repro.models import Model
from repro.optim import AdamWConfig


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    model = Model(cfg)
    ocfg = AdamWConfig(lr=5e-3, weight_decay=0.0)
    data = SyntheticLM(cfg, batch=4, seq=16, seed=9)
    tstep = jax.jit(step_mod.build_train_step(model, ocfg))
    return cfg, model, ocfg, data, tstep


def _params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_recovery_is_bit_exact(setup, tmp_path):
    cfg, model, ocfg, data, tstep = setup
    total = 8

    def fresh_state():
        return step_mod.init_train_state(model, jax.random.key(0), ocfg)

    with ActorSystem() as sys_a:
        trainer = fault.RecoverableTrainer(
            sys_a, tstep, fresh_state(), data, str(tmp_path / "a"),
            ckpt_every=2)
        state_plain = trainer.run(total)
        assert trainer.recoveries == 0

    with ActorSystem() as sys_b:
        trainer = fault.RecoverableTrainer(
            sys_b, tstep, fresh_state(), data, str(tmp_path / "b"),
            ckpt_every=2)
        state_faulted = trainer.run(total, fail_at=5)
        assert trainer.recoveries == 1

    assert int(state_plain["step"]) == int(state_faulted["step"]) == total
    _params_equal(state_plain["params"], state_faulted["params"])


def test_elastic_dp_resplits_on_death(setup):
    cfg, model, ocfg, data, _ = setup
    params = model.init(jax.random.key(1))

    def grad_fn(p, batch):
        return jax.value_and_grad(lambda q: model.loss(q, batch)[0])(p)

    grad_fn = jax.jit(grad_fn)
    with ActorSystem() as system:
        driver = fault.ElasticDPDriver(system, grad_fn, n_workers=4,
                                       fail_at={2: 1})  # worker 2 dies @ step 1
        batch0 = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        loss0, grads0, used0 = driver.step(params, 0, batch0)
        assert used0 == 4
        batch1 = {k: jnp.asarray(v) for k, v in data.batch_at(1).items()}
        loss1, grads1, used1 = driver.step(params, 1, batch1)
        assert used1 == 3  # re-split over survivors

        # elastic result must equal the single-worker ground truth
        l_ref, g_ref = grad_fn(params, batch1)
        np.testing.assert_allclose(loss1, float(l_ref), rtol=1e-5)
        for a, b in zip(jax.tree.leaves(grads1), jax.tree.leaves(g_ref)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-4, atol=1e-5)


_SUBPROCESS_COMPRESSED_PSUM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.collectives import (compressed_psum,
                                    tree_psum_with_error_feedback)

mesh = jax.make_mesh((4,), ("data",))
x = jnp.stack([jnp.full((8,), float(i + 1)) for i in range(4)])

out = jax.jit(jax.shard_map(
    lambda v: compressed_psum(v[0], "data")[None],
    mesh=mesh, in_specs=P("data"), out_specs=P("data")))(x)
want = float(sum(range(1, 5)))
np.testing.assert_allclose(np.asarray(out), want, rtol=2e-2)

# error feedback: mean of identical runs converges despite quantization
g = jnp.stack([jnp.linspace(-1, 1, 8) * (i + 1) for i in range(4)])
e = jnp.zeros_like(g)
def step(v, err):
    m, ne = tree_psum_with_error_feedback(v[0], err[0], "data")
    return m[None], ne[None]
m, ne = jax.jit(jax.shard_map(step, mesh=mesh,
                              in_specs=(P("data"), P("data")),
                              out_specs=(P("data"), P("data"))))(g, e)
true_mean = np.mean(np.asarray(g), axis=0)
np.testing.assert_allclose(np.asarray(m)[0], true_mean, atol=0.05)
print("OK")
"""


def test_compressed_psum_multidevice():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_COMPRESSED_PSUM],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=__import__("os").path.dirname(__import__("os").path.dirname(
            __file__)))
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout
