"""The DeviceRef data plane (ISSUE 2 acceptance surface).

Covers: ref forwarding through staged pipelines with **zero** host
transfers between stages, access-rights enforcement, donation-after-use
errors, spill/unspill round-trips (incl. pickling — the paper's
distribution option (b)), placement-aware pool/scheduler routing, the
registry's live-bytes watermark accounting, and leak checks via
``live_ref_count()`` after every pipeline/pool run.
"""
import gc
import pickle
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AccessViolation, ActorPool, ActorSystem,
                        ChunkScheduler, DeviceRef, In, InOut, NDRange, Out,
                        Pipeline, compose, dim_vec, fuse, kernel,
                        live_ref_count, memory_stats, reset_transfer_stats,
                        transfer_count)
from repro.core.memref import registry


@pytest.fixture(scope="module")
def system():
    s = ActorSystem(max_workers=8)
    yield s
    s.shutdown()


@pytest.fixture(scope="module")
def mngr(system):
    return system.opencl_manager()


@pytest.fixture()
def ref_baseline():
    """Live-ref baseline for leak checks (GC first: other test modules may
    have dropped refs whose __del__ hasn't run yet)."""
    gc.collect()
    return live_ref_count()


def assert_refs_settle(baseline: int, timeout: float = 5.0) -> None:
    """Leak check that tolerates *in-flight* releases: a chain's cleanup
    runs in actor done-callbacks that can lag the caller's result by a
    scheduler beat (and stray callbacks from earlier test modules may
    still be draining), so poll with GC instead of sampling once. A real
    leak still fails — the count never comes back down to the baseline."""
    deadline = time.monotonic() + timeout
    while True:
        gc.collect()
        n = live_ref_count()
        if n <= baseline:
            return
        if time.monotonic() > deadline:
            assert n == baseline, f"{n - baseline} DeviceRefs leaked"
        time.sleep(0.02)


N = 16


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)),
        name="p1")
def p1(x):
    return x + 1.0


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)),
        name="p2")
def p2(x):
    return x * 2.0


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)),
        name="p3")
def p3(x):
    return x - 3.0


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)),
        name="p4")
def p4(x):
    return x / 2.0


@kernel(In(jnp.float32), Out(jnp.float32, as_ref=True),
        nd_range=NDRange(dim_vec(N)), name="p4_ref")
def p4_ref(x):
    return x / 2.0


def _expected(x):
    return ((x + 1.0) * 2.0 - 3.0) / 2.0


# ----------------------------------------------------------------------------
# zero-copy staged pipelines (tentpole acceptance criterion)
# ----------------------------------------------------------------------------
def test_staged_4_stage_pipeline_zero_host_transfers(system, ref_baseline):
    """A 4-stage staged pipeline must forward DeviceRefs between stages:
    zero ``to_value()`` host transfers, exactly one final read-back."""
    pipe = (Pipeline(system, mode="staged")
            .stage(p1).stage(p2).stage(p3).stage(p4).build())
    x = np.arange(N, dtype=np.float32)
    reset_transfer_stats()
    r = pipe.ask(x)
    np.testing.assert_allclose(r, _expected(x), rtol=1e-6)
    assert transfer_count() == 0, "stages round-tripped through the host"
    stats = memory_stats()
    assert stats["readbacks"] == 1      # only the final value read-back
    assert stats["spills"] == 0
    # intermediate refs were released by the chain
    assert_refs_settle(ref_baseline)


def test_staged_pipeline_ref_output_no_transfers_at_all(system, ref_baseline):
    """With a ref-semantics final stage the whole run does zero host
    traffic; the single transfer happens only at the explicit read-back."""
    pipe = (Pipeline(system, mode="staged")
            .stage(p1).stage(p2).stage(p3).stage(p4_ref).build())
    x = np.arange(N, dtype=np.float32)
    reset_transfer_stats()
    out = pipe.ask(x)
    assert isinstance(out, DeviceRef)
    assert transfer_count() == 0
    assert memory_stats()["readbacks"] == 0
    np.testing.assert_allclose(out.to_value(), _expected(x), rtol=1e-6)
    assert transfer_count() == 1        # the explicit read-back, counted
    out.release()
    assert_refs_settle(ref_baseline)


def test_staged_value_stages_promoted_to_refs_only_internally(system):
    """Promotion to ref emission must not leak into direct use: a worker
    spawned from the same decl still returns host values."""
    w = system.spawn(p1)
    x = np.arange(N, dtype=np.float32)
    out = w.ask(x)
    assert isinstance(out, np.ndarray)


def test_staged_from_existing_actors_forwards_refs(system, ref_baseline):
    """Existing value-semantics kernel actors get cloned (not mutated)
    into ref-emitting intermediates."""
    a, b = system.spawn(p1), system.spawn(p2)
    pipe = Pipeline(system, mode="staged").stages([a, b]).build()
    x = np.arange(N, dtype=np.float32)
    reset_transfer_stats()
    np.testing.assert_allclose(pipe.ask(x), (x + 1) * 2)
    assert transfer_count() == 0
    assert memory_stats()["readbacks"] == 1
    # the original actor is untouched: still value-emitting
    assert isinstance(a.ask(x), np.ndarray)
    assert_refs_settle(ref_baseline)


def test_staged_stage_with_preprocess_gets_values(system):
    """A successor stage with a preprocess must receive value payloads:
    the preprocess runs before ref unwrapping, so promoting the upstream
    stage to ref emission would hand it a DeviceRef."""
    consumer = p2.with_options(preprocess=lambda x: x * 2.0)
    pipe = Pipeline(system, mode="staged").stage(p1).stage(consumer).build()
    x = np.arange(N, dtype=np.float32)
    np.testing.assert_allclose(pipe.ask(x), (x + 1) * 2 * 2)


def test_staged_passthrough_final_stage_keeps_ref_alive(system, ref_baseline):
    """An opaque final stage forwarding the upstream ref unchanged must
    hand the caller a *live* ref — the chain may not release a ref that
    escapes into the result."""
    ident = system.spawn(lambda r: r)
    pipe = Pipeline(system, mode="staged").stage(p4_ref).stage(ident).build()
    x = np.arange(N, dtype=np.float32)
    out = pipe.ask(x)
    assert isinstance(out, DeviceRef)
    np.testing.assert_allclose(out.to_value(), x / 2.0)   # still live
    out.release()
    assert_refs_settle(ref_baseline)


def test_staged_opaque_stage_gets_values(system):
    """A plain (non-kernel) actor downstream forces the kernel before it
    back to value emission — opaque actors never see DeviceRefs."""
    seen = []
    opaque = system.spawn(lambda x: (seen.append(type(x)), x + 1.0)[1])
    pipe = Pipeline(system, mode="staged").stage(p1).stage(opaque).build()
    x = np.arange(N, dtype=np.float32)
    np.testing.assert_allclose(pipe.ask(x), x + 2)
    assert seen and not issubclass(seen[0], DeviceRef)


# ----------------------------------------------------------------------------
# access rights (paper §3.5)
# ----------------------------------------------------------------------------
def test_read_only_ref_cannot_be_donated_or_updated(mngr, system):
    updater = system.spawn(
        kernel(InOut(jnp.float32, as_ref=True),
               nd_range=NDRange(dim_vec(4)), name="upd")(lambda x: x * 2.0))
    full = DeviceRef.put(np.ones(4, np.float32))
    ro = full.restrict("r")
    with pytest.raises(AccessViolation):
        ro.donate()
    # the buffer is usable through the original rw ref ...
    out = updater.ask(full)
    np.testing.assert_allclose(out.to_value(), 2.0)
    # ... but an in_out kernel rejects the read-only view (and dies with
    # the violation — actor fault semantics)
    with pytest.raises(AccessViolation):
        updater.ask(ro)
    out.release()
    ro.release()


def test_write_only_ref_cannot_be_read():
    ref = DeviceRef.put(np.ones(4, np.float32), access="w")
    with pytest.raises(AccessViolation):
        _ = ref.array
    with pytest.raises(AccessViolation):
        ref.to_value()
    with pytest.raises(AccessViolation):
        ref.spill()     # spilling serializes the contents: needs 'r' too
    ref.release()


def test_rights_cannot_widen():
    ref = DeviceRef.put(np.ones(4, np.float32), access="r")
    with pytest.raises(AccessViolation):
        ref.restrict("rw")
    with pytest.raises(ValueError):
        ref.restrict("x")
    ref.release()


# ----------------------------------------------------------------------------
# donation
# ----------------------------------------------------------------------------
def test_donation_after_use_raises(mngr, system):
    updater = system.spawn(
        kernel(InOut(jnp.float32, as_ref=True),
               nd_range=NDRange(dim_vec(4)), name="upd2")(lambda x: x + 1.0))
    ref = DeviceRef.put(np.zeros(4, np.float32))
    out = updater.ask(ref)
    np.testing.assert_allclose(out.to_value(), 1.0)
    # the incoming in_out ref was donated: every further use raises
    with pytest.raises(RuntimeError, match="donat"):
        _ = ref.array
    with pytest.raises(RuntimeError, match="donat"):
        ref.donate()
    with pytest.raises(RuntimeError, match="donat"):
        ref.spill()
    ref.release()   # release after donation is a no-op, not an error
    out.release()


def test_donate_returns_array_and_retires_accounting():
    base_bytes = registry.live_bytes()
    ref = DeviceRef.put(np.ones(8, np.float32))
    assert registry.live_bytes() == base_bytes + 32
    arr = ref.donate()
    assert arr.shape == (8,)
    assert registry.live_bytes() == base_bytes


# ----------------------------------------------------------------------------
# spill / unspill (distribution option (b))
# ----------------------------------------------------------------------------
def test_spill_roundtrip_through_pickle(ref_baseline):
    data = np.arange(12, dtype=np.float32).reshape(3, 4)
    ref = DeviceRef.put(data)
    with pytest.raises(TypeError):
        pickle.dumps(ref)               # device-resident: option (a)
    ref.spill()
    assert ref.is_spilled
    clone = pickle.loads(pickle.dumps(ref))     # option (b): explicit
    assert clone.is_spilled and clone.shape == (3, 4)
    clone.unspill()
    np.testing.assert_array_equal(clone.to_value(), data)
    ref.unspill()
    np.testing.assert_array_equal(ref.to_value(), data)
    ref.release()
    clone.release()
    assert_refs_settle(ref_baseline)


def test_spill_moves_bytes_off_device():
    base = registry.live_bytes()
    ref = DeviceRef.put(np.zeros(256, np.float32))
    assert registry.live_bytes() == base + 1024
    ref.spill()
    assert registry.live_bytes() == base        # host copy doesn't count
    ref.unspill()
    assert registry.live_bytes() == base + 1024
    ref.release()
    assert registry.live_bytes() == base


def test_spilled_ref_array_access_requires_unspill():
    ref = DeviceRef.put(np.ones(4, np.float32)).spill()
    with pytest.raises(RuntimeError, match="spill"):
        _ = ref.array
    # to_value on a spilled ref serves the host copy without a transfer
    before = transfer_count()
    np.testing.assert_allclose(ref.to_value(), 1.0)
    assert transfer_count() == before
    ref.release()


# ----------------------------------------------------------------------------
# registry accounting / watermarks
# ----------------------------------------------------------------------------
def test_registry_watermark_and_device_stats(mngr):
    dev = mngr.find_device()
    base_live = dev.live_bytes()
    refs = [DeviceRef.put(np.zeros(64, np.float32)) for _ in range(4)]
    assert dev.live_bytes() == base_live + 4 * 256
    assert dev.peak_bytes() >= dev.live_bytes()
    stats = mngr.memory_stats()
    assert stats[dev.name]["live_bytes"] == dev.live_bytes()
    for r in refs:
        r.release()
    assert dev.live_bytes() == base_live


def test_release_is_idempotent_and_terminal(ref_baseline):
    ref = DeviceRef.put(np.ones(4, np.float32))
    ref.release()
    ref.release()
    with pytest.raises(RuntimeError):
        _ = ref.array
    assert_refs_settle(ref_baseline)


# ----------------------------------------------------------------------------
# placement-aware routing
# ----------------------------------------------------------------------------
class _StubDevice:
    """Quacks like repro.core.manager.Device for routing tests."""

    def __init__(self, jax_device):
        self.jax_device = jax_device

    def queue_depth(self):
        return 0

    def live_bytes(self):
        return 0


def test_pool_prefers_worker_holding_the_ref(system):
    counts = [0, 0]

    def make(i):
        def fn(r):
            counts[i] += 1
            return np.float32(0.0)
        return fn

    ref = DeviceRef.put(np.ones(4, np.float32))
    local = _StubDevice(ref.device)
    remote = _StubDevice("somewhere-else")
    pool = ActorPool(system, [system.spawn(make(0)), system.spawn(make(1))],
                     policy="round_robin", devices=[remote, local])
    for _ in range(6):
        pool.ask(ref)
    assert counts == [0, 6], counts     # every request routed to `local`
    # without a ref payload, round-robin resumes cycling
    for _ in range(6):
        pool.ask(np.float32(1.0))
    assert counts[0] > 0
    ref.release()


def test_chunk_scheduler_take_pending_prefers_resident_chunks(system):
    """The placement-aware pop: a worker grabs the chunk already resident
    on its device, a foreign worker prefers affinity-free chunks, and FIFO
    is the fallback (strict affinity must never starve a worker)."""
    from repro.core.scheduler import WorkItem

    w_other = system.spawn(lambda *a: None)
    w_local = system.spawn(lambda *a: None)
    ref = DeviceRef.put(np.ones(2, np.float32))
    sched = ChunkScheduler(
        [w_other, w_local],
        devices=[_StubDevice("elsewhere"), _StubDevice(ref.device)])
    items = [WorkItem(0, (0, None)), WorkItem(1, (1, ref)),
             WorkItem(2, (2, ref))]
    pending = list(items)
    assert sched._take_pending(pending, w_local) is items[1]
    assert sched._take_pending(pending, w_other) is items[0]
    # only foreign-affinity chunks left: FIFO fallback keeps w_other busy
    assert sched._take_pending(pending, w_other) is items[2]
    ref.release()


def test_chunk_scheduler_ref_payloads_end_to_end(system):
    ref = DeviceRef.put(np.float32(10.0))
    workers = [system.spawn(
        lambda i, r: i + (float(r.to_value()) if r is not None else 0.0))
        for _ in range(2)]
    sched = ChunkScheduler(workers)
    res = sched.run([(i, ref if i % 2 else None) for i in range(6)],
                    timeout=60)
    assert [int(x) for x in res] == [0, 11, 2, 13, 4, 15]
    ref.release()


# ----------------------------------------------------------------------------
# pools + pipelines leave no refs behind
# ----------------------------------------------------------------------------
def test_pool_of_ref_kernels_leak_free(system, mngr, ref_baseline):
    pool = mngr.spawn_pool(p4_ref, 3, policy="least_loaded")
    x = np.arange(N, dtype=np.float32)
    outs = [pool.ask(x) for _ in range(9)]
    for o in outs:
        assert isinstance(o, DeviceRef)
        np.testing.assert_allclose(o.to_value(), x / 2.0)
        o.release()
    assert_refs_settle(ref_baseline)


def test_pipeline_failure_releases_intermediate_refs(system, ref_baseline):
    boom = system.spawn(
        kernel(In(jnp.float32), Out(jnp.float32),
               nd_range=NDRange(dim_vec(N)),
               name="boom")(lambda x: (_ for _ in ()).throw(ValueError("x"))))
    pipe = Pipeline(system, mode="staged").stage(p1).stage(p2).build()
    # chain p1 -> p2 -> boom manually: boom's failure must not leak p2's ref
    full = Pipeline(system, mode="staged").stages([pipe, boom]).build()
    with pytest.raises(Exception):
        full.ask(np.arange(N, dtype=np.float32))
    time.sleep(0.2)     # let the failure callback run
    assert_refs_settle(ref_baseline)


# ----------------------------------------------------------------------------
# compressed wire format on refs (dist/collectives)
# ----------------------------------------------------------------------------
def test_quantize_ref_roundtrip_and_wire_bytes(ref_baseline):
    from repro.dist.collectives import dequantize_ref, quantize_ref
    x = np.linspace(-1.0, 1.0, 128).astype(np.float32)
    ref = DeviceRef.put(x)
    qref, scale = quantize_ref(ref)
    assert qref.nbytes == ref.nbytes // 4       # int8: 4x fewer wire bytes
    qref.spill()                                # the compressed boundary
    shipped = pickle.loads(pickle.dumps(qref))
    deq = dequantize_ref(shipped.unspill(), scale)
    np.testing.assert_allclose(deq.to_value(), x, atol=2.0 / 254)
    for r in (ref, qref, shipped, deq):
        r.release()
    assert_refs_settle(ref_baseline)
