"""Sharding rule-engine tests (divisibility fallbacks, FSDP, caches) —
run on a 4-device (2 data × 2 model) subprocess mesh where needed, pure
spec checks otherwise."""
import os
import subprocess
import sys

import pytest

_SPEC_CHECKS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro import configs
from repro.dist import sharding as sh
from repro.launch.mesh import make_mesh
from repro.models import Model

mesh = make_mesh((2, 8), ("data", "model"))

# --- divisibility-aware rules ---------------------------------------------
cfg = configs.get_config("llama3-8b")
model = Model(cfg, vocab=cfg.padded_vocab(8))
shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
explain = {}
sh.param_shardings(shapes, cfg, mesh, explain=explain)
def spec(name):
    return explain[name][1]
assert spec("embed") == P("model", None), spec("embed")
assert spec("head") == P(None, "model")
assert spec("groups/0/0/attn/wq") == P(None, None, "model")
assert spec("groups/0/0/attn/wo") == P(None, "model", None)
assert spec("groups/0/0/mlp/w_out") == P(None, "model", None)
assert spec("groups/0/0/norm1/scale") == P(None, None)

# FSDP adds 'data' on the largest unsharded big dim
explain2 = {}
sh.param_shardings(shapes, cfg, mesh, sh.Plan(fsdp=True), explain=explain2)
assert explain2["groups/0/0/mlp/w_up"][1] == P(None, "data", "model")
assert explain2["groups/0/0/attn/wo"][1] == P(None, "model", "data")

# qwen1.5: 40 kv heads * 128 = 5120 % 8 == 0 → shardable; but on a mesh of
# model=16 the 40-head dim itself is checked at cache level
cfgq = configs.get_config("qwen1.5-32b")

# mamba2 in_proj second dim is 3352: divisible by 8 (→ sharded on this
# mesh) but NOT by 16 (→ the production mesh replicates it)
cfgm = configs.get_config("mamba2-130m")
mm = Model(cfgm, vocab=cfgm.padded_vocab(8))
shm = jax.eval_shape(lambda: mm.init(jax.random.key(0)))
em = {}
sh.param_shardings(shm, cfgm, mesh, explain=em)
assert em["groups/0/0/ssm/in_proj"][1] == P(None, None, "model")
assert em["groups/0/0/ssm/out_proj"][1] == P(None, "model", None)

mesh16 = make_mesh((1, 16), ("data", "model"))
em16 = {}
sh.param_shardings(shm, cfgm, mesh16, explain=em16)
assert em16["groups/0/0/ssm/in_proj"][1] == P(None, None, None), \
    "3352 % 16 != 0 must fall back to replication"

# --- batch specs: non-divisible batch replicates (long_500k B=1) -----------
bspec = {"tokens": jax.ShapeDtypeStruct((1, 128), jnp.int32)}
bs = sh.batch_shardings(bspec, mesh)
assert bs["tokens"].spec == P(None, None)
bspec = {"tokens": jax.ShapeDtypeStruct((4, 128), jnp.int32)}
assert sh.batch_shardings(bspec, mesh)["tokens"].spec == P("data", None)

# --- cache specs: seq-sharded KV needs divisibility -------------------------
cache_shapes = jax.eval_shape(lambda: model.init_cache(4, 128))
cs = sh.cache_shardings(cache_shapes, cfg, mesh, sh.Plan(kv_cache="seq"))
k_sh = jax.tree.leaves(
    {"k": cs["groups"][0][0]["k"]})[0]
assert k_sh.spec == P(None, "data", "model", None, None), k_sh.spec
print("OK")
"""


def test_sharding_rules_subprocess():
    r = subprocess.run([sys.executable, "-c", _SPEC_CHECKS],
                       capture_output=True, text=True, timeout=420,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


_DRYRUN_SMALL = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import repro.configs as C
from repro.launch import dryrun_lib
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
C.SHAPES["t_train"] = (256, 8, "train")
C.SHAPES["t_prefill"] = (512, 4, "prefill")
C.SHAPES["t_decode"] = (512, 8, "decode")
C.get_config = C.get_smoke_config          # reduced configs, fast compiles
dryrun_lib.configs.get_config = C.get_smoke_config

failures = []
for arch in C.list_archs():
    for shape in ("t_train", "t_prefill", "t_decode"):
        rep = dryrun_lib.lower_cell(arch, shape, mesh, "test-8")
        if rep["status"] != "compiled":
            failures.append((arch, shape, rep.get("error", rep["status"])))
        else:
            rl = rep["roofline"]
            assert rl["flops_per_device"] > 0, (arch, shape)
            assert rl["bytes_per_device"] > 0, (arch, shape)
assert not failures, failures
print("OK all archs x 3 kinds compiled on 8-device mesh")
"""


@pytest.mark.slow
def test_dryrun_all_archs_small_mesh():
    """Integration: every arch × {train,prefill,decode} lowers+compiles on a
    small mesh with roofline terms — the dry-run path in miniature."""
    r = subprocess.run([sys.executable, "-c", _DRYRUN_SMALL],
                       capture_output=True, text=True, timeout=560,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])
    assert "OK" in r.stdout
