"""The continuous-batching serve runtime (ISSUE 3 acceptance surface).

Covers: batcher policies (max-wait vs max-batch, shape bucketing),
join/leave correctness (every admitted request gets exactly its own
tokens back), a 16-thread client hammer, admission control (backpressure,
load shedding, SLO budget), fault injection (worker dies mid-batch →
re-queue on another worker, exactly-once; permanent failures → per-request
errors), the deadline-aware ChunkScheduler pick, the pool's async submit,
the PipelineRunner serve path, the launch-CLI cache-capacity guard, and
the ISSUE 3 demo: 32 requests / max-batch 8 with zero host transfers
between decode steps.
"""
import gc
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ActorPool, ActorSystem, ChunkScheduler,
                        DeadlineExceeded, live_ref_count, transfer_count)
from repro.launch.serve import check_cache_capacity
from repro.serve import (Batcher, QueueOverflow, Request, RequestQueue,
                         ServeEngine, SLOExceeded, make_decode_worker)


@pytest.fixture(scope="module")
def system():
    s = ActorSystem(max_workers=8)
    yield s
    s.shutdown()


# ----------------------------------------------------------------------------
# toy decode model: cache row = [seed, step]; token = seed*1000 + step
# ----------------------------------------------------------------------------
def counter_step(cache, tokens):
    next_tok = (cache[:, 0] * 1000 + cache[:, 1]).astype(jnp.int32)
    return next_tok, cache.at[:, 1].add(1)


def counter_init(prompt):
    return jnp.asarray([int(prompt), 0], jnp.int32), 0


def expected_tokens(seed, n):
    return [seed * 1000 + i for i in range(n)]


def make_engine(system, **kw):
    kw.setdefault("n_workers", 2)
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait_ms", 2.0)
    return ServeEngine(system, counter_step, counter_init, **kw)


# ----------------------------------------------------------------------------
# batcher policies
# ----------------------------------------------------------------------------
def test_batcher_max_batch_returns_without_waiting_window():
    q = RequestQueue()
    for s in range(8):
        q.submit(Request(s, max_new_tokens=1))
    b = Batcher(q, max_batch=8, max_wait_ms=10_000.0)
    t0 = time.monotonic()
    batch = b.take(wait_s=0.0)
    elapsed = time.monotonic() - t0
    assert len(batch) == 8
    assert elapsed < 5.0  # full batch short-circuits the 10s window


def test_batcher_max_wait_dispatches_partial_batch():
    q = RequestQueue()
    for s in range(3):
        q.submit(Request(s, max_new_tokens=1))
    b = Batcher(q, max_batch=8, max_wait_ms=30.0)
    t0 = time.monotonic()
    batch = b.take(wait_s=0.0)
    elapsed = time.monotonic() - t0
    assert len(batch) == 3          # went with what it had...
    assert elapsed >= 0.025         # ...but only after the window closed
    assert len(q) == 0


def test_batcher_window_admits_late_arrivals():
    q = RequestQueue()
    q.submit(Request(0, max_new_tokens=1))
    b = Batcher(q, max_batch=4, max_wait_ms=500.0)

    def late():
        time.sleep(0.05)
        for s in (1, 2, 3):
            q.submit(Request(s, max_new_tokens=1))

    t = threading.Thread(target=late)
    t.start()
    batch = b.take(wait_s=0.0)
    t.join()
    assert [r.prompt for r in batch] == [0, 1, 2, 3]


def test_batcher_shape_bucketing():
    q = RequestQueue()
    a1 = Request(np.zeros(3), max_new_tokens=1)
    b1 = Request(np.zeros(5), max_new_tokens=1)
    a2 = Request(np.ones(3), max_new_tokens=1)
    for r in (a1, b1, a2):
        q.submit(r)
    b = Batcher(q, max_batch=8, max_wait_ms=10.0)
    first = b.take(wait_s=0.0)
    assert [r.id for r in first] == [a1.id, a2.id]  # seed's bucket only
    second = b.take(wait_s=0.0)
    assert [r.id for r in second] == [b1.id]        # other bucket next
    assert len(q) == 0


def test_batcher_join_path_is_windowless_and_pinned():
    q = RequestQueue()
    match = Request(np.zeros(3), max_new_tokens=1)
    other = Request(np.zeros(5), max_new_tokens=1)
    q.submit(other)
    q.submit(match)
    b = Batcher(q, max_batch=8, max_wait_ms=10_000.0)
    t0 = time.monotonic()
    batch = b.take(4, bucket=(3,), wait_s=0.0, max_wait_s=0.0)
    assert time.monotonic() - t0 < 5.0
    assert [r.id for r in batch] == [match.id]
    assert len(q) == 1  # the other bucket stayed queued


def test_queue_orders_by_priority_then_deadline():
    q = RequestQueue()
    now = time.monotonic()
    low = Request("a", priority=5)
    urgent = Request("b", priority=0, deadline=now + 10)
    more_urgent = Request("c", priority=0, deadline=now + 5)
    for r in (low, urgent, more_urgent):
        q.submit(r)
    assert q.pop(timeout=0).id == more_urgent.id
    assert q.pop(timeout=0).id == urgent.id
    assert q.pop(timeout=0).id == low.id


# ----------------------------------------------------------------------------
# admission control: backpressure + load shedding
# ----------------------------------------------------------------------------
def test_queue_overflow_sheds_nonblocking():
    q = RequestQueue(max_depth=2)
    q.submit(Request(0))
    q.submit(Request(1))
    with pytest.raises(QueueOverflow):
        q.submit(Request(2))
    assert q.shed == 1
    assert len(q) == 2


def test_queue_backpressure_blocks_until_space():
    q = RequestQueue(max_depth=1)
    q.submit(Request(0))
    admitted = []

    def producer():
        q.submit(Request(1), block=True, timeout=5.0)
        admitted.append(True)

    t = threading.Thread(target=producer)
    t.start()
    time.sleep(0.05)
    assert not admitted          # still backpressured
    assert q.pop(timeout=0) is not None
    t.join(timeout=5.0)
    assert admitted and len(q) == 1


def test_queue_slo_budget_sheds_when_wait_estimate_blows_budget():
    q = RequestQueue(slo_budget_s=0.1)
    q.submit(Request(0))         # no service estimate yet: admitted
    q.note_service_time(1.0)     # engine observed 1s/step
    with pytest.raises(SLOExceeded):
        q.submit(Request(1))     # (depth+1) * 1s >> 0.1s budget
    assert q.shed == 1


def test_queue_sheds_expired_deadline_at_admission():
    q = RequestQueue()
    with pytest.raises(SLOExceeded):
        q.submit(Request(0, deadline=time.monotonic() - 1.0))
    assert q.shed == 1


# ----------------------------------------------------------------------------
# engine: join/leave correctness
# ----------------------------------------------------------------------------
def test_every_request_gets_exactly_its_own_tokens(system):
    lengths = [3, 1, 4, 2, 5, 1, 3, 2, 4, 1]
    with make_engine(system, max_batch=3) as eng:
        futs = [eng.submit(seed, max_new_tokens=n)
                for seed, n in enumerate(lengths)]
        results = [f.result(timeout=60) for f in futs]
    for seed, (n, res) in enumerate(zip(lengths, results)):
        assert res.tokens == expected_tokens(seed, n), f"request {seed}"
    s = eng.stats()
    assert s["completed"] == len(lengths)
    assert s["joined"] == len(lengths) and s["left"] == len(lengths)
    assert s["failed"] == 0


def test_requests_join_a_running_batch(system):
    """A long request keeps the batch alive while short ones join and
    leave mid-flight — continuous batching, not gang scheduling."""
    with make_engine(system, max_batch=2, max_wait_ms=1.0) as eng:
        long_fut = eng.submit(1, max_new_tokens=30)
        time.sleep(0.2)  # the long request is mid-decode by now
        late_futs = [eng.submit(seed, max_new_tokens=2)
                     for seed in (2, 3, 4)]
        assert long_fut.result(60).tokens == expected_tokens(1, 30)
        for seed, f in zip((2, 3, 4), late_futs):
            assert f.result(60).tokens == expected_tokens(seed, 2)
    s = eng.stats()
    # the late requests were admitted while the long one was running, so
    # the batch must have been shared at some point
    assert s["peak_batch"] >= 2
    assert s["steps"] < 30 + 3 * 2  # overlap: fewer steps than serial sum


def test_sixteen_thread_client_hammer(system):
    """16 concurrent client threads; no lost, duplicated, or cross-wired
    responses under concurrent submission."""
    n_threads, per_thread = 16, 4
    results: dict = {}
    errors: list = []
    with make_engine(system, max_batch=4, max_wait_ms=1.0,
                     n_workers=3) as eng:

        def client(tid):
            try:
                futs = []
                for k in range(per_thread):
                    seed = tid * 100 + k
                    n = 1 + (seed % 5)
                    futs.append((seed, n, eng.submit(seed, max_new_tokens=n)))
                for seed, n, fut in futs:
                    results[(seed, n)] = fut.result(timeout=120)
            except Exception as exc:  # pragma: no cover - failure path
                errors.append(exc)

        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=180)
    assert not errors
    assert len(results) == n_threads * per_thread  # none lost
    for (seed, n), res in results.items():
        assert res.tokens == expected_tokens(seed, n), (seed, n)
    s = eng.stats()
    assert s["completed"] == n_threads * per_thread
    assert s["failed"] == 0


def test_engine_leak_free_and_deadline_shedding(system):
    gc.collect()
    base = live_ref_count()
    eng = make_engine(system, max_batch=4)
    # admitted while fresh, expires while the engine is still stopped —
    # deterministic mid-queue expiry
    ok = eng.submit(7, max_new_tokens=3)
    dead = eng.submit(8, max_new_tokens=3, slo_ms=50.0)
    time.sleep(0.1)
    with eng:
        assert ok.result(60).tokens == expected_tokens(7, 3)
        with pytest.raises(DeadlineExceeded):
            dead.result(60)
    gc.collect()
    assert live_ref_count() == base  # every cache ref released
    assert eng.stats()["expired"] >= 1


def test_failed_cache_init_releases_partial_tree(system):
    # regression: init_fn returning a tree whose *second* leaf fails to
    # wrap used to leak the DeviceRef already created for the first —
    # every shed/failed admission exit must release what it built
    class BadLeaf:
        def __array__(self):
            raise RuntimeError("unwrappable cache leaf")

    def bad_init(prompt):
        return (jnp.zeros(4, jnp.float32), BadLeaf()), 0

    gc.collect()
    base = live_ref_count()
    eng = ServeEngine(system, counter_step, bad_init, n_workers=2,
                      max_batch=4)
    with eng:
        fut = eng.submit(1, max_new_tokens=2)
        with pytest.raises(RuntimeError, match="unwrappable"):
            fut.result(60)
    gc.collect()
    assert live_ref_count() == base  # the good leaf was released
    assert eng.stats()["failed"] == 1


# ----------------------------------------------------------------------------
# fault injection
# ----------------------------------------------------------------------------
def _flaky_pool(system, crashes: int):
    """A pool whose first ``crashes`` decode dispatches die mid-batch."""
    armed = {"left": crashes}
    lock = threading.Lock()
    decode = make_decode_worker(counter_step)

    def flaky(*payload):
        with lock:
            if armed["left"] > 0:
                armed["left"] -= 1
                raise RuntimeError("injected mid-batch fault")
        return decode(*payload)

    workers = [system.spawn(flaky) for _ in range(3)]
    return ActorPool(system, workers, policy="least_loaded")


def test_worker_crash_requeues_batch_exactly_once(system):
    """A worker that dies mid-batch: the engine re-queues the affected
    requests on another worker; every request still gets exactly its own
    tokens, exactly once."""
    pool = _flaky_pool(system, crashes=1)
    eng = ServeEngine(system, init_fn=counter_init, pool=pool,
                      max_batch=4, max_wait_ms=5.0)
    with eng:
        futs = [eng.submit(seed, max_new_tokens=3) for seed in range(6)]
        results = [f.result(timeout=60) for f in futs]
    for seed, res in enumerate(results):
        assert res.tokens == expected_tokens(seed, 3)
    s = eng.stats()
    assert s["requeues"] >= 1          # the injected fault was re-issued
    assert s["completed"] == 6 and s["failed"] == 0
    assert len(pool.live_workers()) == 2  # the crashed replica is gone


def test_engine_owned_pool_self_heals_after_worker_death(system):
    """Killing a replica of an engine-owned pool must not shrink capacity:
    the engine respawns a replacement before the next step."""
    with make_engine(system, n_workers=2, max_batch=4) as eng:
        first = eng.submit(1, max_new_tokens=2)
        assert first.result(60).tokens == expected_tokens(1, 2)
        eng.pool.workers[0].exit()  # simulate a replica crash
        futs = [eng.submit(seed, max_new_tokens=3) for seed in (2, 3)]
        for seed, f in zip((2, 3), futs):
            assert f.result(60).tokens == expected_tokens(seed, 3)
        assert len(eng.pool.live_workers()) == 2  # capacity restored
    assert eng.stats()["respawned"] >= 1


def test_permanent_failure_is_per_request_error_not_engine_crash(system):
    """Every replica poisoned: the affected requests surface the error on
    their own futures; the engine survives and keeps serving."""
    pool = _flaky_pool(system, crashes=99)  # kills all 3 workers
    eng = ServeEngine(system, init_fn=counter_init, pool=pool,
                      max_batch=4, max_wait_ms=5.0, step_timeout=30.0)
    with eng:
        doomed = [eng.submit(seed, max_new_tokens=2) for seed in range(3)]
        excs = []
        for f in doomed:
            with pytest.raises(Exception) as ei:
                f.result(timeout=60)
            excs.append(ei.value)
    assert all(isinstance(e, Exception) for e in excs)
    s = eng.stats()
    assert s["failed"] == 3 and s["completed"] == 0
    # the engine thread exited cleanly via stop(), not by crashing
    assert not eng._thread.is_alive()


# ----------------------------------------------------------------------------
# deadline-aware scheduler pick + pool async submit
# ----------------------------------------------------------------------------
def test_chunk_scheduler_earliest_deadline_first(system):
    order = []

    def record(tag):
        order.append(tag)
        return tag

    w = system.spawn(record)
    now = time.monotonic()
    sched = ChunkScheduler([w])
    out = sched.run([("late",), ("soon",), ("mid",)],
                    deadlines=[now + 30, now + 10, now + 20])
    assert out == ["late", "soon", "mid"]   # results stay input-ordered
    assert order == ["soon", "mid", "late"]  # dispatch was EDF


def test_chunk_scheduler_sheds_expired_chunks(system):
    w = system.spawn(lambda x: x)
    sched = ChunkScheduler([w])
    with pytest.raises(DeadlineExceeded):
        sched.run([(1,), (2,)],
                  deadlines=[time.monotonic() - 1.0, None])
    assert sched.stats["expired"] == 1


def test_pool_submit_excludes_observed_bad_worker(system):
    seen = []

    def w1(x):
        seen.append("w1")
        return x

    def w2(x):
        seen.append("w2")
        return x

    r1, r2 = system.spawn(w1), system.spawn(w2)
    pool = ActorPool(system, [r1, r2], policy="round_robin")
    for _ in range(4):
        fut = pool.submit(1, exclude=[r1])
        assert fut.result(10) == 1
        assert fut.worker.actor_id == r2.actor_id
    assert seen == ["w2"] * 4
    # excluding everything degrades to normal routing, never strands work
    assert pool.submit(1, exclude=[r1, r2]).result(10) == 1


# ----------------------------------------------------------------------------
# staged serving across layer actors (PipelineRunner.submit)
# ----------------------------------------------------------------------------
def test_pipeline_runner_submit_serves_concurrent_microbatches(system):
    from repro.dist.pipeline import PipelineRunner
    s0 = system.spawn(lambda x: x + 1)
    s1 = system.spawn(lambda x: x * 10)
    runner = PipelineRunner(system, [s0, s1], depth=3)
    futs = [runner.submit(i) for i in range(6)]
    assert [f.result(30) for f in futs] == [(i + 1) * 10 for i in range(6)]
    # run() is the same machinery
    assert runner.run(list(range(4))) == [(i + 1) * 10 for i in range(4)]


# ----------------------------------------------------------------------------
# launch CLI: cache sizing guard (regression)
# ----------------------------------------------------------------------------
def test_check_cache_capacity_guard():
    assert check_cache_capacity(64, 65) == 65      # steps+1 fits exactly
    with pytest.raises(ValueError):
        check_cache_capacity(65, 65)               # off-by-one caught
    with pytest.raises(ValueError):
        check_cache_capacity(-1, 10)


# ----------------------------------------------------------------------------
# ISSUE 3 demo: 32 queued requests, max-batch 8, zero host transfers
# ----------------------------------------------------------------------------
def test_demo_32_requests_zero_host_transfers_with_latency_report(system):
    n_requests, steps = 32, 4
    eng = make_engine(system, max_batch=8, n_workers=2)
    # queue everything *before* the engine starts: batches form full
    futs = [eng.submit(seed, max_new_tokens=steps)
            for seed in range(n_requests)]
    t0 = transfer_count()
    with eng:
        results = [f.result(timeout=120) for f in futs]
    assert transfer_count() == t0, \
        "decode caches must stay device-resident between steps"
    for seed, res in enumerate(results):
        assert res.tokens == expected_tokens(seed, steps)
    s = eng.stats()
    assert s["peak_batch"] == 8
    # 32 requests × 4 steps = 128 request-steps in 16 batched steps
    assert s["steps"] == (n_requests // 8) * steps
    lat = s["latency"]
    assert lat["count"] == n_requests
    assert 0 < lat["p50_ms"] <= lat["p99_ms"]
    print(f"\ndemo: {n_requests} requests, {s['steps']} batched steps, "
          f"p50={lat['p50_ms']:.2f}ms p99={lat['p99_ms']:.2f}ms, "
          f"transfers={transfer_count() - t0}")
