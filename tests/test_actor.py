"""Unit tests for the actor runtime (paper §2.1 semantics)."""
import threading
import time

import pytest

from repro.core import (Actor, ActorFailed, ActorSystem, DownMessage,
                        ExitMessage)


@pytest.fixture()
def system():
    s = ActorSystem(max_workers=4)
    yield s
    s.shutdown()


def test_spawn_function_actor_and_request(system):
    ref = system.spawn(lambda x, y: x + y)
    assert ref.ask(2, 3) == 5


def test_messages_processed_in_order(system):
    seen = []
    done = threading.Event()

    def behave(i):
        seen.append(i)
        if i == 99:
            done.set()

    ref = system.spawn(behave)
    for i in range(100):
        ref.send(i)
    assert done.wait(10)
    assert seen == list(range(100))


def test_actor_state_isolated_sequential(system):
    """Actors are isolated entities; a single actor never runs concurrently."""

    class Counter(Actor):
        def __init__(self):
            super().__init__()
            self.n = 0
            self.concurrent = 0
            self.max_concurrent = 0

        def receive(self, _):
            self.concurrent += 1
            self.max_concurrent = max(self.max_concurrent, self.concurrent)
            time.sleep(0.001)
            self.n += 1
            self.concurrent -= 1
            return self.n

    c = Counter()
    ref = system.spawn(c)
    futs = [ref.request("tick") for _ in range(50)]
    results = [f.result(10) for f in futs]
    assert results == list(range(1, 51))
    assert c.max_concurrent == 1


def test_failure_sets_exception_and_kills_actor(system):
    def bad(x):
        raise ValueError("boom")

    ref = system.spawn(bad)
    with pytest.raises(ValueError):
        ref.ask(1)
    assert not ref.is_alive()
    with pytest.raises(ActorFailed):
        ref.ask(2)


def test_monitor_receives_down_message(system):
    inbox = []
    got = threading.Event()

    def watcher(msg):
        inbox.append(msg)
        got.set()

    w = system.spawn(watcher)
    target = system.spawn(lambda: 1 / 0)
    system.monitor(w, target)
    target.send()
    assert got.wait(10)
    assert isinstance(inbox[0], DownMessage)
    assert inbox[0].actor_id == target.actor_id
    assert isinstance(inbox[0].reason, ZeroDivisionError)


def test_monitor_on_dead_actor_fires_immediately(system):
    inbox = []
    got = threading.Event()

    def watcher(msg):
        inbox.append(msg)
        got.set()

    w = system.spawn(watcher)
    target = system.spawn(lambda x: x)
    target.exit(None)
    system.monitor(w, target)
    assert got.wait(10)
    assert isinstance(inbox[0], DownMessage)


def test_link_propagates_exit(system):
    class Trapper(Actor):
        def __init__(self):
            super().__init__()
            self.trap_exit = True
            self.exits = []
            self.got = threading.Event()

        def receive(self, msg):
            if isinstance(msg, ExitMessage):
                self.exits.append(msg)
                self.got.set()

    trapper = Trapper()
    t = system.spawn(trapper)
    victim = system.spawn(lambda: 1 / 0)
    system.link(t, victim)
    victim.send()
    assert trapper.got.wait(10)
    assert trapper.exits[0].actor_id == victim.actor_id


def test_link_kills_non_trapping_actor(system):
    other = system.spawn(lambda x: x)
    victim = system.spawn(lambda: 1 / 0)
    system.link(other, victim)
    victim.send()
    deadline = time.monotonic() + 10
    while other.is_alive() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not other.is_alive()


def test_promise_delegation(system):
    """A behavior returning a Future delegates the response (paper §3.5)."""
    inner = system.spawn(lambda x: x * 10)

    def delegating(x):
        return inner.request(x + 1)

    outer = system.spawn(delegating)
    assert outer.ask(4) == 50


def test_shutdown_terminates_all(system):
    refs = [system.spawn(lambda x: x) for _ in range(10)]
    system.shutdown()
    assert all(not r.is_alive() for r in refs)
