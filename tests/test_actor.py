"""Unit tests for the actor runtime (paper §2.1 semantics)."""
import threading
import time

import pytest

from repro.core import (Actor, ActorFailed, ActorSystem, DownMessage,
                        ExitMessage)


@pytest.fixture()
def system():
    s = ActorSystem(max_workers=4)
    yield s
    s.shutdown()


def test_spawn_function_actor_and_request(system):
    ref = system.spawn(lambda x, y: x + y)
    assert ref.ask(2, 3) == 5


def test_messages_processed_in_order(system):
    seen = []
    done = threading.Event()

    def behave(i):
        seen.append(i)
        if i == 99:
            done.set()

    ref = system.spawn(behave)
    for i in range(100):
        ref.send(i)
    assert done.wait(10)
    assert seen == list(range(100))


def test_actor_state_isolated_sequential(system):
    """Actors are isolated entities; a single actor never runs concurrently."""

    class Counter(Actor):
        def __init__(self):
            super().__init__()
            self.n = 0
            self.concurrent = 0
            self.max_concurrent = 0

        def receive(self, _):
            self.concurrent += 1
            self.max_concurrent = max(self.max_concurrent, self.concurrent)
            time.sleep(0.001)
            self.n += 1
            self.concurrent -= 1
            return self.n

    c = Counter()
    ref = system.spawn(c)
    futs = [ref.request("tick") for _ in range(50)]
    results = [f.result(10) for f in futs]
    assert results == list(range(1, 51))
    assert c.max_concurrent == 1


def test_failure_sets_exception_and_kills_actor(system):
    def bad(x):
        raise ValueError("boom")

    ref = system.spawn(bad)
    with pytest.raises(ValueError):
        ref.ask(1)
    assert not ref.is_alive()
    with pytest.raises(ActorFailed):
        ref.ask(2)


def test_monitor_receives_down_message(system):
    inbox = []
    got = threading.Event()

    def watcher(msg):
        inbox.append(msg)
        got.set()

    w = system.spawn(watcher)
    target = system.spawn(lambda: 1 / 0)
    system.monitor(w, target)
    target.send()
    assert got.wait(10)
    assert isinstance(inbox[0], DownMessage)
    assert inbox[0].actor_id == target.actor_id
    assert isinstance(inbox[0].reason, ZeroDivisionError)


def test_monitor_on_dead_actor_fires_immediately(system):
    inbox = []
    got = threading.Event()

    def watcher(msg):
        inbox.append(msg)
        got.set()

    w = system.spawn(watcher)
    target = system.spawn(lambda x: x)
    target.exit(None)
    system.monitor(w, target)
    assert got.wait(10)
    assert isinstance(inbox[0], DownMessage)


def test_link_propagates_exit(system):
    class Trapper(Actor):
        def __init__(self):
            super().__init__()
            self.trap_exit = True
            self.exits = []
            self.got = threading.Event()

        def receive(self, msg):
            if isinstance(msg, ExitMessage):
                self.exits.append(msg)
                self.got.set()

    trapper = Trapper()
    t = system.spawn(trapper)
    victim = system.spawn(lambda: 1 / 0)
    system.link(t, victim)
    victim.send()
    assert trapper.got.wait(10)
    assert trapper.exits[0].actor_id == victim.actor_id


def test_link_kills_non_trapping_actor(system):
    other = system.spawn(lambda x: x)
    victim = system.spawn(lambda: 1 / 0)
    system.link(other, victim)
    victim.send()
    deadline = time.monotonic() + 10
    while other.is_alive() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not other.is_alive()


def test_promise_delegation(system):
    """A behavior returning a Future delegates the response (paper §3.5)."""
    inner = system.spawn(lambda x: x * 10)

    def delegating(x):
        return inner.request(x + 1)

    outer = system.spawn(delegating)
    assert outer.ask(4) == 50


def test_shutdown_terminates_all(system):
    refs = [system.spawn(lambda x: x) for _ in range(10)]
    system.shutdown()
    assert all(not r.is_alive() for r in refs)


# ----------------------------------------------------------------------------
# fault-propagation races (ISSUE 5 satellites)
# ----------------------------------------------------------------------------
def test_monitor_registered_during_terminate_always_delivers(system):
    """A monitor registered while the target is terminating must still get
    exactly one DownMessage (the old unlocked liveness check could land
    after the terminate snapshot and deliver nothing)."""
    for _ in range(50):
        target = system.spawn(lambda x: x)
        inbox, got = [], threading.Event()
        w = system.spawn(lambda m: (inbox.append(m), got.set()))
        t = threading.Thread(target=target.exit, args=(None,))
        t.start()
        system.monitor(w, target)
        t.join()
        assert got.wait(10)
        assert len(inbox) == 1
        assert isinstance(inbox[0], DownMessage)
        assert inbox[0].actor_id == target.actor_id


def test_link_to_dying_actor_delivers_exit(system):
    """Linking to an actor racing into termination must never leave a
    one-sided link: the living side always receives an ExitMessage."""
    for _ in range(50):
        victim = system.spawn(lambda x: x)
        other = system.spawn(lambda x: x)
        t = threading.Thread(target=victim.exit, args=("bye",))
        t.start()
        system.link(other, victim)
        t.join()
        deadline = time.monotonic() + 10
        while other.is_alive() and time.monotonic() < deadline:
            time.sleep(0.001)
        assert not other.is_alive()


def test_shutdown_concurrent_with_enqueue_strands_no_future(system):
    """Requests racing a shutdown must all resolve (result or
    ActorFailed) — the old mailbox-append-after-unlocked-check could
    strand a reply future forever."""
    refs = [system.spawn(lambda x: x) for _ in range(4)]
    futs, stop = [], threading.Event()

    def hammer():
        i = 0
        while not stop.is_set():
            futs.append(refs[i % len(refs)].request(i))
            i += 1

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.05)
    system.shutdown()
    stop.set()
    for t in threads:
        t.join()
    for f in futs:
        try:
            f.result(timeout=10)   # either a value or ActorFailed — never a hang
        except ActorFailed:
            pass


def test_ask_uses_system_default_timeout_and_names_actor():
    """ISSUE 5 satellite: ask() threads ActorSystem.default_ask_timeout
    and the TimeoutError names the actor id and its liveness."""
    from concurrent.futures import TimeoutError as FuturesTimeout

    s = ActorSystem(max_workers=2, default_ask_timeout=0.1)
    try:
        sleeper = s.spawn(lambda: time.sleep(5))
        with pytest.raises(FuturesTimeout) as ei:
            sleeper.ask()
        msg = str(ei.value)
        assert f"#{sleeper.actor_id}" in msg
        assert "alive" in msg
        assert "0.1" in msg
        # explicit timeout still wins over the system default
        fast = s.spawn(lambda x: x)
        assert fast.ask(1, timeout=10) == 1
    finally:
        s.shutdown()


def test_chain_future_cancellation_propagates_to_promise(system):
    """Cancelling the outer request() future cancels the delegated
    promise instead of leaking the in-flight work."""
    from concurrent.futures import Future

    promise = Future()
    delegated = system.spawn(lambda: promise)
    outer = delegated.request()
    deadline = time.monotonic() + 10
    while not promise._done_callbacks and time.monotonic() < deadline:
        time.sleep(0.005)   # wait for the delegation to be wired up
    assert outer.cancel()
    assert promise.cancelled()


def test_reply_after_cancel_does_not_crash_actor(system):
    """A reply future cancelled while the actor is mid-compute must be
    swallowed when the actor finishes — the set_result on a cancelled
    future must never crash the resolving actor."""
    started = threading.Event()

    def slow(x):
        started.set()
        time.sleep(0.2)
        return x

    ref = system.spawn(slow)
    fut = ref.request(1)
    assert started.wait(10)
    assert fut.cancel()          # mailbox futures are never 'running'
    time.sleep(0.4)              # let the actor finish and try to resolve
    assert ref.is_alive()
    assert ref.ask(2, timeout=10) == 2
