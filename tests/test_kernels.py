"""Per-kernel allclose sweeps vs the ref.py oracles (interpret=True on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(1234)


# ----------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (128, 256, 256), (384, 128, 384)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_matmul_sweep(m, k, n, dtype):
    a = RNG.standard_normal((m, k), np.float32).astype(dtype)
    b = RNG.standard_normal((k, n), np.float32).astype(dtype)
    got = ops.matmul(a, b, impl="pallas")
    want = ref.matmul(jnp.asarray(a), jnp.asarray(b))
    tol = 2e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_matmul_nondivisible_falls_back():
    a = RNG.standard_normal((100, 100), np.float32)
    b = RNG.standard_normal((100, 100), np.float32)
    got = ops.matmul(jnp.asarray(a), jnp.asarray(b), impl="auto")
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------------------
@pytest.mark.parametrize("h,w,it", [(8, 128, 16), (16, 256, 64), (24, 128, 100)])
def test_mandelbrot_sweep(h, w, it):
    kw = dict(height=h, width=w, max_iter=it, re_min=-0.5, re_max=0.1,
              im_min=-0.7375, im_max=-0.1375)
    np.testing.assert_array_equal(np.asarray(ops.mandelbrot(impl="pallas", **kw)),
                                  np.asarray(ops.mandelbrot(impl="ref", **kw)))


def test_mandelbrot_row_offset_consistency():
    """Fractional offload slices must tile to the full image (paper §5.4)."""
    kw = dict(width=128, max_iter=32, re_min=-2.0, re_max=1.0,
              im_min=-1.5, im_max=1.5)
    full = np.asarray(ops.mandelbrot(height=32, total_height=32, impl="pallas", **kw))
    top = np.asarray(ops.mandelbrot(height=16, row_offset=0, total_height=32,
                                    impl="pallas", **kw))
    bottom = np.asarray(ops.mandelbrot(height=16, row_offset=16, total_height=32,
                                       impl="pallas", **kw))
    np.testing.assert_array_equal(np.vstack([top, bottom]), full)


# ----------------------------------------------------------------------------
@pytest.mark.parametrize("n,bs", [(256, 256), (1024, 256), (2048, 512)])
@pytest.mark.parametrize("density", [0.0, 0.3, 1.0])
def test_stream_compact_sweep(n, bs, density):
    mask = RNG.random(n) < density
    x = (RNG.integers(1, 2**32, n, dtype=np.uint64).astype(np.uint32)) * mask
    got, cnt = ops.stream_compact(jnp.asarray(x), bs=bs, impl="pallas")
    want, wcnt = ref.stream_compact(jnp.asarray(x))
    assert int(cnt) == int(wcnt) == int(mask.sum())
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_stream_compact_order_preserved():
    x = np.array([5, 0, 7, 0, 0, 9, 1, 0] * 32, np.uint32)
    got, cnt = ops.stream_compact(jnp.asarray(x), bs=256, impl="pallas")
    survivors = x[x != 0]
    np.testing.assert_array_equal(np.asarray(got)[:int(cnt)], survivors)


# ----------------------------------------------------------------------------
@pytest.mark.parametrize("n", [256, 1024, 4096])
@pytest.mark.parametrize("bits", [4, 8])
def test_radix_sort_sweep(n, bits):
    keys = RNG.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    vals = np.arange(n, dtype=np.int32)
    kp, vp = ops.radix_sort(jnp.asarray(keys), jnp.asarray(vals),
                            bits_per_pass=bits, impl="pallas")
    np.testing.assert_array_equal(np.asarray(kp), np.sort(keys))
    # payload permuted consistently
    np.testing.assert_array_equal(keys[np.asarray(vp)], np.asarray(kp))


def test_radix_sort_stability():
    """Equal keys keep input order (required by the WAH pipeline)."""
    keys = np.array([3, 1, 3, 1, 2, 3, 1, 2] * 32, np.uint32)
    vals = np.arange(keys.size, dtype=np.int32)
    _, vp = ops.radix_sort(jnp.asarray(keys), jnp.asarray(vals), impl="pallas")
    vp = np.asarray(vp)
    for key in (1, 2, 3):
        positions = vp[np.sort(np.flatnonzero(keys[vp] == key))]
        assert (np.diff(positions) > 0).all()


def test_radix_sort_16bit_oracle_path():
    keys = RNG.integers(0, 2**32, 512, dtype=np.uint64).astype(np.uint32)
    kp = ops.radix_sort(jnp.asarray(keys), bits_per_pass=16)
    np.testing.assert_array_equal(np.asarray(kp), np.sort(keys))


# ----------------------------------------------------------------------------
@pytest.mark.parametrize("n,bs", [(512, 512), (2048, 512), (1024, 256)])
def test_wah_interleave_sweep(n, bs):
    f = RNG.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    l = RNG.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    got = ops.wah_interleave(jnp.asarray(f), jnp.asarray(l), bs=bs, impl="pallas")
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.wah_interleave(jnp.asarray(f),
                                                                jnp.asarray(l))))


# ----------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,hkv,sq,skv,d", [
    (1, 2, 2, 128, 128, 64),     # MHA square
    (2, 4, 2, 128, 256, 64),     # GQA, kv longer (decode-ish)
    (1, 8, 1, 64, 128, 128),     # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, hkv, sq, skv, d, causal):
    q = RNG.standard_normal((b, h, sq, d), np.float32)
    k = RNG.standard_normal((b, hkv, skv, d), np.float32)
    v = RNG.standard_normal((b, hkv, skv, d), np.float32)
    got = ops.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                              causal=causal, impl="pallas", bq=64, bk=64)
    want = ref.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                               causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [32, 100, 256])
def test_flash_attention_local_window(window):
    q = RNG.standard_normal((1, 2, 128, 64), np.float32)
    k = RNG.standard_normal((1, 2, 256, 64), np.float32)
    v = RNG.standard_normal((1, 2, 256, 64), np.float32)
    got = ops.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                              causal=True, window=window, impl="pallas",
                              bq=64, bk=64)
    want = ref.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                               causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    q = RNG.standard_normal((1, 2, 128, 64), np.float32).astype(jnp.bfloat16)
    k = RNG.standard_normal((1, 2, 128, 64), np.float32).astype(jnp.bfloat16)
    v = RNG.standard_normal((1, 2, 128, 64), np.float32).astype(jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True, impl="pallas", bq=64, bk=64)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)
