"""Paged KV-cache pool + disaggregated prefill/decode (ISSUE 6 surface).

Covers: page alloc/release accounting against the DeviceRef registry,
write_pages/gather roundtrips, page-table two-phase append (boundary
allocation, copy-on-write at a shared tail), prefix sharing (same Page
objects, exactly-once allocation, pin survival and eviction), the
prefix-safety guarantees (AccessViolation on a sealed write — both
directly and through the decode worker — and COW divergence leaving the
sibling's pages byte-identical), the paged ServeEngine end-to-end (zero
host transfers on the prefill→decode handoff), exactly-once replay of a
crashed prefill worker, and the page-pressure fields in
``DeviceManager.memory_stats()``.
"""
import gc
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AccessViolation, ActorSystem, live_ref_count,
                        transfer_count)
from repro.core.memref import tree_release
from repro.serve import (PagePool, PageTable, PoolExhausted, ServeEngine,
                         make_paged_decode_worker, make_prefill_worker)


@pytest.fixture(scope="module")
def system():
    s = ActorSystem(max_workers=8)
    yield s
    s.shutdown()


def ref_baseline():
    """Live-ref baseline for leak checks (GC first: other test modules may
    have dropped refs whose __del__ hasn't run yet)."""
    gc.collect()
    return live_ref_count()


def assert_refs_settle(baseline: int, timeout: float = 5.0) -> None:
    """Leak check that tolerates in-flight releases (stray done-callbacks
    from earlier test modules may still be draining): poll with GC instead
    of sampling once. A real leak still fails — the count never comes back
    down to the baseline."""
    deadline = time.monotonic() + timeout
    while True:
        gc.collect()
        n = live_ref_count()
        if n <= baseline:
            return
        if time.monotonic() > deadline:
            assert n == baseline, f"{n - baseline} DeviceRefs leaked"
        time.sleep(0.02)


# ----------------------------------------------------------------------------
# toy paged model: single leaf [T, 1] holding the token value as float;
# next token = (sum of context + last token) mod 997
# ----------------------------------------------------------------------------
MOD = 997


def toy_prefill(prompt):
    arr = jnp.asarray(np.asarray(prompt, dtype=np.float32)).reshape(-1, 1)
    return [arr], int(np.sum(np.asarray(prompt)) % MOD)


def toy_paged_step(kv, lengths, tokens):
    k = kv[0]  # [B, T, 1]
    T = k.shape[1]
    mask = (jnp.arange(T)[None, :] < lengths[:, None]).astype(k.dtype)
    s = jnp.sum(k[..., 0] * mask, axis=1)
    nxt = (s.astype(jnp.int32) + tokens) % MOD
    return nxt, [nxt.astype(jnp.float32)[:, None]]


def simulate(prompt, steps):
    h = list(prompt)
    last = sum(prompt) % MOD
    out = []
    for _ in range(steps):
        nxt = (sum(h) + last) % MOD
        out.append(nxt)
        h.append(nxt)
        last = nxt
    return out


def make_pool(**kw):
    kw.setdefault("page_tokens", 4)
    kw.setdefault("max_pages", 64)
    return PagePool([((1,), jnp.float32)], **kw)


# ----------------------------------------------------------------------------
# pool allocation / accounting
# ----------------------------------------------------------------------------
def test_alloc_release_accounting():
    base = ref_baseline()
    pool = make_pool()
    pages = [pool.alloc_page() for _ in range(3)]
    st = pool.stats()
    assert st["pages_live"] == 3
    assert st["pages_free"] == pool.max_pages - 3
    assert st["allocated"] == 3
    assert live_ref_count() == base + 3  # one leaf per page
    pool.release_pages(pages)
    st = pool.stats()
    assert st["pages_live"] == 0
    assert st["freed"] == 3
    assert st["peak_pages"] == 3
    assert_refs_settle(base)


def test_release_is_idempotent():
    pool = make_pool()
    page = pool.alloc_page()
    pool.release_page(page)
    pool.release_page(page)  # double release must not underflow
    assert pool.stats()["pages_live"] == 0
    assert pool.stats()["freed"] == 1


def test_pool_exhausted_raises():
    pool = make_pool(max_pages=2)
    pages = [pool.alloc_page(), pool.alloc_page()]
    with pytest.raises(PoolExhausted):
        pool.alloc_page()
    pool.release_pages(pages)
    pool.alloc_page()  # space again after release


def test_write_pages_gather_roundtrip():
    base = ref_baseline()
    pool = make_pool(page_tokens=4)
    vals = np.arange(10, dtype=np.float32).reshape(-1, 1)
    pages, length = pool.write_pages([jnp.asarray(vals)])
    assert length == 10
    assert len(pages) == 3           # ceil(10 / 4)
    assert [p.used for p in pages] == [4, 4, 2]
    table = PageTable(pool, pages=pages, length=length)
    (got,) = table.gather()
    np.testing.assert_array_equal(np.asarray(got[:10]), vals)
    np.testing.assert_array_equal(np.asarray(got[10:]),
                                  np.zeros((2, 1), np.float32))
    # partial tail page ⇒ internal fragmentation is visible
    assert 0.0 < pool.stats()["fragmentation"] < 1.0
    table.release_pages()
    assert_refs_settle(base)


def test_prepare_append_allocates_at_boundary():
    pool = make_pool(page_tokens=4)
    pages, length = pool.write_pages(
        [jnp.zeros((4, 1), jnp.float32)])      # exactly one full page
    table = PageTable(pool, pages=pages, length=length)
    assert table.capacity == 4
    tail, off = table.prepare_append()
    assert len(table.pages) == 2 and off == 0  # fresh page, offset 0
    table.commit_append([jnp.ones((4, 1), jnp.float32)])
    assert table.length == 5
    tail, off = table.prepare_append()
    assert len(table.pages) == 2 and off == 1  # same page, next slot
    table.release_pages()


def test_tree_release_recognizes_page_tables():
    # the ChunkScheduler reclaims a speculative-race loser's payload via
    # tree_release; a prefill result carrying a PageTable must be
    # reclaimed like any DeviceRef payload
    base = ref_baseline()
    pool = make_pool()
    pages, length = pool.write_pages([jnp.zeros((6, 1), jnp.float32)])
    table = PageTable(pool, pages=pages, length=length)
    tree_release((table, 7, False))
    assert pool.stats()["pages_live"] == 0
    assert_refs_settle(base)


# ----------------------------------------------------------------------------
# prefix sharing: exactly-once allocation, sealing, eviction
# ----------------------------------------------------------------------------
def test_prefix_sharing_maps_same_pages_exactly_once():
    base = ref_baseline()
    pool = make_pool()
    prefill = make_prefill_worker(toy_prefill, pool)
    prompt = [3, 1, 4, 1, 5, 9]
    t1, first1, hit1 = prefill("prefill", prompt)
    t2, first2, hit2 = prefill("prefill", prompt)
    assert (hit1, hit2) == (False, True)
    assert first1 == first2 == sum(prompt) % MOD
    # the *same* Page objects — shared, not duplicated
    assert [id(p) for p in t1.pages] == [id(p) for p in t2.pages]
    st = pool.stats()
    assert st["allocated"] == len(t1.pages)   # allocated exactly once
    assert st["prefix_hits"] == 1
    assert st["pages_shared"] == len(t1.pages)
    assert all(p.sealed for p in t1.pages)
    # both requests finish: pages survive via the prefix-cache pin
    t1.release_pages()
    t2.release_pages()
    assert pool.stats()["pages_live"] == len(pool._prefix[
        pool.prefix_key(prompt)].pages)
    assert pool.evict_prefixes() == 1
    assert pool.stats()["pages_live"] == 0
    assert_refs_settle(base)


def test_prefix_cache_lru_cap():
    pool = make_pool(max_prefixes=2)
    prefill = make_prefill_worker(toy_prefill, pool)
    tables = [prefill("prefill", [i, i])[0] for i in range(3)]
    assert pool.stats()["prefix_entries"] == 2
    assert pool.stats()["prefix_evicted"] == 1
    for t in tables:
        t.release_pages()
    pool.evict_prefixes()


def test_allocation_pressure_evicts_idle_prefixes():
    pool = make_pool(page_tokens=4, max_pages=1)
    prefill = make_prefill_worker(toy_prefill, pool)
    t1, _, _ = prefill("prefill", [1, 2])
    t1.release_pages()                 # now held only by the cache pin
    assert pool.stats()["pages_live"] == 1
    t2, _, _ = prefill("prefill", [5, 6])   # needs space → evicts idle entry
    assert pool.stats()["prefix_evicted"] >= 1
    t2.release_pages()
    pool.evict_prefixes()


# ----------------------------------------------------------------------------
# satellite 3: prefix-safety guarantees
# ----------------------------------------------------------------------------
def test_sealed_page_write_raises_access_violation():
    pool = make_pool()
    prefill = make_prefill_worker(toy_prefill, pool)
    table, _, _ = prefill("prefill", [1, 2, 3])
    sealed = table.pages[-1]
    assert sealed.shared
    sealed.arrays()                    # reading a sealed page is fine
    with pytest.raises(AccessViolation):
        sealed.writable_arrays()
    with pytest.raises(AccessViolation):
        sealed._replace([jnp.zeros((4, 1), jnp.float32)])
    table.release_pages()
    pool.evict_prefixes()


def test_decode_worker_rejects_shared_tail():
    # a decode worker handed a still-shared (read-restricted) tail page
    # must fail loudly before any compute, not corrupt the prefix
    pool = make_pool()
    prefill = make_prefill_worker(toy_prefill, pool)
    table, first, _ = prefill("prefill", [1, 2, 3])
    decode = make_paged_decode_worker(toy_paged_step, pool)
    with pytest.raises(AccessViolation):
        decode("pstep", (first,), ((tuple(table.pages), table.length),))
    table.release_pages()
    pool.evict_prefixes()


def test_cow_divergence_leaves_sibling_byte_identical():
    base = ref_baseline()
    pool = make_pool(page_tokens=4)
    prefill = make_prefill_worker(toy_prefill, pool)
    prompt = [1, 2, 3, 4, 5, 6]        # length 6: full page + partial tail
    ta, first, _ = prefill("prefill", prompt)
    tb, _, _ = prefill("prefill", prompt)
    assert ta.pages[-1] is tb.pages[-1]
    (before,) = tb.gather()
    before = np.asarray(before).copy()
    # request A diverges: prepare_append must COW the shared tail...
    tail_before = ta.pages[-1]
    tail, off = ta.prepare_append()
    assert tail is not tail_before and not tail.shared
    assert off == ta.tail_offset() == 2
    assert pool.stats()["cow"] == 1
    # ...and A's committed write lands only in its private clone
    new = tail.writable_arrays()[0].at[off].set(999.0)
    ta.commit_append([new])
    (ga,) = ta.gather()
    assert np.asarray(ga)[6, 0] == 999.0
    (after,) = tb.gather()
    np.testing.assert_array_equal(np.asarray(after), before)  # untouched
    ta.release_pages()
    tb.release_pages()
    pool.evict_prefixes()
    assert_refs_settle(base)


# ----------------------------------------------------------------------------
# paged ServeEngine end-to-end
# ----------------------------------------------------------------------------
def test_engine_paged_end_to_end(system):
    base = ref_baseline()
    pool = make_pool(page_tokens=4, max_pages=128)
    engine = ServeEngine(system, step_fn=toy_paged_step, cache_pool=pool,
                         prefill_fn=toy_prefill, prefill_workers=2,
                         n_workers=2, max_batch=4, step_timeout=60.0)
    t0 = transfer_count()
    with engine:
        futs = [engine.submit([i, i + 1, i + 2], max_new_tokens=6)
                for i in range(8)]
        results = [f.result(timeout=120) for f in futs]
    for i, r in enumerate(results):
        assert r.tokens == simulate([i, i + 1, i + 2], 6), f"request {i}"
    # the prefill→decode handoff is pure in-process ref passing
    assert transfer_count() - t0 == 0
    st = engine.stats()
    assert st["completed"] == 8
    assert st["prefills"] == 8
    assert 0.0 < st["occupancy"] <= 1.0
    assert st["pool"]["pages_live"] >= 0
    pool.evict_prefixes()
    assert_refs_settle(base)


def test_engine_paged_prefix_hits_across_requests(system):
    pool = make_pool(page_tokens=4, max_pages=128)
    engine = ServeEngine(system, step_fn=toy_paged_step, cache_pool=pool,
                         prefill_fn=toy_prefill, prefill_workers=1,
                         n_workers=2, max_batch=4, step_timeout=60.0)
    prompt = [7, 7, 7, 7]
    with engine:
        futs = [engine.submit(prompt, max_new_tokens=3) for _ in range(4)]
        results = [f.result(timeout=120) for f in futs]
    expected = simulate(prompt, 3)
    assert all(r.tokens == expected for r in results)
    st = engine.stats()
    assert st["prefix_hits"] == 3              # first miss, three hits
    assert st["pool"]["allocated"] >= 1
    # identical prompts never re-allocated their prefix pages (COW clones
    # and fresh decode tails are the only other allocations)
    assert sum(1 for r in results if r.prefix_hit) == 3
    pool.evict_prefixes()


def test_engine_paged_prefill_crash_replays_exactly_once(system):
    crashes = [1]

    def flaky_prefill(prompt):
        if crashes and crashes.pop():
            raise RuntimeError("injected prefill crash")
        return toy_prefill(prompt)

    base = ref_baseline()
    pool = make_pool(page_tokens=4, max_pages=64)
    engine = ServeEngine(system, step_fn=toy_paged_step, cache_pool=pool,
                         prefill_fn=flaky_prefill, prefill_workers=2,
                         n_workers=2, max_batch=4, step_timeout=60.0)
    with engine:
        fut = engine.submit([2, 3, 4], max_new_tokens=4)
        res = fut.result(timeout=120)
    assert res.tokens == simulate([2, 3, 4], 4)   # replay, exactly once
    st = engine.stats()
    assert st["prefill_dispatch"]["failed"] >= 1  # the crash was real
    pool.evict_prefixes()
    assert_refs_settle(base)


def test_engine_paged_validation():
    pool = make_pool()
    with pytest.raises(ValueError):               # no prefill_fn
        ServeEngine(object(), step_fn=toy_paged_step, cache_pool=pool)
    with pytest.raises(ValueError):               # init_fn in paged mode
        ServeEngine(object(), step_fn=toy_paged_step, cache_pool=pool,
                    prefill_fn=toy_prefill, init_fn=lambda p: (None, 0))


# ----------------------------------------------------------------------------
# satellite 2: page pressure in DeviceManager.memory_stats()
# ----------------------------------------------------------------------------
def test_memory_stats_reports_page_pressure(system):
    pool = make_pool(max_pages=32)
    pages = [pool.alloc_page() for _ in range(2)]
    stats = system.opencl_manager().memory_stats()
    dev = next(iter(stats.values()))
    for key in ("pages_total", "pages_free", "pages_shared",
                "fragmentation"):
        assert key in dev
    total = sum(d["pages_total"] for d in stats.values())
    free = sum(d["pages_free"] for d in stats.values())
    assert total >= 32
    assert total - free >= 2            # our two live pages are visible
    pool.release_pages(pages)
