"""qwen2-vl-2b [arXiv:2409.12191; hf]
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 — M-RoPE, dynamic
resolution (vision frontend stubbed as precomputed patch embeddings)."""
from repro.configs.base import ModelConfig

ARCH = "qwen2-vl-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="vlm", n_layers=28, d_model=1536, n_heads=12,
        n_kv_heads=2, d_ff=8960, vocab_size=151936, head_dim=128,
        mlp="swiglu", attn_bias=True, m_rope=True,
        mrope_sections=(16, 24, 24), n_vision_tokens=256,
        tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        mlp="swiglu", attn_bias=True, m_rope=True, mrope_sections=(2, 3, 3),
        n_vision_tokens=8, tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32")
