"""The paper's own workload (§4): WAH bitmap indexing — not an LM.

Kept in the registry so ``--arch wah-indexing`` selects the indexing
pipeline in examples/benchmarks."""
ARCH = "wah-indexing"

DEFAULT_N = 1 << 20        # input values
DEFAULT_CARDINALITY = 256  # distinct values
