"""Model/arch configuration schema.

Every assigned architecture gets a ``configs/<id>.py`` exporting
``config()`` (the exact published shape) and ``smoke_config()`` (a reduced
same-family config for CPU smoke tests). The registry in
``configs/__init__`` resolves ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    #: routing group length; capacity C = ⌈k·g/E·cf⌉ is independent of S
    group_size: int = 4096


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128       # N
    head_dim: int = 64         # P
    expand: int = 2            # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256           # SSD chunk length
    n_groups: int = 1          # B/C groups


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    #: repeating unit of temporal mixers, e.g. ("rec", "rec", "attn")
    pattern: Tuple[str, ...] = ()
    window: int = 2048         # local-attention window
    lru_width: Optional[int] = None
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 0
    n_frames: int = 1500       # stub frontend: precomputed frame embeddings


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention details
    qk_norm: bool = False
    attn_bias: bool = False            # qwen1.5 QKV bias
    attn_logit_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    m_rope: bool = False               # qwen2-vl 3-axis rotary
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # mlp
    mlp: str = "swiglu"                # swiglu | geglu | gelu | relu2
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    tie_embeddings: bool = False
    # sub-configs
    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()
    hybrid: HybridConfig = HybridConfig()
    encdec: EncDecConfig = EncDecConfig()
    # vlm stub frontend
    n_vision_tokens: int = 0
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # distribution knobs (overridable per run)
    remat: str = "full"                # none | full
    scan_layers: bool = True
    #: long-context support class, used to decide long_500k applicability
    subquadratic: bool = False

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper is enc-dec)

    def padded_vocab(self, multiple: int) -> int:
        return ((self.vocab_size + multiple - 1) // multiple) * multiple

    def dtype(self) -> jnp.dtype:
        return jnp.dtype(self.compute_dtype)

    def param_count(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS and reporting)."""
        d, f, v, l = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.resolved_head_dim
        qkvo = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + \
            self.n_heads * hd * d
        gated = self.mlp in ("swiglu", "geglu")
        mlp = d * f * (3 if gated else 2)
        if self.family == "moe":
            mlp *= self.moe.n_experts
            mlp += d * self.moe.n_experts  # router
        if self.family == "ssm":
            di = self.ssm.expand * d
            n = self.ssm.state_dim
            nh = di // self.ssm.head_dim
            g = self.ssm.n_groups
            qkvo = d * (2 * di + 2 * g * n + nh) + di * d
            mlp = 0
        if self.family == "hybrid":
            lru = self.hybrid.lru_width or d
            rec = d * lru * 2 + lru * d + 3 * lru  # branches + out + gates
            att = qkvo
            pat = self.hybrid.pattern or ("rec",)
            frac_rec = sum(1 for p in pat if p == "rec") / len(pat)
            qkvo = rec * frac_rec + att * (1 - frac_rec)
        emb = v * d * (1 if self.tie_embeddings else 2)
        enc = 0
        if self.family == "encdec":
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            enc = self.encdec.n_enc_layers * (qkvo + mlp)
            qkvo = 2 * qkvo  # decoder self + cross
        return int(l * (qkvo + mlp) + emb + enc)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f, l = self.d_model, self.d_ff, self.n_layers
        gated = self.mlp in ("swiglu", "geglu")
        per_expert = d * f * (3 if gated else 2)
        total = self.param_count()
        inactive = l * per_expert * (self.moe.n_experts - self.moe.top_k)
        return int(total - inactive)
