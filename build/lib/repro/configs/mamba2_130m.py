"""mamba2-130m [arXiv:2405.21060; unverified]
24L d_model=768 (attention-free) vocab=50280, ssm_state=128 — SSD."""
from repro.configs.base import ModelConfig, SSMConfig

ARCH = "mamba2-130m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="ssm", n_layers=24, d_model=768, n_heads=0,
        n_kv_heads=0, d_ff=0, vocab_size=50280, tie_embeddings=True,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                      chunk=256),
        subquadratic=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=256, tie_embeddings=True,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                      chunk=16),
        subquadratic=True, param_dtype="float32", compute_dtype="float32")
