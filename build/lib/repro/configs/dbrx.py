"""dbrx-132b [hf:databricks/dbrx-base; unverified]
40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4."""
from repro.configs.base import ModelConfig, MoEConfig

ARCH = "dbrx-132b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe", n_layers=40, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=10752, vocab_size=100352, head_dim=128,
        mlp="swiglu", moe=MoEConfig(n_experts=16, top_k=4))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        mlp="swiglu", moe=MoEConfig(n_experts=4, top_k=4),
        param_dtype="float32", compute_dtype="float32")
