"""qwen1.5-32b [hf:Qwen/Qwen1.5 family; hf]
64L d_model=5120 40H (kv=40, MHA) d_ff=27392 vocab=152064 — QKV bias."""
from repro.configs.base import ModelConfig

ARCH = "qwen1.5-32b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=64, d_model=5120, n_heads=40,
        n_kv_heads=40, d_ff=27392, vocab_size=152064, head_dim=128,
        attn_bias=True, mlp="swiglu")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
        attn_bias=True, mlp="swiglu",
        param_dtype="float32", compute_dtype="float32")
