"""nemotron-4-340b [arXiv:2402.16819; unverified]
96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000 — squared-ReLU."""
from repro.configs.base import ModelConfig

ARCH = "nemotron-4-340b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=96, d_model=18432, n_heads=96,
        n_kv_heads=8, d_ff=73728, vocab_size=256000, head_dim=192,
        mlp="relu2")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        mlp="relu2", param_dtype="float32", compute_dtype="float32")
