"""llama3-8b [arXiv:2407.21783; unverified]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256 — GQA, 128k vocab."""
from repro.configs.base import ModelConfig

ARCH = "llama3-8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=14336, vocab_size=128256, head_dim=128,
        mlp="swiglu", rope_theta=500_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        mlp="swiglu", param_dtype="float32", compute_dtype="float32")
