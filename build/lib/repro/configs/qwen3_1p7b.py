"""qwen3-1.7b [hf:Qwen/Qwen3-8B family; hf]
28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936 — qk_norm, GQA."""
from repro.configs.base import ModelConfig

ARCH = "qwen3-1.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=28, d_model=2048, n_heads=16,
        n_kv_heads=8, d_ff=6144, vocab_size=151936, head_dim=128,
        qk_norm=True, mlp="swiglu", tie_embeddings=True,
        rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        qk_norm=True, mlp="swiglu", tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32")
