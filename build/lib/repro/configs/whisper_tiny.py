"""whisper-tiny [arXiv:2212.04356; unverified]
4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 — enc-dec, conv frontend
stub (input_specs provides precomputed frame embeddings)."""
from repro.configs.base import EncDecConfig, ModelConfig

ARCH = "whisper-tiny"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="encdec", n_layers=4, d_model=384, n_heads=6,
        n_kv_heads=6, d_ff=1536, vocab_size=51865, head_dim=64,
        mlp="gelu", norm="layernorm", tie_embeddings=True,
        encdec=EncDecConfig(n_enc_layers=4, n_frames=1500))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="encdec", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
        mlp="gelu", norm="layernorm", tie_embeddings=True,
        encdec=EncDecConfig(n_enc_layers=2, n_frames=32),
        param_dtype="float32", compute_dtype="float32")
