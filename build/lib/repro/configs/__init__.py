"""Architecture registry: ``--arch <id>`` → exact published config.

Every assigned architecture has ``configs/<id>.py`` with ``config()``
(full shape, dry-run only) and ``smoke_config()`` (reduced, CPU-testable).
"""
from . import (dbrx, llama3_8b, mamba2_130m, nemotron4_340b, phi35_moe,
               qwen2_vl, qwen3_1p7b, qwen15_32b, recurrentgemma_9b,
               whisper_tiny)
from .base import ModelConfig

_MODULES = {
    m.ARCH: m
    for m in (phi35_moe, dbrx, whisper_tiny, qwen2_vl, mamba2_130m,
              qwen3_1p7b, qwen15_32b, nemotron4_340b, llama3_8b,
              recurrentgemma_9b)
}

ARCHS = tuple(_MODULES)

#: assigned input shapes: name → (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].smoke_config()


def list_archs():
    return list(ARCHS)


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    """long_500k needs sub-quadratic attention (assignment; DESIGN.md §5)."""
    if shape == "long_500k":
        return cfg.subquadratic
    return True
