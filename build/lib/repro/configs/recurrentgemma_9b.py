"""recurrentgemma-9b [arXiv:2402.19427; unverified]
38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000 — RG-LRU + local
attention, 1:2 pattern, window 2048."""
from repro.configs.base import HybridConfig, ModelConfig

ARCH = "recurrentgemma-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="hybrid", n_layers=38, d_model=4096, n_heads=16,
        n_kv_heads=1, d_ff=12288, vocab_size=256000, head_dim=256,
        mlp="geglu", tie_embeddings=True,
        hybrid=HybridConfig(pattern=("rec", "rec", "attn"), window=2048,
                            lru_width=4096, conv_width=4),
        subquadratic=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=1, d_ff=128, vocab_size=256, head_dim=16,
        mlp="geglu", tie_embeddings=True,
        hybrid=HybridConfig(pattern=("rec", "rec", "attn"), window=16,
                            lru_width=64, conv_width=4),
        subquadratic=True, param_dtype="float32", compute_dtype="float32")
