"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16e top-2."""
from repro.configs.base import ModelConfig, MoEConfig

ARCH = "phi3.5-moe-42b-a6.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=6400, vocab_size=32064, head_dim=128,
        mlp="swiglu", moe=MoEConfig(n_experts=16, top_k=2))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        mlp="swiglu", moe=MoEConfig(n_experts=4, top_k=2),
        param_dtype="float32", compute_dtype="float32")
