"""Distribution layer: sharding rules, train/serve steps, collectives,
fault tolerance, and pipeline parallelism — all built on the unified
kernel-actor surface in ``repro.core`` (paper §3.5/§3.6 scaled up).

Modules:

* :mod:`repro.dist.api`         — sharding-hint context managers used by the
                                  model code (``hint``/``hint_vocab``/
                                  ``hint_named``).
* :mod:`repro.dist.sharding`    — the divisibility-aware sharding rule
                                  engine (params, optimizer state, batches,
                                  KV caches) for GSPMD meshes.
* :mod:`repro.dist.step`        — train/serve step builders (grad accum,
                                  LR schedules, greedy decode).
* :mod:`repro.dist.collectives` — int8-compressed all-reduce with error
                                  feedback.
* :mod:`repro.dist.fault`       — supervised checkpoint/restart training
                                  and elastic data parallelism, built on
                                  the actor monitor/link substrate.
* :mod:`repro.dist.pipeline`    — pipeline parallelism from stage actors,
                                  a consumer of :class:`repro.core.Pipeline`.
"""
from . import api, collectives, fault, pipeline, sharding, step

__all__ = ["api", "collectives", "fault", "pipeline", "sharding", "step"]
