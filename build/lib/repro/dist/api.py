"""Sharding-hint API: the model code calls ``hint``/``hint_vocab``/
``hint_named`` unconditionally; outside a distribution context they are
identity functions, inside one (``dryrun_lib`` lowering a pod-scale cell)
they pin intermediate activations with ``with_sharding_constraint``.

This indirection keeps the model pure: layers never import mesh or
``NamedSharding`` types, the launcher decides placement (DESIGN.md §6).
Contexts are thread-local so concurrent actor-driven lowerings do not
leak constraints into each other.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional

import jax

__all__ = [
    "activation_sharding", "vocab_sharding", "spec_map",
    "hint", "hint_vocab", "hint_named",
]

_state = threading.local()


def _get(name: str):
    return getattr(_state, name, None)


@contextlib.contextmanager
def activation_sharding(sharding):
    """Pin the residual stream ([B, S, D]) to ``sharding`` within scope."""
    prev = _get("act")
    _state.act = sharding
    try:
        yield
    finally:
        _state.act = prev


@contextlib.contextmanager
def vocab_sharding(sharding):
    """Pin vocab-dim tensors ([B, S, V]) to ``sharding`` within scope."""
    prev = _get("vocab")
    _state.vocab = sharding
    try:
        yield
    finally:
        _state.vocab = prev


@contextlib.contextmanager
def spec_map(mapping: Optional[Dict[str, Any]]):
    """Named-site constraints (Megatron-style TP output pins). ``mapping``
    maps hint-site names (``attn_q``, ``attn_kv``, ``mlp_hidden``) to
    shardings; ``None`` disables all named hints."""
    prev = _get("specmap")
    _state.specmap = mapping
    try:
        yield
    finally:
        _state.specmap = prev


def _constrain(x, sharding):
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


def hint(x):
    """Constrain a residual-stream activation (no-op outside a context)."""
    return _constrain(x, _get("act"))


def hint_vocab(x):
    """Constrain a vocab-dim tensor (no-op outside a context)."""
    return _constrain(x, _get("vocab"))


def hint_named(x, name: str):
    """Constrain a named hint site, if the active spec map pins it."""
    mapping = _get("specmap")
    if not mapping:
        return x
    return _constrain(x, mapping.get(name))
