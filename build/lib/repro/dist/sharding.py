"""Divisibility-aware sharding rule engine (DESIGN.md §6).

Maps parameter / optimizer / batch / KV-cache pytrees onto a GSPMD mesh
with ``data`` (+ optional ``pod``) and ``model`` axes. Rules are keyed by
the leaf's path name (param trees are transparent dicts — see
``models/layers.py``), and every rule is guarded by divisibility: a
dimension that does not divide the axis size falls back to replication
instead of failing to lower (e.g. mamba2's 3352-wide ``in_proj`` shards
on an 8-way mesh but replicates on a 16-way one).

Conventions:

* column-parallel weights (``wq``/``wk``/``wv``/``w_up``/``w_gate``/
  ``in_proj`` …) shard their output (last) dim on ``model``;
* row-parallel weights (``wo``/``w_out``/``out_proj``) shard their
  contraction dim (second-to-last) on ``model`` — the Megatron pairing
  that keeps one all-reduce per block;
* the embedding table shards its vocab rows, the LM head its vocab
  columns (both padded to the mesh via ``cfg.padded_vocab``);
* everything else (norm scales, biases, routers, positional tables)
  replicates;
* ``Plan(fsdp=True)`` additionally shards the largest remaining big dim
  over the data axes (ZeRO-3-equivalent since optimizer state mirrors
  parameter shardings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, FlattenedIndexKey, GetAttrKey, SequenceKey

__all__ = [
    "MODEL_AXIS", "Plan", "data_axes",
    "param_shardings", "opt_state_shardings",
    "batch_shardings", "cache_shardings",
]

MODEL_AXIS = "model"

#: weights whose output (last) dim is model-sharded (column-parallel)
_COL_PARALLEL = {"wq", "wk", "wv", "bq", "bk", "bv",
                 "w_up", "w_gate", "in_proj", "w_x", "w_y"}
#: weights whose contraction (second-to-last) dim is model-sharded
_ROW_PARALLEL = {"wo", "w_out", "out_proj"}
#: lookup tables that must never shard their index dim
_REPLICATED = {"pos_embed", "router"}

#: smallest dim FSDP will split over the data axes — below this the
#: per-shard tile is not worth the gather traffic
_FSDP_MIN_DIM = 512


@dataclasses.dataclass(frozen=True)
class Plan:
    """Distribution knobs consumed by the rule engine."""

    fsdp: bool = False          # ZeRO param+opt sharding over the data axes
    kv_cache: str = "heads"     # decode KV layout: "heads" | "seq"


# ----------------------------------------------------------------------------
# mesh helpers
# ----------------------------------------------------------------------------
def data_axes(mesh) -> Tuple[str, ...]:
    """All non-model axes (``('data',)`` or ``('pod', 'data')``)."""
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)


def _axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _model_size(mesh) -> int:
    return _axis_sizes(mesh).get(MODEL_AXIS, 1)


def _data_size(mesh) -> int:
    sizes = _axis_sizes(mesh)
    return int(np.prod([sizes[a] for a in data_axes(mesh)])) if data_axes(mesh) else 1


def _dp_axes(mesh):
    """The data axes as a single PartitionSpec entry."""
    axes = data_axes(mesh)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def _dp_spec(mesh, n: Optional[int]):
    """PartitionSpec entry for a batch-like dim of size ``n``: the data
    axes when ``n`` divides their product, else ``None`` (replicate)."""
    if n is None:
        return None
    return _dp_axes(mesh) if n % _data_size(mesh) == 0 else None


def _path_name(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, DictKey):
            parts.append(str(k.key))
        elif isinstance(k, SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, GetAttrKey):
            parts.append(str(k.name))
        elif isinstance(k, FlattenedIndexKey):
            parts.append(str(k.key))
        else:  # pragma: no cover - future key types
            parts.append(str(k))
    return "/".join(parts)


# ----------------------------------------------------------------------------
# parameters
# ----------------------------------------------------------------------------
def _param_spec(name: str, shape: Tuple[int, ...], msize: int
                ) -> Tuple[Tuple, str]:
    """→ (per-dim spec entries, human-readable rule tag)."""
    leaf = name.rsplit("/", 1)[-1]
    nd = len(shape)
    spec = [None] * nd

    def divisible(i: int) -> bool:
        return shape[i] % msize == 0

    if leaf in _REPLICATED:
        return tuple(spec), "replicate(table)"
    if leaf == "embed" and nd == 2:
        if divisible(0):
            spec[0] = MODEL_AXIS
            return tuple(spec), "vocab-rows"
        return tuple(spec), "replicate(vocab%model!=0)"
    if leaf == "head" and nd >= 2:
        if divisible(nd - 1):
            spec[nd - 1] = MODEL_AXIS
            return tuple(spec), "vocab-cols"
        return tuple(spec), "replicate(vocab%model!=0)"
    if leaf in _COL_PARALLEL and nd >= 1:
        if divisible(nd - 1):
            spec[nd - 1] = MODEL_AXIS
            return tuple(spec), "column-parallel"
        return tuple(spec), f"replicate({shape[nd - 1]}%{msize}!=0)"
    if leaf in _ROW_PARALLEL and nd >= 2:
        if divisible(nd - 2):
            spec[nd - 2] = MODEL_AXIS
            return tuple(spec), "row-parallel"
        return tuple(spec), f"replicate({shape[nd - 2]}%{msize}!=0)"
    return tuple(spec), "replicate"


def _apply_fsdp(spec: Tuple, shape: Tuple[int, ...], mesh) -> Tuple:
    """Add the data axes on the largest unsharded big dim (if divisible)."""
    dsize = _data_size(mesh)
    if dsize <= 1:
        return spec
    cands = [i for i in range(len(shape))
             if spec[i] is None and shape[i] % dsize == 0
             and shape[i] >= _FSDP_MIN_DIM]
    if not cands:
        return spec
    best = max(cands, key=lambda i: (shape[i], i))
    out = list(spec)
    out[best] = _dp_axes(mesh)
    return tuple(out)


def param_shardings(shapes, cfg, mesh, plan: Optional[Plan] = None, *,
                    explain: Optional[Dict[str, Tuple[str, P]]] = None):
    """Parameter pytree (of arrays or ShapeDtypeStructs) → NamedShardings.

    ``explain``, when given, is filled with ``path → (rule, PartitionSpec)``
    so tests and the dry-run report can audit every placement decision.
    """
    plan = plan or Plan()
    msize = _model_size(mesh)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    out = []
    for path, leaf in leaves:
        name = _path_name(path)
        spec, rule = _param_spec(name, tuple(leaf.shape), msize)
        if plan.fsdp:
            fsdp_spec = _apply_fsdp(spec, tuple(leaf.shape), mesh)
            if fsdp_spec != spec:
                spec, rule = fsdp_spec, rule + "+fsdp"
        pspec = P(*spec)
        if explain is not None:
            explain[name] = (rule, pspec)
        out.append(NamedSharding(mesh, pspec))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_state_shardings(param_sh, mesh):
    """AdamW state shardings: first/second moments mirror the parameter
    shardings exactly (ZeRO-equivalent partitioning for free), the step
    counter replicates."""
    return {"m": param_sh, "v": param_sh,
            "count": NamedSharding(mesh, P())}


# ----------------------------------------------------------------------------
# batches
# ----------------------------------------------------------------------------
def batch_shardings(batch_specs: Dict[str, Any], mesh) -> Dict[str, Any]:
    """Input batches shard their leading (batch) dim over the data axes;
    a non-divisible batch (e.g. the B=1 long-context shape) replicates.
    ``positions`` is [3, B, S] — its batch dim is second."""
    out = {}
    for k, v in batch_specs.items():
        if k == "positions":
            out[k] = NamedSharding(
                mesh, P(None, _dp_spec(mesh, v.shape[1]), None))
        else:
            rest = (None,) * (len(v.shape) - 1)
            out[k] = NamedSharding(mesh, P(_dp_spec(mesh, v.shape[0]), *rest))
    return out


# ----------------------------------------------------------------------------
# KV / recurrent caches
# ----------------------------------------------------------------------------
def cache_shardings(cache_shapes, cfg, mesh, plan: Optional[Plan] = None):
    """Decode-cache shardings. KV leaves ([layers, B, S, Hkv, hd]) shard
    batch on data and, per ``plan.kv_cache``, either the sequence dim
    ("seq" — flash-decode split-K layout) or the kv-head dim ("heads") on
    model; recurrent/conv state shards batch only. Divisibility fallbacks
    apply per-dim as for parameters."""
    plan = plan or Plan()
    msize = _model_size(mesh)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = []
    for path, leaf in leaves:
        shape = tuple(leaf.shape)
        nd = len(shape)
        name = _path_name(path).rsplit("/", 1)[-1]
        if nd == 0:
            out.append(NamedSharding(mesh, P()))
            continue
        spec = [None] * nd
        if nd >= 2:
            spec[1] = _dp_spec(mesh, shape[1])  # batch dim
        if name in ("k", "v") and nd == 5:
            if plan.kv_cache == "seq":
                if shape[2] % msize == 0:
                    spec[2] = MODEL_AXIS
            elif shape[3] % msize == 0:
                spec[3] = MODEL_AXIS
        out.append(NamedSharding(mesh, P(*spec)))
    return jax.tree_util.tree_unflatten(treedef, out)
