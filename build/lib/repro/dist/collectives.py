"""Compressed collectives: int8-quantized all-reduce with error feedback.

At pod scale the gradient all-reduce is bandwidth-bound; quantizing each
shard's contribution to int8 with a per-shard absmax scale cuts the wire
bytes 4x at <1% relative error, and carrying the quantization residual
into the next step (error feedback, 1-bit-Adam-style) makes the *time
average* unbiased so training quality is preserved.

The ref-plane entry points (:func:`quantize_ref` / :func:`dequantize_ref`)
operate on :class:`~repro.core.memref.DeviceRef`\\ s at the host boundary:
the compressed payload stays device-resident as an int8 ref, and spilling
*that* ref at an explicit stage boundary (paper §3.5 option (b)) ships 4x
fewer bytes over the wire than spilling the float original.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro  # noqa: F401  — installs the jax.shard_map compat alias
from repro.core.memref import DeviceRef, as_device_array

__all__ = ["compressed_psum", "tree_psum_with_error_feedback",
           "quantize_ref", "dequantize_ref"]


def _quantize(x):
    """→ (int8 payload, f32 scale, dequantized value)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale, q.astype(jnp.float32) * scale


# payload+scale only: jitting the full _quantize would materialize (and
# discard) the float32 dequantized copy on every call
_quantize_wire = jax.jit(lambda x: _quantize(x)[:2])


def quantize_ref(x) -> tuple:
    """Compress an array or :class:`DeviceRef` to its int8 wire format.

    → ``(DeviceRef[int8], float scale)``. The payload never leaves the
    device; combined with ``DeviceRef.spill()`` this is the compressed
    host-serialization boundary (4x fewer wire bytes than the original).
    The input ref is *not* consumed.
    """
    q, scale = _quantize_wire(as_device_array(x))
    return DeviceRef(q), float(scale)


def dequantize_ref(q, scale: float, dtype=jnp.float32,
                   access: str = "rw") -> DeviceRef:
    """Inverse of :func:`quantize_ref`: expand an int8 payload (array or
    ref) back to a ``dtype`` ref on device. Relative error ≤ 1/254.
    ``access`` restores the original ref's rights (the wire format must
    not widen a restricted view back to ``rw``)."""
    arr = as_device_array(q)
    deq = (arr.astype(jnp.float32) * jnp.float32(scale)).astype(dtype)
    return DeviceRef(deq, access=access)


def compressed_psum(x, axis_name: str):
    """All-reduce-sum of int8-quantized shard contributions.

    Each shard quantizes with its own absmax scale, so the reduction runs
    over dequantized int8 payloads — per-shard relative error ≤ 1/254.
    """
    _, _, deq = _quantize(x)
    return jax.lax.psum(deq, axis_name).astype(x.dtype)


def tree_psum_with_error_feedback(grads, errors, axis_name: str):
    """Mean-reduce a gradient pytree through int8 quantization, carrying
    the per-shard quantization residual forward.

    → ``(mean_grads, new_errors)``; both pytrees match the input structure
    (bare arrays are treated as single-leaf trees).
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e.astype(jnp.float32)
        _, _, deq = _quantize(corrected)
        new_err = (corrected - deq).astype(e.dtype)
        mean = jax.lax.pmean(deq, axis_name).astype(g.dtype)
        return mean, new_err

    pairs = jax.tree.map(one, grads, errors)
    is_pair = lambda t: isinstance(t, tuple)
    mean = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    new_errors = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return mean, new_errors
