"""Pipeline parallelism from stage actors (DESIGN.md §4).

``make_layer_stage_actors`` slices a model's layer stack into contiguous
stages, each owned by one actor (one mesh slice at pod scale); the
:class:`PipelineRunner` streams microbatches through the stage chain with
a bounded in-flight depth — the paper's async event-chaining (Listing 4)
applied to 1F pipeline schedules: stage *n+1* of microbatch *i* overlaps
stage *n* of microbatch *i+1*.

The stage chain itself is built with the unified
:class:`repro.core.Pipeline` surface (``mode="staged"``), so the same
composition object covers kernel actors and model stages.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import ActorRef, ActorSystem
from repro.core.api import Pipeline
from repro.core.memref import DeviceRef, as_device_array
from repro.models.layers import apply_norm
from repro.models.transformer import embed_inputs, layer_groups, _apply_unit

__all__ = ["PipelineRunner", "make_layer_stage_actors"]


# ----------------------------------------------------------------------------
# stage construction
# ----------------------------------------------------------------------------
def _positions_for(cfg, b: int, s: int):
    base = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return jnp.broadcast_to(base, (3, b, s)) if cfg.m_rope else base


def _stage_fn(model, chunk_units, first: bool, last: bool,
              embed, final_norm, head):
    """A pure ``(chunk_params, x) → x`` function for one stage.

    The first stage embeds tokens; the last applies the final norm and LM
    head. Middle stages are pure residual-stream transforms, so only the
    [B, S, D] activation crosses actor boundaries."""
    cfg = model.cfg

    def stage(chunk_params, x):
        if first:
            tokens = x
            b, s = tokens.shape
            x = embed_inputs({"embed": embed}, cfg, tokens, None)
        else:
            b, s = x.shape[0], x.shape[1]
        positions = _positions_for(cfg, b, s)
        aux = jnp.zeros((), jnp.float32)
        for unit, lp in zip(chunk_units, chunk_params):
            x, aux = _apply_unit(lp, cfg, unit, x, positions, aux,
                                 model.attn_impl)
        if last:
            x = apply_norm(final_norm, x, cfg.norm)
            h = embed.T if cfg.tie_embeddings else head
            return x @ h.astype(x.dtype)
        return x

    return stage


def make_layer_stage_actors(system: ActorSystem, model, params,
                            n_stages: int) -> List[ActorRef]:
    """Split the layer stack into ``n_stages`` contiguous stage actors.

    The staged forward reproduces ``model.forward`` exactly (same per-layer
    ops in the same order); only the logits (not the MoE aux loss) leave
    the last stage."""
    cfg = model.cfg
    if cfg.family == "encdec":
        raise NotImplementedError("stage split targets decoder-only stacks")
    units: list = []  # (unit kinds, per-layer params)
    for gi, (unit, count) in enumerate(layer_groups(cfg)):
        gp = params["groups"][gi]
        for ci in range(count):
            units.append((unit, jax.tree.map(lambda a, ci=ci: a[ci], gp)))
    n_layers = len(units)
    if not 1 <= n_stages <= n_layers:
        raise ValueError(f"n_stages={n_stages} not in [1, {n_layers}]")
    sizes = [n_layers // n_stages + (1 if i < n_layers % n_stages else 0)
             for i in range(n_stages)]
    head = params.get("head")
    stages, lo = [], 0
    for si, sz in enumerate(sizes):
        chunk = units[lo:lo + sz]
        last = si == n_stages - 1
        lo += sz
        fn = _stage_fn(model, [u for u, _ in chunk],
                       first=(si == 0), last=last,
                       embed=params["embed"],
                       final_norm=params["final_norm"], head=head)
        jitted = jax.jit(fn)
        chunk_params = [p for _, p in chunk]

        # stages speak DeviceRef natively: inputs are unwrapped (host
        # microbatches are transferred once, by the first stage) and the
        # [B, S, D] activation crosses actor boundaries as a ref — the
        # composed chain releases it once the next stage has consumed it
        def _stage(x, _f=jitted, _p=chunk_params, _last=last):
            y = _f(_p, as_device_array(x))
            return y if _last else DeviceRef(y)

        stages.append(system.spawn(_stage))
    return stages


# ----------------------------------------------------------------------------
# microbatch streaming
# ----------------------------------------------------------------------------
class PipelineRunner:
    """Streams microbatches through a stage chain with ≤ ``depth`` in
    flight; results come back in submission order and the first stage
    failure aborts the run.

    :meth:`submit` is the asynchronous single-microbatch entry point —
    staged *serving* across layer actors drives it directly (one request's
    activations per call, concurrent up to ``depth``); :meth:`run` is the
    batch-mode loop over it.

    Construction takes either ``stages`` (a linear actor chain, built
    through the :class:`~repro.core.api.Pipeline` wrapper) **or**
    ``graph=`` — a :class:`repro.core.graph.Graph` (built on the fly) or
    an already-built :class:`~repro.core.graph.GraphRef` — so microbatch
    streaming works over arbitrary device-resident DAGs (fan-out/fan-in
    model stages), not just chains.
    """

    def __init__(self, system: ActorSystem,
                 stages: Optional[Sequence[ActorRef]] = None,
                 depth: int = 2, *, graph=None):
        if (stages is None) == (graph is None):
            raise ValueError("pass exactly one of stages or graph")
        self.depth = depth
        if graph is not None:
            from repro.core.graph import Graph
            self._chain = graph.build() if isinstance(graph, Graph) else graph
        else:
            if not stages:
                raise ValueError("need at least one stage")
            self._chain = Pipeline(system, mode="staged").stages(
                stages).build()
        # shared in-flight window: concurrent submit() callers (a serve
        # engine's request threads) and run() draw from the same budget
        self._sem = threading.Semaphore(depth)

    def submit(self, mb: Any, *, emit: str = "value",
               timeout: Optional[float] = None) -> Future:
        """Admit one microbatch into the stage chain; returns a future for
        its result. At most ``depth`` microbatches are in flight — a full
        window blocks the caller (backpressure) until a slot frees, or
        raises ``TimeoutError`` after ``timeout`` seconds.

        ``emit`` selects the result representation:

        * ``"value"`` — whatever the last stage produced (default);
        * ``"ref"``   — wrap each result as a :class:`DeviceRef`, the
          stay-on-device handoff to a downstream consumer;
        * ``"spill"`` — wrap **and spill**: the explicit host-serialization
          stage boundary (paper §3.5 option (b)) for cross-node transport —
          spilled refs pickle.
        """
        if emit not in ("value", "ref", "spill"):
            raise ValueError(f"emit must be value|ref|spill, got {emit!r}")
        if not self._sem.acquire(timeout=timeout):
            raise TimeoutError(
                f"pipeline in-flight window ({self.depth}) still full "
                f"after {timeout}s")
        payload = mb if isinstance(mb, tuple) else (mb,)
        try:
            fut = self._chain.request(*payload)
        except BaseException:
            # the window is instance state now: a synchronous request
            # failure must hand its slot back or the runner shrinks
            self._sem.release()
            raise
        out: Future = Future()

        def _done(f):
            self._sem.release()
            exc = f.exception()
            if exc is not None:
                out.set_exception(exc)
                return
            res = f.result()
            if emit != "value":
                ref = (res if isinstance(res, DeviceRef)
                       else DeviceRef(jnp.asarray(res)))
                if emit == "spill":
                    ref.spill()
                res = ref
            out.set_result(res)

        fut.add_done_callback(_done)
        return out

    def run(self, microbatches: Sequence[Any],
            timeout: Optional[float] = 300.0, emit: str = "value") -> list:
        """Stream the microbatches; returns results in submission order.

        Microbatches may be host arrays **or** :class:`DeviceRef`\\ s (the
        first stage unwraps refs, so data already on device never bounces
        through the host). A thin loop over :meth:`submit`; the first
        stage failure stops further admissions and aborts the run.
        """
        futures: list[Future] = []
        for mb in microbatches:
            if any(f.done() and f.exception() is not None for f in futures):
                break  # a stage already failed: stop admitting
            futures.append(self.submit(mb, emit=emit, timeout=timeout))
        results: list = [None] * len(microbatches)
        first_error: Optional[BaseException] = None
        for i, f in enumerate(futures):
            try:
                results[i] = f.result(timeout)
            except Exception as exc:
                if first_error is None:
                    first_error = exc
        if first_error is not None:
            raise first_error
        return results
