"""Fault tolerance on the actor substrate (paper §2.1 applied at scale).

* :class:`RecoverableTrainer` — the training loop runs inside a worker
  actor; a supervisor monitors it (``DownMessage``), and on failure the
  trainer restores the latest published checkpoint and respawns the
  worker. Because the data pipeline is stateless-deterministic
  (``batch_at(step)``) and the checkpoint roundtrip is lossless, recovery
  is **bit-exact**: a faulted run converges to the identical parameters
  as an unfaulted one.

* :class:`ElasticDPDriver` — data-parallel gradient workers as actors; a
  worker death mid-step is detected through its failed response future
  and the batch is re-split over the survivors, so the step result is
  independent of the worker count (weighted recombination).
"""
from __future__ import annotations

from concurrent.futures import TimeoutError as FuturesTimeoutError
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.core import Actor, ActorSystem, DownMessage

__all__ = ["FaultInjected", "RecoverableTrainer", "ElasticDPDriver"]


class FaultInjected(RuntimeError):
    """Deliberate fault (tests / demos): kills the receiving actor."""


def _to_device(batch: Dict[str, Any]) -> Dict[str, Any]:
    return {k: jnp.asarray(v) for k, v in batch.items()}


# ----------------------------------------------------------------------------
# supervised checkpoint/restart training
# ----------------------------------------------------------------------------
class _TrainWorker(Actor):
    """Owns the train state; one message = one optimizer step."""

    def __init__(self, train_step: Callable, state):
        super().__init__()
        self._train_step = train_step
        self.state = state

    def receive(self, cmd: str, *args):
        if cmd == "step":
            step_idx, batch, inject = args
            if inject:
                raise FaultInjected(f"injected fault at step {step_idx}")
            self.state, metrics = self._train_step(self.state, batch)
            return metrics
        if cmd == "state":
            return self.state
        raise ValueError(f"unknown command {cmd!r}")


class RecoverableTrainer:
    """Checkpoint-every-k training with supervised restart."""

    def __init__(self, system: ActorSystem, train_step: Callable, state,
                 data, ckpt_dir: str, *, ckpt_every: int = 2, keep: int = 3,
                 step_timeout: float = 600.0):
        self.system = system
        self.train_step = train_step
        self.data = data
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep
        self.step_timeout = step_timeout
        self.recoveries = 0
        self._template = jax.tree.map(lambda x: x, state)  # treedef donor
        self._downs: list = []
        self._sup = system.spawn(self._record_down)
        # step-0 checkpoint: the recovery floor before the first periodic save
        ckpt.save(ckpt_dir, 0, state, keep=keep)
        self._worker = self._spawn_worker(state)

    def _record_down(self, msg):
        if isinstance(msg, DownMessage):
            self._downs.append(msg)

    def _spawn_worker(self, state):
        ref = self.system.spawn(_TrainWorker(self.train_step, state))
        self.system.monitor(self._sup, ref)
        return ref

    def run(self, total_steps: int, fail_at: Optional[int] = None):
        """Run ``total_steps`` optimizer steps; returns the final state.

        ``fail_at`` injects one fault before that step executes — the
        worker dies, the supervisor restores the latest checkpoint, and
        training resumes from the restored step."""
        step, injected = 0, False
        while step < total_steps:
            batch = _to_device(self.data.batch_at(step))
            inject = fail_at is not None and step == fail_at and not injected
            try:
                self._worker.ask("step", step, batch, inject,
                                 timeout=self.step_timeout)
            except Exception:
                injected = True
                self.recoveries += 1
                step = self._recover()
                continue
            step += 1
            if step % self.ckpt_every == 0:
                self._checkpoint(step)
        final = self._worker.ask("state", timeout=self.step_timeout)
        if int(final["step"]) != total_steps:  # pragma: no cover - invariant
            raise RuntimeError(
                f"state.step={int(final['step'])} != {total_steps}")
        return final

    def _checkpoint(self, step: int) -> None:
        state = self._worker.ask("state", timeout=self.step_timeout)
        ckpt.save(self.ckpt_dir, step, state, keep=self.keep)

    def _recover(self) -> int:
        restored, manifest = ckpt.restore(self.ckpt_dir,
                                          target=self._template)
        state = jax.tree.map(jnp.asarray, restored)
        self._worker = self._spawn_worker(state)
        return int(manifest["step"])


# ----------------------------------------------------------------------------
# elastic data parallelism
# ----------------------------------------------------------------------------
class _GradWorker(Actor):
    """Computes (loss, grads) on its batch shard; may carry a planted
    fault (``fail_at[index] == step_idx``) that kills it mid-step."""

    def __init__(self, grad_fn: Callable, index: int,
                 fail_at: Dict[int, int]):
        super().__init__()
        self._grad_fn = grad_fn
        self.index = index
        self._fail_at = dict(fail_at)

    def receive(self, params, shard, step_idx):
        if self._fail_at.get(self.index) == step_idx:
            raise FaultInjected(
                f"worker {self.index} died at step {step_idx}")
        loss, grads = self._grad_fn(params, shard)
        return loss, grads


class ElasticDPDriver:
    """Data-parallel gradient computation that survives worker loss.

    Each step splits the batch rows over the live workers; if a worker
    dies mid-step the step is retried over the survivors. The combined
    (loss, grads) is the row-weighted average, so it equals the
    single-worker result regardless of the split."""

    def __init__(self, system: ActorSystem, grad_fn: Callable, *,
                 n_workers: int = 4,
                 fail_at: Optional[Dict[int, int]] = None,
                 step_timeout: float = 600.0,
                 workers: Optional[list] = None):
        """``workers`` adopts pre-spawned gradient workers instead of
        spawning locally — including :class:`repro.net.RemoteActorRef`\\ s
        (e.g. from ``NodeRuntime.spawn_remote``): a remote *node* death
        fails its response futures just like a local worker death, so the
        elastic re-split covers whole-node loss with no extra code."""
        self.system = system
        self.step_timeout = step_timeout
        if workers is not None:
            self.workers = list(workers)
        else:
            self.workers = [
                system.spawn(_GradWorker(grad_fn, i, fail_at or {}))
                for i in range(n_workers)
            ]

    @staticmethod
    def _shard(batch: Dict[str, Any], start: int, size: int):
        return {k: (v[:, start:start + size] if k == "positions"
                    else v[start:start + size])
                for k, v in batch.items()}

    def step(self, params, step_idx: int, batch: Dict[str, Any]):
        """→ ``(loss, grads, n_workers_used)``."""
        batch = _to_device(batch)
        rows = next(v.shape[1] if k == "positions" else v.shape[0]
                    for k, v in batch.items())
        for _ in range(len(self.workers) + 1):
            live = [w for w in self.workers if w.is_alive()]
            if not live:
                raise RuntimeError("no live gradient workers")
            n = len(live)
            sizes = [rows // n + (1 if i < rows % n else 0) for i in range(n)]
            dispatched, start = [], 0
            for w, sz in zip(live, sizes):
                if sz:
                    dispatched.append(
                        (w, w.request(params, self._shard(batch, start, sz),
                                      step_idx), sz))
                start += sz
            results, dead = [], []
            for w, fut, sz in dispatched:
                try:
                    results.append((fut.result(self.step_timeout), sz))
                except FuturesTimeoutError:
                    # the worker is healthy but slow — surface the timeout
                    # instead of misclassifying it as a death
                    raise
                except Exception:
                    dead.append(w.actor_id)
            if dead:
                self.workers = [w for w in self.workers
                                if w.actor_id not in dead]
                continue
            used = sum(1 for _, sz in results if sz)
            loss = sum(float(l) * sz for (l, _), sz in results) / rows
            grads = jax.tree.map(
                lambda *gs: sum(
                    g.astype(jnp.float32) * (sz / rows)
                    for g, (_, sz) in zip(gs, results)),
                *[g for (_, g), _ in results])
            return loss, grads, used
        raise RuntimeError("elastic step did not converge")  # pragma: no cover
