"""Train / serve step builders.

``build_train_step`` closes over the model and optimizer config and
returns a pure ``(state, batch) → (state, metrics)`` function suitable
for ``jax.jit`` (callers add ``in_shardings``/``donate_argnums``).
Gradient accumulation runs as a ``lax.scan`` over microbatches so the
HLO stays O(1) in the accumulation factor; ``presplit=True`` accepts a
batch already shaped ``[A, B/A, ...]`` (the dry-run path, where the
splitter runs on the host to keep the per-device working set bounded).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.optim import adamw

__all__ = ["init_train_state", "build_train_step", "build_serve_step"]


def init_train_state(model, key, ocfg) -> Dict[str, Any]:
    """→ ``{"params", "opt", "step"}`` — the canonical train-state pytree."""
    params = model.init(key)
    return {
        "params": params,
        "opt": adamw.init(params, ocfg),
        "step": jnp.zeros((), jnp.int32),
    }


def _split_microbatches(batch: Dict[str, Any], accum: int) -> Dict[str, Any]:
    """``[B, ...] → [A, B/A, ...]``; ``positions`` [3,B,S] → [A,3,B/A,S]."""
    out = {}
    for k, v in batch.items():
        v = jnp.asarray(v)
        if k == "positions":
            three, b, s = v.shape
            out[k] = jnp.moveaxis(v.reshape(three, accum, b // accum, s), 1, 0)
        else:
            b = v.shape[0]
            out[k] = v.reshape((accum, b // accum) + v.shape[1:])
    return out


def build_train_step(model, ocfg, *, grad_accum: int = 1,
                     lr_schedule: Optional[Callable] = None,
                     accum_dtype: str = "float32",
                     presplit: bool = False,
                     grad_shardings=None) -> Callable:
    """One optimizer step: loss + grad (accumulated over ``grad_accum``
    microbatches), global-norm clip, AdamW update.

    ``grad_shardings`` (a pytree of NamedShardings matching the params)
    pins the accumulated gradients so GSPMD keeps the accumulation loop
    collective-free until the optimizer."""
    adt = jnp.dtype(accum_dtype)

    def loss_fn(params, mb):
        loss, parts = model.loss(params, mb)
        return loss, parts

    def train_step(state, batch):
        params, opt, step = state["params"], state["opt"], state["step"]

        if grad_accum <= 1:
            (loss, parts), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            loss = loss.astype(jnp.float32)
        else:
            mbs = batch if presplit else _split_microbatches(batch, grad_accum)

            def body(carry, mb):
                loss_acc, g_acc = carry
                (l, parts), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(adt) / grad_accum, g_acc, g)
                return (loss_acc + l.astype(jnp.float32) / grad_accum,
                        g_acc), parts

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            (loss, grads), parts_stack = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), mbs)
            parts = jax.tree.map(lambda x: jnp.mean(x, axis=0), parts_stack)

        if grad_shardings is not None:
            grads = jax.tree.map(jax.lax.with_sharding_constraint,
                                 grads, grad_shardings)

        lr_scale = lr_schedule(step) if lr_schedule is not None else 1.0
        new_params, new_opt, opt_metrics = adamw.update(
            grads, opt, params, ocfg, lr_scale)
        metrics = {"loss": loss, **parts, **opt_metrics}
        new_state = {"params": new_params, "opt": new_opt, "step": step + 1}
        return new_state, metrics

    return train_step


def build_serve_step(model) -> Callable:
    """One greedy decode step: ``(params, cache, tokens[B,1]) →
    (next[B,1] int32, logits[B,1,V], cache)``."""

    def serve_step(params, cache, tokens):
        logits, cache = model.decode_step(params, tokens, cache)
        nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return serve_step
