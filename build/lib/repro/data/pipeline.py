"""Deterministic synthetic data pipeline.

Stateless by construction: ``batch_at(step)`` derives every batch from
``(seed, step, shard)`` with a counter-based RNG, so a restarted (or
re-sharded, for elastic rescale) trainer reproduces the exact stream —
the property the checkpoint/restart test and the paper-style supervisor
recovery rely on.

The token stream has learnable structure (a noisy affine next-token rule)
so small-model training loss demonstrably decreases.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


class SyntheticLM:
    def __init__(self, cfg: ModelConfig, batch: int, seq: int, *,
                 seed: int = 0, shard: int = 0, num_shards: int = 1,
                 noise: float = 0.05):
        assert batch % num_shards == 0, (batch, num_shards)
        self.cfg = cfg
        self.global_batch = batch
        self.batch = batch // num_shards
        self.seq = seq
        self.seed = seed
        self.shard = shard
        self.num_shards = num_shards
        self.noise = noise

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(np.random.Philox(
            key=np.uint64(self.seed),
            counter=[np.uint64(step), np.uint64(self.shard), 0, 0]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        v = self.cfg.vocab_size
        b, s = self.batch, self.seq
        # noisy affine chain: x_{t+1} = (a*x_t + c) % v, occasionally random
        a = 31
        c = 7
        x = np.empty((b, s + 1), np.int32)
        x[:, 0] = rng.integers(0, v, b)
        noise = rng.random((b, s)) < self.noise
        rand = rng.integers(0, v, (b, s))
        for t in range(s):
            nxt = (x[:, t] * a + c) % v
            x[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        out = {"tokens": x[:, :-1], "labels": x[:, 1:]}
        dt = np.dtype(self.cfg.compute_dtype)
        if self.cfg.family == "encdec":
            out["frames"] = rng.standard_normal(
                (b, self.cfg.encdec.n_frames, self.cfg.d_model)).astype(dt)
        if self.cfg.family == "vlm":
            out["vision_embeds"] = rng.standard_normal(
                (b, self.cfg.n_vision_tokens, self.cfg.d_model)).astype(dt)
            pos = np.broadcast_to(np.arange(s, dtype=np.int32), (b, s))
            out["positions"] = np.broadcast_to(pos, (3, b, s)).copy()
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch queue over any step-indexed source."""

    def __init__(self, source: SyntheticLM, depth: int = 2,
                 start_step: int = 0):
        self._source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)
