from . import analysis
from .analysis import Roofline, analyze, model_flops_for, parse_collectives

__all__ = ["analysis", "Roofline", "analyze", "model_flops_for",
           "parse_collectives"]
