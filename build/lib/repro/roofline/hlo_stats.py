"""Static analyzer for optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body **once**, so any
scan-over-layers / grad-accumulation program is undercounted by the trip
count (~176× for dbrx train_4k). This module re-derives the roofline
inputs by walking the module:

* parses every computation and its ops (result shape, operands, attrs),
* recovers **trip counts** of `while` loops from their condition
  computations (`compare(iter, constant)`),
* propagates a **multiplier** through the call graph
  (entry → while bodies ×trip, fusions/calls ×1),
* counts per-op **FLOPs** (dot/convolution contractions — elementwise is
  noise at LM scale), **bytes accessed** (operand+result sizes at
  fusion/dot/collective/data-movement op boundaries ≈ HBM traffic), and
  **collective bytes/seconds** (ring cost model, replica-group size).

Everything is derived from the compiled artifact, per the assignment.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<shape>\([^()]*\)|[a-z0-9]+"
    r"\[[0-9,]*\](?:\{[^}]*\})?)\s+(?P<opcode>[\w\-]+)\((?P<rest>.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*"
                      r"(?P<params>\((?:[^()]|\([^()]*\))*\))\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}
#: op kinds whose operand/result traffic we count as HBM bytes. Plain
#: elementwise ops are EXCLUDED: on the TPU target they fuse into their
#: producers/consumers, while the CPU backend leaves them unfused — counting
#: them would inflate the memory term ~20× with traffic a TPU compile never
#: pays. Fusion boundaries, contractions, data movement, and collectives
#: are inherent traffic on both backends.
_TRAFFIC_OPS = _COLLECTIVES | {
    "fusion", "dot", "convolution", "copy", "dynamic-update-slice",
    "dynamic-slice", "scatter", "gather", "reduce", "transpose",
    "concatenate", "slice", "pad", "reverse", "select-and-scatter", "sort",
    "reduce-window",
}


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return "", []
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",")] if dims else []


def _is_scores_class(shape_str: str, seq_dims=None) -> bool:
    """Attention-score-shaped: ≥2 dims that are sequence-sized. With
    ``seq_dims`` (e.g. {4096, 512, 256}) membership is exact; fallback is
    ≥2 dims ≥2048 (ambiguous when d_model == seq — noted in EXPERIMENTS)."""
    for _, dims in _SHAPE_RE.findall(shape_str):
        vals = [int(d) for d in dims.split(",") if d]
        if seq_dims is not None:
            if sum(1 for d in vals if d in seq_dims) >= 2:
                return True
        elif sum(1 for d in vals if d >= 2048) >= 2:
            return True
    return False


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    shape: str
    operands: List[str]
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    shapes: Dict[str, str]          # symbol → shape str (incl. params)


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line or line.lstrip().startswith("//"):
            continue
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_RE.match(line.strip())
            if m:
                cur = Computation(m.group("name"), [], {})
                comps[cur.name] = cur
                # parameter shapes from the signature
                for pm in re.finditer(r"([\w.\-]+):\s*("
                                      r"\([^)]*\)|[a-z0-9]+\[[0-9,]*\]"
                                      r"(?:\{[^}]*\})?)", m.group("params")):
                    cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        rest = m.group("rest")
        # operands = %refs before the closing paren of the op call
        call_part = rest.split("),", 1)[0]
        operands = _OPERAND_RE.findall(call_part)
        op = Op(m.group("name"), m.group("opcode"), m.group("shape"),
                operands, rest)
        cur.ops.append(op)
        cur.shapes[op.name] = op.shape
    return comps


def _trip_count(cond: Computation) -> int:
    """Recover the while trip count from its condition computation.

    The loop bound appears as an integer constant compared against the
    induction variable; XLA may wrap the compare in a fused
    sub-computation, so when no local ``compare`` references a constant we
    take the largest integer constant in the condition body."""
    consts: Dict[str, int] = {}
    for op in cond.ops:
        if op.opcode == "constant":
            mm = re.search(r"constant\((-?\d+)\)", "constant(" + op.rest)
            if mm:
                consts[op.name] = int(mm.group(1))
    for op in cond.ops:
        if op.opcode == "compare":
            for o in op.operands:
                if o in consts:
                    return max(consts[o], 1)
    if consts:
        return max(max(consts.values()), 1)
    return 1


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    entry = None
    for name, c in comps.items():
        if name in ("main", "main.0") or name.startswith("main"):
            entry = name
    if entry is None:  # fall back: computation not referenced by others
        referenced = set()
        for c in comps.values():
            for op in c.ops:
                for m in re.finditer(r"(?:body|condition|calls|to_apply)="
                                     r"%?([\w.\-]+)", op.rest):
                    referenced.add(m.group(1))
        for name in comps:
            if name not in referenced:
                entry = name
                break
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    # propagate in topological-ish order via worklist
    work = [entry]
    seen_edges = set()
    while work:
        cname = work.pop()
        c = comps.get(cname)
        if c is None:
            continue
        for op in c.ops:
            if op.opcode == "while":
                body = re.search(r"body=%?([\w.\-]+)", op.rest)
                cond = re.search(r"condition=%?([\w.\-]+)", op.rest)
                if not body or not cond:
                    continue
                trips = _trip_count(comps[cond.group(1)]) if cond.group(1) in comps else 1
                for target, factor in ((body.group(1), trips),
                                       (cond.group(1), trips + 1)):
                    edge = (cname, target)
                    if edge in seen_edges:
                        continue
                    seen_edges.add(edge)
                    if target in mult:
                        mult[target] += mult[cname] * factor
                        work.append(target)
            else:
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)",
                                     op.rest):
                    target = m.group(1)
                    edge = (cname, target, op.name)
                    if edge in seen_edges:
                        continue
                    seen_edges.add(edge)
                    if target in mult:
                        mult[target] += mult[cname]
                        work.append(target)
    return mult


def _dot_flops(op: Op, comp: Computation) -> float:
    """2 × |result| × |contraction|."""
    _, out_dims = _shape_dims(op.shape)
    lhs_shape = comp.shapes.get(op.operands[0], "") if op.operands else ""
    _, lhs_dims = _shape_dims(lhs_shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    contraction = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contraction *= lhs_dims[int(idx)]
    out = 1
    for d in out_dims:
        out *= d
    return 2.0 * out * max(contraction, 1)


def _group_size(rest: str) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class ModuleStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_seconds: float = 0.0
    collective_count: int = 0
    by_loop_flops: Dict[str, float] = dataclasses.field(default_factory=dict)
    bytes_by_opcode: Dict[str, float] = dataclasses.field(default_factory=dict)
    by_comp_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    by_comp_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: traffic of attention-score-class tensors (≥2 dims ≥2048): the bytes a
    #: flash/Pallas attention kernel keeps in VMEM instead of HBM
    bytes_scores_class: float = 0.0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze_module(hlo: str, *, ici_bw: float = 50e9,
                   seq_dims=None) -> ModuleStats:
    comps = parse_module(hlo)
    mult = _multipliers(comps)
    stats = ModuleStats()
    fusion_bodies = {name for name in comps if "fused_computation" in name}
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        in_fusion = name in fusion_bodies
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                f = _dot_flops(op, comp) * m
                stats.flops += f
                stats.by_loop_flops[name] = stats.by_loop_flops.get(name, 0) + f
            if in_fusion:
                continue  # boundary traffic is counted at the fusion op site
            opc = op.opcode.replace("-start", "")
            if op.opcode in _TRAFFIC_OPS or opc in _COLLECTIVES:
                nbytes = shape_bytes(op.shape)
                if op.opcode != "fusion":
                    for o in op.operands:
                        nbytes += shape_bytes(comp.shapes.get(o, ""))
                # fusion: count the WRITE only — its reads are either other
                # counted ops' results (already written once) or parameters;
                # TPU fusions keep elementwise chains in registers/VMEM, so
                # charging their boundaries once is the roofline convention.
                stats.bytes_accessed += nbytes * m
                stats.bytes_by_opcode[opc] = \
                    stats.bytes_by_opcode.get(opc, 0.0) + nbytes * m
                stats.by_comp_bytes[name] = \
                    stats.by_comp_bytes.get(name, 0.0) + nbytes * m
                score_bytes = shape_bytes(op.shape) if _is_scores_class(
                    op.shape, seq_dims) else 0
                if op.opcode != "fusion":
                    for o in op.operands:
                        osh = comp.shapes.get(o, "")
                        if _is_scores_class(osh, seq_dims):
                            score_bytes += shape_bytes(osh)
                stats.bytes_scores_class += score_bytes * m
            if opc in {"all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute"}:
                nbytes = shape_bytes(op.shape)
                n = _group_size(op.rest)
                if n <= 1:
                    continue
                frac = (n - 1) / n
                if opc == "all-reduce":
                    secs = 2 * nbytes * frac / ici_bw
                elif opc == "collective-permute":
                    secs = nbytes / ici_bw
                else:
                    secs = nbytes * frac / ici_bw
                stats.collective_bytes[opc] = \
                    stats.collective_bytes.get(opc, 0.0) + nbytes * m
                stats.by_comp_collective[f"{name}:{opc}:{op.shape[:40]}"] = \
                    stats.by_comp_collective.get(
                        f"{name}:{opc}:{op.shape[:40]}", 0.0) + nbytes * m
                stats.collective_seconds += secs * m
                stats.collective_count += int(m)
    return stats
