"""Roofline analysis from compiled dry-run artifacts (assignment §ROOFLINE).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = Σ_ops ring_time(op_kind, bytes, group_size) over the
                 **optimized post-SPMD HLO** (collective bytes are not in
                 cost_analysis; we parse ``compiled.as_text()``)

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI. Ring-collective cost model per op kind (n = group size):

    all-gather      bytes_out × (n-1)/n / BW
    reduce-scatter  bytes_in  × (n-1)/n / BW
    all-reduce      2 × bytes × (n-1)/n / BW
    all-to-all      bytes × (n-1)/n / BW
    collective-permute  bytes / BW
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (effective per-chip per-collective)

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%x = bf16[128,1024]{1,0} all-gather(...)`  (also tuple results)
_OP_RE = re.compile(
    r"=\s*(?P<shape>\((?:[^()]*)\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>all-reduce-start|all-gather-start|reduce-scatter|all-to-all|"
    r"collective-permute-start|all-reduce|all-gather|collective-permute)\b"
    r"(?P<rest>.*)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(rest: str) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:  # iota format [groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    seconds_by_kind: Dict[str, float]
    count: int

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_seconds(self) -> float:
        return sum(self.seconds_by_kind.values())


def parse_collectives(hlo_text: str, ici_bw: float = ICI_BW) -> CollectiveStats:
    bytes_by: Dict[str, int] = {}
    secs_by: Dict[str, float] = {}
    count = 0
    for line in hlo_text.splitlines():
        if "fusion" in line and all(c not in line for c in _COLLECTIVES):
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op").replace("-start", "")
        nbytes = _shape_bytes(m.group("shape"))
        n = _group_size(m.group("rest"))
        if n <= 1:
            continue
        frac = (n - 1) / n
        if op == "all-reduce":
            secs = 2 * nbytes * frac / ici_bw
        elif op == "collective-permute":
            secs = nbytes / ici_bw
        else:  # all-gather (result), reduce-scatter (operand≈result parsed)
            secs = nbytes * frac / ici_bw
        bytes_by[op] = bytes_by.get(op, 0) + nbytes
        secs_by[op] = secs_by.get(op, 0.0) + secs
        count += 1
    return CollectiveStats(bytes_by, secs_by, count)


# ----------------------------------------------------------------------------
@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective: CollectiveStats
    model_flops: float            # 6·N_active·D (global)
    memory_per_device: Dict[str, float]
    step_kind: str
    bytes_by_opcode: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective.total_seconds

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total_hlo = self.flops_per_device * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / achievable step time (max of the terms):
        how close the step is to the compute roofline for its useful FLOPs."""
        useful_s = (self.model_flops / self.chips) / PEAK_FLOPS
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        return useful_s / bound if bound else 0.0

    def to_dict(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "step_kind": self.step_kind,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes": self.collective.bytes_by_kind,
            "collective_count": self.collective.count,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "memory_per_device": self.memory_per_device,
            "bytes_by_opcode": self.bytes_by_opcode,
        }


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float, step_kind: str, seq_dims=None) -> Roofline:
    """Derive the three terms from the compiled artifact.

    ``cost_analysis()`` counts while-loop bodies once, so scanned programs
    are undercounted by their trip counts; we use the static HLO analyzer
    (``hlo_stats``) which multiplies through the loop nest. XLA's own
    numbers are preserved in ``memory_per_device['xla_cost_*']``."""
    from . import hlo_stats
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    stats = hlo_stats.analyze_module(hlo, ici_bw=ICI_BW, seq_dims=seq_dims)
    flops = stats.flops
    nbytes = stats.bytes_accessed
    coll = CollectiveStats(
        {k: int(v) for k, v in stats.collective_bytes.items()},
        {"total": stats.collective_seconds}, stats.collective_count)
    mem: Dict[str, float] = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = float(v)
    except Exception:
        pass
    mem["xla_cost_flops_loop_bodies_once"] = float(cost.get("flops", 0.0))
    mem["xla_cost_bytes_loop_bodies_once"] = float(
        cost.get("bytes accessed", 0.0))
    # counterfactual: memory term with attention-score traffic kept in VMEM
    # (what the Pallas flash kernel — the TPU deploy path — achieves)
    mem["bytes_scores_class"] = float(stats.bytes_scores_class)
    mem["memory_s_flash_equiv"] = float(
        (stats.bytes_accessed - stats.bytes_scores_class) / HBM_BW)
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    flops_per_device=flops, bytes_per_device=nbytes,
                    collective=coll, model_flops=model_flops,
                    memory_per_device=mem, step_kind=step_kind,
                    bytes_by_opcode=dict(stats.bytes_by_opcode))


def model_flops_for(cfg, shape_name: str, seq: int, global_batch: int,
                    step_kind: str) -> float:
    """Useful model FLOPs: 6·N_active·D plus the attention term
    (PaLM-appendix-style MFU accounting — at 32k+ context the S² attention
    FLOPs dominate the parameter FLOPs and must be credited)."""
    n_active = cfg.active_param_count()
    h, hd = cfg.n_heads, cfg.resolved_head_dim

    def attn_fwd_per_seq(s_ctx: int) -> float:
        """QKᵀ + PV over a causal context (½ the pairs count)."""
        if cfg.is_attention_free or not h:
            return 0.0
        l_attn = cfg.n_layers
        eff = s_ctx
        if cfg.family == "hybrid":
            pat = cfg.hybrid.pattern or ("attn",)
            l_attn = cfg.n_layers * sum(1 for p in pat if p == "attn") / len(pat)
            eff = min(s_ctx, 2 * cfg.hybrid.window)  # local window
        per_layer = 2.0 * s_ctx * eff * h * hd  # causal ½ × (2 matmuls × 2)
        enc = 0.0
        if cfg.family == "encdec":
            t = cfg.encdec.n_frames
            enc = cfg.encdec.n_enc_layers * 4.0 * t * t * h * hd  # bidirectional
        return l_attn * per_layer + enc

    if step_kind == "train":
        return (6.0 * n_active * seq +
                3.0 * attn_fwd_per_seq(seq)) * global_batch
    if step_kind == "prefill":
        return (2.0 * n_active * seq + attn_fwd_per_seq(seq)) * global_batch
    # decode: one token against an s_ctx-deep cache → 4·S·H·Dh per layer
    l_attn = cfg.n_layers
    eff = seq
    if cfg.family == "hybrid":
        pat = cfg.hybrid.pattern or ("attn",)
        l_attn = cfg.n_layers * sum(1 for p in pat if p == "attn") / len(pat)
        eff = min(seq, cfg.hybrid.window)
    attn_dec = 0.0 if (cfg.is_attention_free or not h) else \
        l_attn * 4.0 * eff * h * hd
    return (2.0 * n_active + attn_dec) * global_batch
