"""Mamba-2 SSD mixer (state-space duality, arXiv:2405.21060).

Chunked SSD: within a chunk the recurrence is computed as a masked
attention-like quadratic form (MXU-friendly); across chunks a short
``lax.scan`` carries the [H, P, N] state. Decode is the pure recurrence
with an (ssm_state, conv_state) cache. Attention-free — the ``long_500k``
cell lowers through this path.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import apply_norm, init_norm


def _dims(cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.ssm.expand * d
    n = cfg.ssm.state_dim
    p = cfg.ssm.head_dim
    h = di // p
    g = cfg.ssm.n_groups
    conv_dim = di + 2 * g * n
    return d, di, n, p, h, g, conv_dim


def init_ssm(key, cfg: ModelConfig, dtype) -> dict:
    d, di, n, p, h, g, conv_dim = _dims(cfg)
    w = cfg.ssm.conv_width
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        # order: [z | xBC | dt]
        "in_proj": jax.random.normal(ks[0], (d, 2 * di + 2 * g * n + h), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (w, conv_dim), dtype) * (1.0 / math.sqrt(w)),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": init_norm(di, "rmsnorm", dtype),
        "out_proj": jax.random.normal(ks[2], (di, d), dtype) / math.sqrt(di),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. xbc: [B,S,C]; w: [W,C]."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(width):  # unrolled tiny loop → fused multiply-adds
        out = out + pad[:, i:i + xbc.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def apply_ssm(params: dict, cfg: ModelConfig, u: jax.Array) -> jax.Array:
    """Full-sequence SSD. u: [B,S,D] → [B,S,D]."""
    d, di, n, p, h, g, conv_dim = _dims(cfg)
    b, s, _ = u.shape
    q = min(cfg.ssm.chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    zxbcdt = u @ params["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + conv_dim]
    dt = zxbcdt[..., di + conv_dim:]
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    x = xbc[..., :di].reshape(b, s, h, p)
    bmat = xbc[..., di:di + g * n].reshape(b, s, g, n)
    cmat = xbc[..., di + g * n:].reshape(b, s, g, n)
    # broadcast groups over heads
    bmat = jnp.repeat(bmat, h // g, axis=2)                     # [B,S,H,N]
    cmat = jnp.repeat(cmat, h // g, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    a = -jnp.exp(params["a_log"])                                # [H] negative
    delta = dt * a                                               # log decay

    # chunked layout
    xw = (x.astype(jnp.float32) * dt[..., None]).reshape(b, nc, q, h, p)
    bm = bmat.astype(jnp.float32).reshape(b, nc, q, h, n)
    cm = cmat.astype(jnp.float32).reshape(b, nc, q, h, n)
    dl = delta.reshape(b, nc, q, h)
    cum = jnp.cumsum(dl, axis=2)                                 # [B,NC,Q,H]

    # intra-chunk: scores[i,j] = (C_i·B_j) exp(cum_i - cum_j), j ≤ i.
    # Mask the *exponent* (not the result) so masked entries have zero
    # gradient instead of 0·inf = NaN in the backward pass.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]         # [B,NC,Q,Q,H]
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -1e30))
    cb = jnp.einsum("bcihn,bcjhn->bcijh", cm, bm)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", cb * decay, xw)

    # chunk states: S_c = Σ_j exp(cum_last - cum_j) B_j ⊗ xw_j → [B,NC,H,N,P]
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                      # [B,NC,Q,H]
    states = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp", tail, bm, xw)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # [B,NC,H]

    def scan_body(carry, inp):
        s_c, dec_c = inp                                          # [B,H,N,P],[B,H]
        new = carry * dec_c[..., None, None] + s_c
        return new, carry                                         # emit prev state

    init = jnp.zeros((b, h, n, p), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_body, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)            # [B,NC,H,N,P]

    # inter-chunk: y_i += C_i · (exp(cum_i) * S_prev)
    start_decay = jnp.exp(cum)                                    # [B,NC,Q,H]
    y_inter = jnp.einsum("bcihn,bchnp,bcih->bcihp", cm, prev_states, start_decay)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    y = y + x.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di).astype(u.dtype)
    y = apply_norm(params["norm"], y * jax.nn.silu(z), "rmsnorm")
    return y @ params["out_proj"]


# ----------------------------------------------------------------------------
# decode
# ----------------------------------------------------------------------------
def init_ssm_cache(cfg: ModelConfig, batch: int, dtype, n_layers: int) -> dict:
    d, di, n, p, h, g, conv_dim = _dims(cfg)
    w = cfg.ssm.conv_width
    return {
        "state": jnp.zeros((n_layers, batch, h, n, p), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, w - 1, conv_dim), dtype),
    }


def decode_ssm(params: dict, cfg: ModelConfig, u: jax.Array, state, conv):
    """One step. u: [B,1,D]; state: [B,H,N,P]; conv: [B,W-1,C]."""
    d, di, n, p, h, g, conv_dim = _dims(cfg)
    b = u.shape[0]
    zxbcdt = u[:, 0, :] @ params["in_proj"]
    z = zxbcdt[:, :di]
    xbc = zxbcdt[:, di:di + conv_dim]
    dt = zxbcdt[:, di + conv_dim:]

    window = jnp.concatenate([conv, xbc[:, None, :]], axis=1)     # [B,W,C]
    new_conv = window[:, 1:, :]
    xbc = jax.nn.silu(jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                                 params["conv_w"].astype(jnp.float32))
                      + params["conv_b"].astype(jnp.float32))
    x = xbc[:, :di].reshape(b, h, p)
    bm = jnp.repeat(xbc[:, di:di + g * n].reshape(b, g, n), h // g, axis=1)
    cm = jnp.repeat(xbc[:, di + g * n:].reshape(b, g, n), h // g, axis=1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,H]
    decay = jnp.exp(dt * -jnp.exp(params["a_log"]))                # [B,H]
    xw = x.astype(jnp.float32) * dt[..., None]                     # [B,H,P]
    state = state * decay[..., None, None] + \
        jnp.einsum("bhn,bhp->bhnp", bm, xw)
    y = jnp.einsum("bhn,bhnp->bhp", cm, state) + \
        x.astype(jnp.float32) * params["d_skip"][None, :, None]
    y = y.reshape(b, di).astype(u.dtype)
    y = apply_norm(params["norm"], y * jax.nn.silu(z), "rmsnorm")
    return (y @ params["out_proj"])[:, None, :], state, new_conv
