"""Whisper-style encoder–decoder backbone (arXiv:2212.04356).

The conv/mel frontend is a **stub** per the assignment: ``input_specs``
provides precomputed frame embeddings [B, n_frames, d_model]. Encoder =
bidirectional self-attention blocks over frames with sinusoidal positions;
decoder = causal self-attention + cross-attention with learned positions.
Decode carries a self-attn KV cache plus per-layer cross K/V computed once
at prefill.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import attention as attn_mod
from .layers import (apply_mlp, apply_norm, init_embedding, init_mlp,
                     init_norm, sinusoidal_positions)

Params = Dict[str, Any]


def _init_enc_block(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {"norm1": init_norm(cfg.d_model, cfg.norm, dtype),
            "attn": attn_mod.init_attention(k1, cfg, dtype),
            "norm2": init_norm(cfg.d_model, cfg.norm, dtype),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)}


def _init_dec_block(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": init_norm(cfg.d_model, cfg.norm, dtype),
            "self_attn": attn_mod.init_attention(k1, cfg, dtype),
            "norm_x": init_norm(cfg.d_model, cfg.norm, dtype),
            "cross_attn": attn_mod.init_attention(k2, cfg, dtype),
            "norm2": init_norm(cfg.d_model, cfg.norm, dtype),
            "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)}


def init_params(key, cfg: ModelConfig, vocab: Optional[int] = None,
                max_dec_len: int = 448) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    vocab = vocab or cfg.vocab_size
    ks = jax.random.split(key, 6)
    enc_blocks = [_init_enc_block(jax.random.fold_in(ks[0], i), cfg, dtype)
                  for i in range(cfg.encdec.n_enc_layers)]
    dec_blocks = [_init_dec_block(jax.random.fold_in(ks[1], i), cfg, dtype)
                  for i in range(cfg.n_layers)]
    return {
        "enc": {"blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_blocks),
                "final_norm": init_norm(cfg.d_model, cfg.norm, dtype)},
        "dec": {"embed": init_embedding(ks[2], vocab, cfg.d_model, dtype),
                "pos_embed": init_embedding(ks[3], max_dec_len, cfg.d_model,
                                            dtype),
                "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *dec_blocks),
                "final_norm": init_norm(cfg.d_model, cfg.norm, dtype)},
    }


def encode(params: Params, cfg: ModelConfig, frames: jax.Array, *,
           attn_impl: str = "xla") -> jax.Array:
    """frames [B,T,D] (stub frontend output) → encoder states [B,T,D]."""
    t = frames.shape[1]
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + sinusoidal_positions(t, cfg.d_model).astype(x.dtype)

    def body(x, block):
        h = apply_norm(block["norm1"], x, cfg.norm)
        x = x + attn_mod.attention(block["attn"], cfg, h, None, causal=False,
                                   impl=attn_impl)
        h = apply_norm(block["norm2"], x, cfg.norm)
        return x + apply_mlp(block["mlp"], h, cfg.mlp), None

    from .transformer import apply_remat
    body = apply_remat(body, cfg.remat)
    x, _ = jax.lax.scan(body, x, params["enc"]["blocks"])
    return apply_norm(params["enc"]["final_norm"], x, cfg.norm)


def forward(params: Params, cfg: ModelConfig, frames: jax.Array,
            tokens: jax.Array, *, attn_impl: str = "xla"
            ) -> Tuple[jax.Array, jax.Array]:
    """(frames [B,T,D], tokens [B,S]) → (logits [B,S,V], aux=0)."""
    enc_out = encode(params, cfg, frames, attn_impl=attn_impl)
    b, s = tokens.shape
    pos = jnp.arange(s)
    x = jnp.take(params["dec"]["embed"], tokens, axis=0) + \
        jnp.take(params["dec"]["pos_embed"], jnp.minimum(
            pos, params["dec"]["pos_embed"].shape[0] - 1), axis=0)
    x = x.astype(jnp.dtype(cfg.compute_dtype))

    def body(x, block):
        h = apply_norm(block["norm1"], x, cfg.norm)
        x = x + attn_mod.attention(block["self_attn"], cfg, h, None,
                                   causal=True, impl=attn_impl)
        h = apply_norm(block["norm_x"], x, cfg.norm)
        kv = attn_mod.project_kv(block["cross_attn"], cfg, enc_out)
        x = x + attn_mod.attention(block["cross_attn"], cfg, h, None,
                                   cross_kv=kv, impl=attn_impl)
        h = apply_norm(block["norm2"], x, cfg.norm)
        return x + apply_mlp(block["mlp"], h, cfg.mlp), None

    from .transformer import apply_remat
    body = apply_remat(body, cfg.remat)
    x, _ = jax.lax.scan(body, x, params["dec"]["blocks"])
    x = apply_norm(params["dec"]["final_norm"], x, cfg.norm)
    logits = x @ params["dec"]["embed"].T.astype(x.dtype)  # tied head
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            attn_impl: str = "xla"):
    from .transformer import cross_entropy
    logits, aux = forward(params, cfg, batch["frames"], batch["tokens"],
                          attn_impl=attn_impl)
    ce = cross_entropy(logits, batch["labels"])
    return ce, {"ce": ce, "aux": aux}


# ----------------------------------------------------------------------------
# decode
# ----------------------------------------------------------------------------
def init_cache(params: Params, cfg: ModelConfig, frames: jax.Array,
               max_len: int, *, attn_impl: str = "xla") -> Dict[str, Any]:
    """Prefill: run the encoder once, precompute per-layer cross K/V."""
    enc_out = encode(params, cfg, frames, attn_impl=attn_impl)
    batch = frames.shape[0]
    dtype = jnp.dtype(cfg.compute_dtype)

    def per_layer(block):
        k, v = attn_mod.project_kv(block, cfg, enc_out)
        return {"k": k.astype(dtype), "v": v.astype(dtype)}

    cross = jax.vmap(lambda blk: per_layer(blk))(  # over stacked layer dim
        params["dec"]["blocks"]["cross_attn"])
    self_kv = attn_mod.init_kv_cache(cfg, batch, max_len, dtype, cfg.n_layers)
    return {"len": jnp.zeros((), jnp.int32), "self": self_kv, "cross": cross}


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    """tokens [B,1] + cache → (logits [B,1,V], cache)."""
    cache_len = cache["len"]
    pos = jnp.minimum(cache_len, params["dec"]["pos_embed"].shape[0] - 1)
    x = jnp.take(params["dec"]["embed"], tokens, axis=0) + \
        jax.lax.dynamic_slice_in_dim(params["dec"]["pos_embed"], pos, 1, axis=0)
    x = x.astype(jnp.dtype(cfg.compute_dtype))

    def body(x, inp):
        block, kc, vc, cross = inp
        h = apply_norm(block["norm1"], x, cfg.norm)
        out, k, v = attn_mod.decode_attention(block["self_attn"], cfg, h,
                                              kc, vc, cache_len, None)
        x = x + out
        h = apply_norm(block["norm_x"], x, cfg.norm)
        x = x + attn_mod.attention(block["cross_attn"], cfg, h, None,
                                   cross_kv=(cross["k"], cross["v"]))
        h = apply_norm(block["norm2"], x, cfg.norm)
        x = x + apply_mlp(block["mlp"], h, cfg.mlp)
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec"]["blocks"], cache["self"]["k"],
                  cache["self"]["v"], cache["cross"]))
    x = apply_norm(params["dec"]["final_norm"], x, cfg.norm)
    logits = x @ params["dec"]["embed"].T.astype(x.dtype)
    return logits, {"len": cache_len + 1, "self": {"k": ks, "v": vs},
                    "cross": cache["cross"]}
