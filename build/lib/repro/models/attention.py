"""Attention: GQA/MQA with qk-norm, QKV bias, (M-)RoPE, local windows,
softcap; training path + single-token decode path with a KV cache.

Training attention dispatches between the Pallas flash kernel (TPU) and
the masked-einsum XLA path (CPU / dry-run). Decode attention is written
so the KV cache can be sharded on heads *or* sequence — the split-K
(flash-decode) variant used at pod scale lives in ``repro.dist.decode``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops

from .layers import apply_m_rope, apply_norm, apply_rope, init_norm


def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    d, h, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(ks[0], (d, h * hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, hkv * hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, hkv * hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (h * hd, d), dtype) / math.sqrt(h * hd),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_norm(hd, "rmsnorm", dtype)
        p["k_norm"] = init_norm(hd, "rmsnorm", dtype)
    return p


def _project_qkv(p: dict, cfg: ModelConfig, x: jax.Array,
                 positions) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm")
        k = apply_norm(p["k_norm"], k, "rmsnorm")
    if positions is not None:
        if cfg.m_rope:
            q = apply_m_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_m_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    from repro.dist import api as dist_api
    q = dist_api.hint_named(q, "attn_q")
    k = dist_api.hint_named(k, "attn_kv")
    v = dist_api.hint_named(v, "attn_kv")
    return q, k, v


def _mha(q, k, v, *, causal: bool, window: Optional[int],
         softcap: Optional[float], bias_mask: Optional[jax.Array],
         impl: str) -> jax.Array:
    """q: [B,S,H,D] → [B,S,H,D]; k/v: [B,S,Hkv,D]."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if impl == "pallas" and softcap is None and bias_mask is None:
        out = ops.flash_attention(qt, kt, vt, causal=causal, window=window,
                                  impl="pallas")
        return out.transpose(0, 2, 1, 3)
    if impl.startswith("xla_chunked") and bias_mask is None \
            and qt.shape[2] % min(
                int(impl.rsplit(":", 1)[1]) if ":" in impl else 512,
                qt.shape[2]) == 0:
        q_chunk = int(impl.rsplit(":", 1)[1]) if ":" in impl else 512
        out = _mha_chunked(qt, kt, vt, causal=causal, window=window,
                           softcap=softcap, q_chunk=min(q_chunk, qt.shape[2]))
        return out.transpose(0, 2, 1, 3)
    # (non-divisible seq, e.g. whisper's 1500-frame encoder, falls through
    # to the plain path — small enough to materialize)
    # XLA path (dry-run / CPU / softcap / explicit masks). GQA is expressed
    # by a grouped-head einsum — K/V are never repeated/materialized per
    # query head (memory term + SPMD-friendliness).
    b, h, sq, d = qt.shape
    hkv, skv = kt.shape[1], kt.shape[2]
    g = h // hkv
    qg = qt.reshape(b, hkv, g, sq, d)
    logits = jnp.einsum("bkgqd,bkKd->bkgqK", qg.astype(jnp.float32),
                        kt.astype(jnp.float32)) * (d ** -0.5)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > (qpos - window)
    if bias_mask is not None:
        mask = mask[None, None, None] & bias_mask[:, :, None]
    else:
        mask = mask[None, None, None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(vt.dtype)
    out = jnp.einsum("bkgqK,bkKd->bkgqd", probs, vt).reshape(b, h, sq, d)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def _mha_chunked(qt, kt, vt, *, causal: bool, window: Optional[int],
                 softcap: Optional[float], q_chunk: int) -> jax.Array:
    """Sarathi-style chunked prefill: scan over query chunks so the score
    tensor is [B,H,qc,Skv] instead of [B,H,Sq,Skv] — the XLA-path
    equivalent of flash tiling, needed for 32k-prefill lowering."""
    b, h, sq, d = qt.shape
    hkv, skv = kt.shape[1], kt.shape[2]
    g = h // hkv
    assert sq % q_chunk == 0, (sq, q_chunk)
    nc = sq // q_chunk
    qs = qt.reshape(b, hkv, g, nc, q_chunk, d).transpose(3, 0, 1, 2, 4, 5)
    kf = kt.astype(jnp.float32)
    vf = vt

    kpos = jnp.arange(skv)[None, :]

    def body(_, inp):
        qc, idx = inp                                  # [B,Hkv,G,qc,D]
        logits = jnp.einsum("bkgqd,bkKd->bkgqK", qc.astype(jnp.float32),
                            kf) * (d ** -0.5)
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        qpos = jnp.arange(q_chunk)[:, None] + idx * q_chunk + (skv - sq)
        mask = jnp.ones((q_chunk, skv), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > (qpos - window)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(vf.dtype)
        out = jnp.einsum("bkgqK,bkKd->bkgqd", probs, vf)
        return None, out

    _, outs = jax.lax.scan(body, None, (qs, jnp.arange(nc)))
    # [nc,B,Hkv,G,qc,D] → [B,H,Sq,D]
    return outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, h, sq, d).astype(qt.dtype)


def attention(p: dict, cfg: ModelConfig, x: jax.Array, positions, *,
              causal: bool = True, window: Optional[int] = None,
              impl: str = "xla", cross_kv: Optional[Tuple] = None) -> jax.Array:
    """Full-sequence attention (training / prefill).

    ``cross_kv=(k, v)`` switches to cross-attention (whisper decoder):
    K/V come from the encoder, no causal mask.
    """
    b, s, _ = x.shape
    if cross_kv is None:
        q, k, v = _project_qkv(p, cfg, x, positions)
    else:
        q, _, _ = _project_qkv(p, cfg, x, positions)
        k, v = cross_kv
        causal, window = False, None
    out = _mha(q, k, v, causal=causal, window=window,
               softcap=cfg.attn_logit_softcap, bias_mask=None, impl=impl)
    return out.reshape(b, s, -1) @ p["wo"]


def project_kv(p: dict, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Encoder-side K/V for cross attention (computed once per request)."""
    b, s, _ = x.shape
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    if cfg.attn_bias:
        k = k + p["bk"].reshape(hkv, hd)
        v = v + p["bv"].reshape(hkv, hd)
    if cfg.qk_norm:
        k = apply_norm(p["k_norm"], k, "rmsnorm")
    return k, v


# ----------------------------------------------------------------------------
# decode path — one new token against a KV cache
# ----------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                  n_layers: int) -> dict:
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (n_layers, batch, max_len, hkv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(p: dict, cfg: ModelConfig, x: jax.Array, k_cache, v_cache,
                     cache_len, positions, *, window: Optional[int] = None,
                     write_pos=None):
    """One-token attention. x: [B,1,D]; caches: [B,Smax,Hkv,Dh].

    Returns (out [B,1,D], new_k_cache, new_v_cache). The new K/V row is
    written at ``write_pos`` (default ``cache_len``; ring-buffer caches pass
    ``cache_len % capacity``); ``cache_len`` always drives the validity
    mask, saturated at the cache capacity.
    """
    b = x.shape[0]
    if write_pos is None:
        write_pos = cache_len
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, write_pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, write_pos, axis=1)

    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = h // hkv
    smax = k_cache.shape[1]
    qg = q.reshape(b, hkv, g, hd)                                 # [B,Hkv,G,D]
    kf = k_cache.astype(jnp.float32)                              # [B,S,Hkv,D]
    vf = v_cache.astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        kf) * (hd ** -0.5)
    if cfg.attn_logit_softcap is not None:
        logits = cfg.attn_logit_softcap * jnp.tanh(logits / cfg.attn_logit_softcap)
    kpos = jnp.arange(smax)[None, None, None, :]
    # saturate: once a ring-buffer cache has wrapped, every slot is live
    valid = kpos <= jnp.minimum(cache_len, smax - 1)
    if window is not None:
        valid &= kpos > (cache_len - window)
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, vf).astype(x.dtype)
    out = out.reshape(b, 1, h * hd) @ p["wo"]
    return out, k_cache, v_cache
