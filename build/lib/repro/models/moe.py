"""Top-k routed mixture-of-experts (GShard/Switch-style dense dispatch).

TPU-native formulation: routing becomes one-hot dispatch/combine einsums
so GSPMD lowers expert exchange to all-to-all/reduce-scatter collectives.
Experts are sharded on the ``model`` mesh axis (expert parallelism); the
dispatch tensor [T, E, C] carries the expert axis so its per-device slice
stays small (DESIGN.md §6).

Capacity-based token dropping keeps shapes static (dropped tokens pass
through the residual); an auxiliary load-balancing loss (Switch, eq. 4)
discourages imbalance.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s_in,
        "w_gate": jax.random.normal(ks[1], (e, d, f), dtype) * s_in,
        "w_up": jax.random.normal(ks[2], (e, d, f), dtype) * s_in,
        "w_out": jax.random.normal(ks[3], (e, f, d), dtype) * s_out,
    }


def apply_moe(p: dict, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,D] → (y [B,S,D], aux_loss scalar).

    GShard *grouped* dispatch: tokens are routed in fixed-size groups
    (≤ ``group_size``), so the dispatch tensor is [G, g, E, C] with
    per-group capacity C = ⌈k·g/E·cf⌉. C is independent of the global
    token count and of sequence length (a per-sequence group would make
    dispatch quadratic in S at 32k prefill), and the per-device slice
    under (G→data, E→model) sharding stays O(g·C/E) — the property that
    keeps pod-scale MoE lowerable (DESIGN.md §6)."""
    b_in, s_in, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    g = min(getattr(cfg.moe, "group_size", 4096), s_in)
    assert s_in % g == 0, (s_in, g)
    x = x.reshape(b_in * (s_in // g), g, d)
    b, s, _ = x.shape

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                      # [B,S,k]
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)       # renormalize

    capacity = max(int(math.ceil(k * s / e * cfg.moe.capacity_factor)), 1)

    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)       # [B,S,k,E]
    # position of each (token, choice) within its expert queue (per group);
    # priority: earlier tokens first, then lower k
    flat = onehot.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                     # exclusive
    pos = pos.reshape(b, s, k, e)
    pos_idx = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)   # [B,S,k]
    keep = jnp.any((pos < capacity) & (onehot > 0), axis=-1)     # [B,S,k]

    cap_onehot = jax.nn.one_hot(pos_idx, capacity, dtype=jnp.float32)
    disp = jnp.einsum("bske,bskc->bsec", onehot * keep[..., None], cap_onehot)
    comb = jnp.einsum("bske,bskc->bsec",
                      onehot * (topv * keep)[..., None], cap_onehot)

    cd = x.dtype
    expert_in = jnp.einsum("bsec,bsd->becd", disp.astype(cd), x)    # [B,E,C,D]
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("becd,edf->becf", expert_in, p["w_gate"])) * \
            jnp.einsum("becd,edf->becf", expert_in, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", expert_in, p["w_up"]))
    expert_out = jnp.einsum("becf,efd->becd", h, p["w_out"])        # [B,E,C,D]
    y = jnp.einsum("bsec,becd->bsd", comb.astype(cd), expert_out)

    # Switch aux loss: E * Σ_e fraction_tokens(e) * mean_prob(e)
    frac = jnp.mean(onehot.sum(axis=2), axis=(0, 1))                 # [E]
    mean_prob = jnp.mean(probs, axis=(0, 1))                         # [E]
    aux = e * jnp.sum(frac * mean_prob) * cfg.moe.aux_loss_weight
    return y.reshape(b_in, s_in, d), aux.astype(jnp.float32)
