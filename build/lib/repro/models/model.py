"""Unified model API over the decoder-only and encoder–decoder families,
plus the ``input_specs`` used by smoke tests, benchmarks, and the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import encdec, transformer

__all__ = ["Model", "train_input_specs", "serve_input_specs"]


class Model:
    """cfg-bound facade: init / loss / forward / cache / decode."""

    def __init__(self, cfg: ModelConfig, vocab: Optional[int] = None,
                 attn_impl: str = "xla", max_dec_len: int = 448):
        self.cfg = cfg
        self.vocab = vocab or cfg.vocab_size
        self.attn_impl = attn_impl
        self.max_dec_len = max_dec_len

    # -- params ----------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        if self.cfg.family == "encdec":
            return encdec.init_params(key, self.cfg, self.vocab,
                                      max_dec_len=self.max_dec_len)
        return transformer.init_params(key, self.cfg, self.vocab)

    def param_shapes(self) -> Dict[str, Any]:
        return jax.eval_shape(lambda: self.init(jax.random.key(0)))

    # -- training ----------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        if self.cfg.family == "encdec":
            return encdec.loss_fn(params, self.cfg, batch,
                                  attn_impl=self.attn_impl)
        return transformer.loss_fn(params, self.cfg, batch,
                                   attn_impl=self.attn_impl)

    def forward(self, params, batch):
        if self.cfg.family == "encdec":
            return encdec.forward(params, self.cfg, batch["frames"],
                                  batch["tokens"], attn_impl=self.attn_impl)
        return transformer.forward(params, self.cfg, batch["tokens"],
                                   positions=batch.get("positions"),
                                   vision_embeds=batch.get("vision_embeds"),
                                   attn_impl=self.attn_impl)

    # -- serving ----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, params=None,
                   frames=None) -> Dict[str, Any]:
        if self.cfg.family == "encdec":
            return encdec.init_cache(params, self.cfg, frames, max_len,
                                     attn_impl=self.attn_impl)
        return transformer.init_cache(self.cfg, batch, max_len)

    def decode_step(self, params, tokens, cache):
        if self.cfg.family == "encdec":
            return encdec.decode_step(params, self.cfg, tokens, cache)
        return transformer.decode_step(params, self.cfg, tokens, cache)


# ----------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins (no allocation) — dry-run & smoke shapes
# ----------------------------------------------------------------------------
def train_input_specs(cfg: ModelConfig, batch: int, seq: int
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    i32 = jnp.int32
    dt = jnp.dtype(cfg.compute_dtype)
    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
        "labels": jax.ShapeDtypeStruct((batch, seq), i32),
    }
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encdec.n_frames, cfg.d_model), dt)
    if cfg.family == "vlm":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_vision_tokens, cfg.d_model), dt)
        specs["positions"] = jax.ShapeDtypeStruct((3, batch, seq), i32)
    return specs


def serve_input_specs(cfg: ModelConfig, batch: int
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    """One decode step's fresh inputs (cache specs come from eval_shape)."""
    specs = {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}
    return specs
