"""Decoder-only LM assembly: heterogeneous layer groups + scan-over-layers.

A model is a list of *groups*; each group is a repeating *unit* of block
kinds (e.g. ``("attn",)`` for dense, ``("rec","rec","attn")`` for
RecurrentGemma's 1:2 hybrid pattern). Unit parameters are stacked along a
leading ``count`` dimension and the group runs as one ``jax.lax.scan`` —
HLO size stays O(#groups), not O(#layers), which keeps 96-layer/512-device
dry-run compiles tractable (DESIGN.md §6).

The same group structure drives the decode path: caches are stacked per
group and scanned alongside the parameters.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import attention as attn_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .layers import (apply_mlp, apply_norm, init_embedding, init_mlp,
                     init_norm)

Params = Dict[str, Any]


# ----------------------------------------------------------------------------
# structure
# ----------------------------------------------------------------------------
def apply_remat(body, remat: str):
    """Remat policy for the scan body (the §Perf lever set):

    * ``none`` — no remat: everything the backward needs is saved.
    * ``full`` — recompute everything (max memory savings; re-runs the
      tensor-parallel collectives in the backward pass).
    * ``dots`` — save contraction outputs (``dots_saveable``): activations
      that sit *after* the TP all-reduces are kept, so the backward never
      re-pays fwd collectives — at ~4× the saved-activation footprint.
    """
    if remat == "none":
        return body
    if remat == "full":
        return jax.checkpoint(body)
    if remat == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_saveable)
    if remat == "dots_nobatch":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(remat)


def layer_groups(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    if cfg.family in ("dense", "moe", "vlm"):
        return [(("attn",), cfg.n_layers)]
    if cfg.family == "ssm":
        return [(("ssm",), cfg.n_layers)]
    if cfg.family == "hybrid":
        pat = tuple(cfg.hybrid.pattern)
        full, rem = divmod(cfg.n_layers, len(pat))
        groups: List[Tuple[Tuple[str, ...], int]] = [(pat, full)]
        if rem:
            groups.append((pat[:rem], 1))
        return groups
    raise ValueError(cfg.family)


def _init_block(key, cfg: ModelConfig, kind: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "attn":
        p = {"norm1": init_norm(cfg.d_model, cfg.norm, dtype),
             "attn": attn_mod.init_attention(k1, cfg, dtype),
             "norm2": init_norm(cfg.d_model, cfg.norm, dtype)}
        if cfg.family == "moe":
            p["moe"] = moe_mod.init_moe(k2, cfg, dtype)
        else:
            p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
        return p
    if kind == "ssm":
        return {"norm1": init_norm(cfg.d_model, cfg.norm, dtype),
                "ssm": ssm_mod.init_ssm(k1, cfg, dtype)}
    if kind == "rec":
        return {"norm1": init_norm(cfg.d_model, cfg.norm, dtype),
                "rec": rglru_mod.init_rglru(k1, cfg, dtype),
                "norm2": init_norm(cfg.d_model, cfg.norm, dtype),
                "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)}
    raise ValueError(kind)


def init_params(key, cfg: ModelConfig, vocab: Optional[int] = None) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    vocab = vocab or cfg.vocab_size
    keys = jax.random.split(key, 8)
    groups = []
    for gi, (unit, count) in enumerate(layer_groups(cfg)):
        stacked_units = []
        for ci in range(count):
            ku = jax.random.fold_in(keys[0], gi * 10_000 + ci)
            unit_params = [
                _init_block(jax.random.fold_in(ku, pi), cfg, kind, dtype)
                for pi, kind in enumerate(unit)
            ]
            stacked_units.append(unit_params)
        groups.append(jax.tree.map(lambda *xs: jnp.stack(xs), *stacked_units))
    params: Params = {
        "embed": init_embedding(keys[1], vocab, cfg.d_model, dtype),
        "groups": groups,
        "final_norm": init_norm(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = init_embedding(keys[2], vocab, cfg.d_model, dtype).T
    return params


# ----------------------------------------------------------------------------
# forward (training / prefill)
# ----------------------------------------------------------------------------
def _apply_unit(unit_params, cfg: ModelConfig, unit: Tuple[str, ...],
                x: jax.Array, positions, aux: jax.Array,
                attn_impl: str) -> Tuple[jax.Array, jax.Array]:
    from repro.dist import api as dist_api
    x = dist_api.hint(x)
    for block, kind in zip(unit_params, unit):
        if kind == "attn":
            window = cfg.hybrid.window if cfg.family == "hybrid" else None
            h = apply_norm(block["norm1"], x, cfg.norm)
            x = x + attn_mod.attention(block["attn"], cfg, h, positions,
                                       causal=True, window=window,
                                       impl=attn_impl)
            h = apply_norm(block["norm2"], x, cfg.norm)
            if "moe" in block:
                y, a = moe_mod.apply_moe(block["moe"], cfg, h)
                aux = aux + a
            else:
                y = apply_mlp(block["mlp"], h, cfg.mlp)
            x = x + y
        elif kind == "ssm":
            h = apply_norm(block["norm1"], x, cfg.norm)
            x = x + ssm_mod.apply_ssm(block["ssm"], cfg, h)
        elif kind == "rec":
            h = apply_norm(block["norm1"], x, cfg.norm)
            x = x + rglru_mod.apply_rglru(block["rec"], cfg, h)
            h = apply_norm(block["norm2"], x, cfg.norm)
            x = x + apply_mlp(block["mlp"], h, cfg.mlp)
        else:
            raise ValueError(kind)
    return x, aux


def embed_inputs(params: Params, cfg: ModelConfig, tokens: jax.Array,
                 vision_embeds: Optional[jax.Array]) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if vision_embeds is not None:
        p = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x[:, p:, :]], axis=1)
    return x.astype(jnp.dtype(cfg.compute_dtype))


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            positions: Optional[jax.Array] = None,
            vision_embeds: Optional[jax.Array] = None,
            attn_impl: str = "xla") -> Tuple[jax.Array, jax.Array]:
    """tokens [B,S] → (logits [B,S,V], aux_loss)."""
    b, s = tokens.shape
    if positions is None:
        base = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        positions = jnp.broadcast_to(base, (3, b, s)) if cfg.m_rope else base
    x = embed_inputs(params, cfg, tokens, vision_embeds)
    aux = jnp.zeros((), jnp.float32)

    for gi, (unit, count) in enumerate(layer_groups(cfg)):
        gp = params["groups"][gi]

        def body(carry, layer_params, unit=unit):
            x, aux = carry
            x, aux = _apply_unit(layer_params, cfg, unit, x, positions, aux,
                                 attn_impl)
            return (x, aux), None

        body = apply_remat(body, cfg.remat)
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(body, (x, aux), gp)
        else:
            for ci in range(count):
                (x, aux), _ = body((x, aux), jax.tree.map(lambda a: a[ci], gp))

    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ head.astype(x.dtype)
    return logits, aux


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            attn_impl: str = "xla") -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mean next-token cross entropy (+ MoE aux). Sharded-vocab-safe: the
    label logit is picked with a fused compare-select-reduce, not a gather."""
    logits, aux = forward(params, cfg, batch["tokens"],
                          positions=batch.get("positions"),
                          vision_embeds=batch.get("vision_embeds"),
                          attn_impl=attn_impl)
    labels = batch["labels"]
    ce = cross_entropy(logits, labels)
    return ce + aux, {"ce": ce, "aux": aux}


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Sharded-vocab-safe mean CE: the [B,S,V] one-hot select is pinned to
    the vocab sharding (dist_api.hint_vocab) so it never replicates V."""
    from repro.dist import api as dist_api
    lf = dist_api.hint_vocab(logits.astype(jnp.float32))
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    vocab_iota = jnp.arange(lf.shape[-1], dtype=labels.dtype)
    onehot = dist_api.hint_vocab(
        (labels[..., None] == vocab_iota).astype(jnp.float32))
    label_logit = jnp.sum(dist_api.hint_vocab(lf * onehot), axis=-1)
    return jnp.mean(lse - label_logit)


# ----------------------------------------------------------------------------
# decode (one token, cache-carrying)
# ----------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.compute_dtype)
    groups = []
    for unit, count in layer_groups(cfg):
        unit_caches = []
        for kind in unit:
            if kind == "attn":
                unit_caches.append(attn_mod.init_kv_cache(
                    cfg, batch,
                    max_len if cfg.family != "hybrid"
                    else min(max_len, cfg.hybrid.window),
                    dtype, count))
            elif kind == "ssm":
                unit_caches.append(ssm_mod.init_ssm_cache(cfg, batch, dtype, count))
            elif kind == "rec":
                unit_caches.append(rglru_mod.init_rglru_cache(cfg, batch, dtype,
                                                              count))
        groups.append(unit_caches)
    return {"len": jnp.zeros((), jnp.int32), "groups": groups}


def _decode_unit(unit_params, unit_cache, cfg: ModelConfig,
                 unit: Tuple[str, ...], x: jax.Array, cache_len,
                 positions) -> Tuple[jax.Array, list]:
    new_caches = []
    for block, cache, kind in zip(unit_params, unit_cache, unit):
        if kind == "attn":
            window = cfg.hybrid.window if cfg.family == "hybrid" else None
            h = apply_norm(block["norm1"], x, cfg.norm)
            if window is not None:
                # ring-buffer cache for local attention: slot = len % capacity
                slot = jnp.remainder(cache_len, cache["k"].shape[1])
                out, k, v = attn_mod.decode_attention(
                    block["attn"], cfg, h, cache["k"], cache["v"], cache_len,
                    positions, window=None, write_pos=slot)
            else:
                out, k, v = attn_mod.decode_attention(
                    block["attn"], cfg, h, cache["k"], cache["v"], cache_len,
                    positions, window=None)
            x = x + out
            h = apply_norm(block["norm2"], x, cfg.norm)
            if "moe" in block:
                y, _ = moe_mod.apply_moe(block["moe"], cfg, h)
            else:
                y = apply_mlp(block["mlp"], h, cfg.mlp)
            x = x + y
            new_caches.append({"k": k, "v": v})
        elif kind == "ssm":
            h = apply_norm(block["norm1"], x, cfg.norm)
            y, state, conv = ssm_mod.decode_ssm(block["ssm"], cfg, h,
                                                cache["state"], cache["conv"])
            x = x + y
            new_caches.append({"state": state, "conv": conv})
        elif kind == "rec":
            h = apply_norm(block["norm1"], x, cfg.norm)
            y, state, conv = rglru_mod.decode_rglru(block["rec"], cfg, h,
                                                    cache["state"], cache["conv"])
            x = x + y
            h = apply_norm(block["norm2"], x, cfg.norm)
            x = x + apply_mlp(block["mlp"], h, cfg.mlp)
            new_caches.append({"state": state, "conv": conv})
    return x, new_caches


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                cache: Dict[str, Any], *,
                positions: Optional[jax.Array] = None,
                vision_embeds: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """tokens [B,1] + cache → (logits [B,1,V], updated cache)."""
    b = tokens.shape[0]
    cache_len = cache["len"]
    if positions is None:
        base = jnp.broadcast_to(cache_len[None, None], (b, 1)).astype(jnp.int32)
        positions = jnp.broadcast_to(base, (3, b, 1)) if cfg.m_rope else base
    x = embed_inputs(params, cfg, tokens, vision_embeds)

    new_groups = []
    for gi, (unit, count) in enumerate(layer_groups(cfg)):
        gp = params["groups"][gi]
        gc = cache["groups"][gi]

        def body(x, inp, unit=unit):
            layer_params, layer_cache = inp
            x, new_cache = _decode_unit(layer_params, layer_cache, cfg, unit,
                                        x, cache_len, positions)
            return x, new_cache

        if cfg.scan_layers:
            x, new_gc = jax.lax.scan(body, x, (gp, gc))
        else:
            outs = []
            for ci in range(count):
                x, nc = body(x, jax.tree.map(lambda a: a[ci], (gp, gc)))
                outs.append(nc)
            new_gc = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_groups.append(new_gc)

    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ head.astype(x.dtype)
    return logits, {"len": cache_len + 1, "groups": new_groups}
