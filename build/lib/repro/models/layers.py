"""Shared model layers: norms, rotary embeddings (incl. M-RoPE), MLPs.

Pure functions over explicit parameter pytrees (no framework dependency);
initializers return dicts of jnp arrays so param trees stay transparent
for the sharding rule engine in ``repro.dist.sharding``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ----------------------------------------------------------------------------
# normalization
# ----------------------------------------------------------------------------
def init_norm(d: int, kind: str, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        scale = jnp.asarray(p["scale"], jnp.float32)
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * scale).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * jnp.asarray(p["scale"], jnp.float32) + jnp.asarray(p["bias"], jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------------------
# rotary position embeddings
# ----------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B,S,H,D]; positions: [B,S] int32. Half-split (NeoX) convention."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs     # [B,S,d/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_m_rope(x: jax.Array, positions3: jax.Array, theta: float,
                 sections: Tuple[int, int, int]) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL, arXiv:2409.12191).

    ``positions3`` is [3, B, S] — temporal / height / width position ids.
    The head-dim frequency bands are partitioned into ``sections`` (pairs),
    each rotated by its own position stream.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)                                  # (d/2,)
    # section id per frequency pair: [d/2] in {0,1,2}
    sec_ids = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                         total_repeat_length=d // 2)
    # pick the matching position stream per pair
    pos = jnp.take(positions3, sec_ids, axis=0)                   # [d/2, B, S]
    angles = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs  # [B,S,d/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal table [n, d]."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ----------------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------------
def init_mlp(key, d: int, f: int, kind: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    p = {"w_out": jax.random.normal(k3, (f, d), dtype) * s_out}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(k1, (d, f), dtype) * s_in
        p["w_up"] = jax.random.normal(k2, (d, f), dtype) * s_in
    else:
        p["w_up"] = jax.random.normal(k1, (d, f), dtype) * s_in
    return p


def apply_mlp(p: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif kind == "gelu":
        h = jax.nn.gelu(x @ p["w_up"])
    elif kind == "relu2":  # squared ReLU (Primer; Nemotron-4)
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
    else:
        raise ValueError(kind)
    from repro.dist import api as dist_api
    h = dist_api.hint_named(h, "mlp_hidden")
    return h @ p["w_out"]


# ----------------------------------------------------------------------------
def init_embedding(key, vocab: int, d: int, dtype) -> jax.Array:
    return jax.random.normal(key, (vocab, d), dtype) * 0.02


def init_linear(key, d_in: int, d_out: int, dtype, bias: bool = False) -> dict:
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) / math.sqrt(d_in)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def apply_linear(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y
