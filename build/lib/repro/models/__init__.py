"""Model substrate: layers, attention, MoE, SSM, RG-LRU, assemblies."""
from .model import Model, serve_input_specs, train_input_specs

__all__ = ["Model", "serve_input_specs", "train_input_specs"]
