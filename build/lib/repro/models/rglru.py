"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The recurrence  h_t = a_t · h_{t-1} + √(1−a_t²) · (i_t ⊙ x_t)  is linear
in h, so the full sequence runs as a ``jax.lax.associative_scan`` (log-
depth on TPU). Decode is the single-step recurrence against an
(lru_state, conv_state) cache. Sub-quadratic — together with the local-
attention layers this is why recurrentgemma runs the ``long_500k`` cell.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

_C = 8.0  # Griffin's fixed gate sharpness


def _width(cfg: ModelConfig) -> int:
    return cfg.hybrid.lru_width or cfg.d_model


def init_rglru(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    w = _width(cfg)
    cw = cfg.hybrid.conv_width
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    # Λ init so that a = sigmoid(Λ)^c lies in (0.9, 0.999) (paper §2.4)
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9 ** (1 / _C),
                           0.999 ** (1 / _C))
    return {
        "w_x": jax.random.normal(ks[0], (d, w), dtype) * s,        # recurrent branch
        "w_y": jax.random.normal(ks[1], (d, w), dtype) * s,        # gated branch
        "conv_w": jax.random.normal(ks[2], (cw, w), dtype) / math.sqrt(cw),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": jax.random.normal(ks[3], (w, w), dtype) * (1.0 / math.sqrt(w)),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": jax.random.normal(ks[5], (w, w), dtype) * (1.0 / math.sqrt(w)),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": jnp.log(u / (1.0 - u)),                             # logit(a^(1/c))
        "w_out": jax.random.normal(jax.random.fold_in(key, 7), (w, d), dtype)
        / math.sqrt(w),
    }


def _gates(params: dict, x: jax.Array):
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32) + params["b_a"])
    i = jax.nn.sigmoid(xf @ params["w_i"].astype(jnp.float32) + params["b_i"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r      # log a_t  (a in (0,1))
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return a, gated_x


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + pad[:, i:i + x.shape[1], :] * w[i]
    return out + b


def apply_rglru(params: dict, cfg: ModelConfig, u: jax.Array) -> jax.Array:
    """Full-sequence Griffin recurrent block. u: [B,S,D] → [B,S,D]."""
    x = _causal_conv(u @ params["w_x"], params["conv_w"], params["conv_b"])
    a, gx = _gates(params, x)                                  # [B,S,W] f32

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    y = h.astype(u.dtype) * jax.nn.gelu(u @ params["w_y"])
    return y @ params["w_out"]


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype, n_layers: int) -> dict:
    w = _width(cfg)
    cw = cfg.hybrid.conv_width
    return {
        "state": jnp.zeros((n_layers, batch, w), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, cw - 1, w), dtype),
    }


def decode_rglru(params: dict, cfg: ModelConfig, u: jax.Array, state, conv):
    """One step. u: [B,1,D]; state: [B,W]; conv: [B,CW-1,W]."""
    xt = u[:, 0, :] @ params["w_x"]                             # [B,W]
    window = jnp.concatenate([conv, xt[:, None, :]], axis=1)
    new_conv = window[:, 1:, :]
    x = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   params["conv_w"].astype(jnp.float32)) + \
        params["conv_b"].astype(jnp.float32)
    a, gx = _gates(params, x[:, None, :])
    state = a[:, 0] * state + gx[:, 0]
    y = state.astype(u.dtype)[:, None, :] * jax.nn.gelu(u @ params["w_y"])
    return y @ params["w_out"], state, new_conv
