from . import adamw, schedule
from .adamw import AdamWConfig

__all__ = ["adamw", "schedule", "AdamWConfig"]
