"""AdamW with dtype-configurable sharded state + global-norm clipping.

State mirrors the parameter pytree, so under GSPMD the optimizer shards
exactly like the parameters (ZeRO-equivalent partitioning for free) and
updates stay collective-free. ``state_dtype=bfloat16`` halves optimizer
memory for the 340B-class configs (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: str = "float32"


def init(params, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads, state, params, cfg: AdamWConfig,
           lr_scale: jax.Array | float = 1.0
           ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """→ (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c
    lr = cfg.lr * lr_scale
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        mhat = mf / bc1
        vhat = vf / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), mf.astype(sdt), vf.astype(sdt)

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}, \
        {"grad_norm": gnorm}
