"""LR schedules (scale factors composed with AdamWConfig.lr)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(warmup_steps: int, total_steps: int, min_ratio: float = 0.1):
    def schedule(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(warmup_steps, 1), 1.0)
        t = jnp.clip((s - warmup_steps) / jnp.maximum(
            total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return warm * cos
    return schedule


def constant():
    def schedule(step):
        return 1.0
    return schedule
