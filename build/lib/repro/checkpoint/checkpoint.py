"""Manifest-based checkpointing with atomic publication.

Layout::

    <dir>/step_000042/          # complete, published checkpoint
        manifest.json           # treedef, shapes, dtypes, step, metadata
        leaf_00000.npy ...      # one file per pytree leaf (host order)
    <dir>/.tmp_step_000042/     # in-progress (renamed atomically on success)

Restart-safety: a checkpoint is visible iff its directory rename
completed, so a killed writer never leaves a half-readable step. On
multi-host deployments each process writes its addressable shards under
``proc_<k>/`` (single-process containers write one shard set); restore
reassembles by manifest order.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _leaf_paths(tree) -> Tuple[List[Any], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree, *, keep: int = 3,
         metadata: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(directory, f".tmp_{name}")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _leaf_paths(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "process_count": jax.process_count(),
        "leaves": [],
        "metadata": metadata or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        logical = str(arr.dtype)
        if arr.dtype.kind == "V":  # ml_dtypes extension types (bfloat16, fp8)
            arr = np.ascontiguousarray(arr).view(f"u{arr.dtype.itemsize}")
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "file": fname, "shape": list(arr.shape), "dtype": logical})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publication
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)


def all_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        m = _STEP_RE.match(d)
        if m and os.path.exists(os.path.join(directory, d, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: Optional[int] = None, *, target=None,
            shardings=None):
    """Load a checkpoint. ``target`` (a pytree of like-structured values or
    ShapeDtypeStructs) supplies the treedef; ``shardings`` (same structure)
    places leaves onto devices as they load."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = []
    for entry in manifest["leaves"]:
        arr = np.load(os.path.join(path, entry["file"]))
        logical = np.dtype(entry["dtype"])
        if arr.dtype != logical:   # exotic dtype stored as same-width uint
            arr = arr.view(logical)
        arrays.append(arr)
    if target is None:
        return arrays, manifest
    _, treedef = jax.tree.flatten(target)
    tree = jax.tree.unflatten(treedef, arrays)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None else jax.numpy.asarray(a),
            tree, shardings)
    return tree, manifest
