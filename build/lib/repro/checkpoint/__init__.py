from . import checkpoint
from .checkpoint import all_steps, latest_step, restore, save

__all__ = ["checkpoint", "all_steps", "latest_step", "restore", "save"]
