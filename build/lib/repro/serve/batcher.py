"""Dynamic batch formation: max-batch / max-wait policies, shape-bucketed.

The :class:`Batcher` turns the admission queue's request stream into
decode batches. Two policies bound how long a request waits for company:

* **max-batch** — the moment ``max_batch`` same-bucket requests are
  available the batch dispatches, without waiting out the window;
* **max-wait** — once a seed request arrives, the window stays open at
  most ``max_wait_ms``; whatever joined by then goes, so a lone request
  is never held hostage to a batch that might fill later.

Batches are **shape-bucketed**: only requests whose prompt bucket matches
the seed's join, keeping the stacked decode step's shapes uniform (one
compilation per bucket). The engine's continuous-batching join path calls
``take(bucket=..., max_wait_s=0)`` — pinned to the running batch's bucket
and windowless, a running batch never stalls to wait for joiners.
"""
from __future__ import annotations

import time
from typing import List, Optional

from .request import Request, RequestQueue

__all__ = ["Batcher"]


class Batcher:
    def __init__(self, queue: RequestQueue, *, max_batch: int = 8,
                 max_wait_ms: float = 2.0, clock=time.monotonic):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_wait_ms < 0:
            raise ValueError("max_wait_ms must be >= 0")
        self.queue = queue
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self.clock = clock

    def take(self, max_n: Optional[int] = None, *, bucket=None,
             wait_s: float = 0.0,
             max_wait_s: Optional[float] = None) -> List[Request]:
        """Form one batch of up to ``min(max_n, max_batch)`` requests.

        Blocks up to ``wait_s`` for the seed request; once seeded, keeps
        the window open ``max_wait_s`` (default: the configured max-wait)
        for same-bucket requests, returning early the moment the batch is
        full. ``bucket`` pins the batch to a running batch's shape bucket
        (the join path) instead of adopting the seed's. Returns ``[]``
        when nothing arrives in time.
        """
        n = self.max_batch if max_n is None else min(max_n, self.max_batch)
        if n <= 0:
            return []
        seed = self.queue.pop(bucket=bucket, timeout=wait_s)
        if seed is None:
            return []
        batch = [seed]
        if bucket is None:
            bucket = seed.bucket
        window = self.max_wait_s if max_wait_s is None else max_wait_s
        deadline = self.clock() + window
        while len(batch) < n:
            remaining = deadline - self.clock()
            req = self.queue.pop(bucket=bucket, timeout=max(0.0, remaining))
            if req is None:
                break
            batch.append(req)
        return batch

    def take_one(self, *, bucket=None, wait_s: float = 0.0
                 ) -> Optional[Request]:
        """Pop a single request without opening a batching window — the
        prefill stage of a paged engine consumes prompts one at a time
        (pages need no shape bucketing; batching happens at decode)."""
        return self.queue.pop(bucket=bucket, timeout=wait_s)
