"""Latency/throughput accounting for the serve runtime.

The engine records one end-to-end latency and one time-to-first-token per
request; the queue keeps an EWMA of batch-step service time that drives
its SLO-budget load shedding. All summaries report milliseconds — the
unit the paper's sub-second-duty argument is made in.
"""
from __future__ import annotations

import bisect
from typing import Dict, Optional

from repro.analysis.runtime import make_lock

__all__ = ["LatencyStats", "EWMA"]


class LatencyStats:
    """Thread-safe latency reservoir with percentile queries.

    Bounded: past ``maxlen`` samples the oldest half is dropped, so a
    long-lived engine never grows without bound while percentiles stay
    dominated by recent traffic.

    Percentile queries are O(1): an ordered view is maintained
    incrementally on ``record`` (``bisect.insort``) instead of re-sorting
    the full reservoir per call. A mesh router polls every replica's stats
    on each scheduling tick, so ``summary()``/``percentile()`` must stay
    cheap no matter how full the reservoir is (the old per-call sort was
    O(n log n) over up to 100k samples — per tick, per replica).
    """

    def __init__(self, maxlen: int = 100_000):
        self._lock = make_lock("LatencyStats")
        self._samples: list[float] = []    # arrival order (drives eviction)
        self._ordered: list[float] = []    # same samples, kept sorted
        self._sum = 0.0                    # running sum of the reservoir
        self._maxlen = maxlen
        self._count = 0

    def record(self, seconds: float) -> None:
        s = float(seconds)
        with self._lock:
            self._count += 1
            self._samples.append(s)
            bisect.insort(self._ordered, s)
            self._sum += s
            if len(self._samples) > self._maxlen:
                dropped = self._samples[:self._maxlen // 2]
                del self._samples[:self._maxlen // 2]
                self._sum -= sum(dropped)
                # one O(n log n) rebuild per maxlen/2 records, amortized
                # O(log n) per record — never on the query path
                self._ordered = sorted(self._samples)

    @staticmethod
    def _rank(ordered: list, p: float) -> float:
        # nearest-rank on a pre-sorted sample list
        rank = min(len(ordered) - 1,
                   max(0, int(round(p / 100.0 * (len(ordered) - 1)))))
        return ordered[rank]

    def percentile(self, p: float) -> float:
        """The ``p``-th percentile in seconds (nearest-rank); 0.0 when no
        samples were recorded yet."""
        with self._lock:
            if not self._ordered:
                return 0.0
            return self._rank(self._ordered, p)

    def summary(self) -> Dict[str, float]:
        with self._lock:
            if not self._ordered:
                return {"count": self._count, "p50_ms": 0.0, "p95_ms": 0.0,
                        "p99_ms": 0.0, "mean_ms": 0.0, "max_ms": 0.0}
            ordered = self._ordered
            return {
                "count": self._count,
                "p50_ms": self._rank(ordered, 50) * 1e3,
                "p95_ms": self._rank(ordered, 95) * 1e3,
                "p99_ms": self._rank(ordered, 99) * 1e3,
                "mean_ms": self._sum / len(ordered) * 1e3,
                "max_ms": ordered[-1] * 1e3,
            }


class EWMA:
    """Exponentially weighted moving average (service-time estimator)."""

    def __init__(self, alpha: float = 0.2):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.alpha = alpha
        self._value: Optional[float] = None
        self._lock = make_lock("EWMA")

    def update(self, x: float) -> float:
        with self._lock:
            if self._value is None:
                self._value = float(x)
            else:
                self._value += self.alpha * (float(x) - self._value)
            return self._value

    @property
    def value(self) -> Optional[float]:
        return self._value
