"""Paged KV-cache pool: page-granular allocation over the DeviceRef plane.

The monolithic serve path (`ServeEngine` + ``init_fn``) holds each
request's decode state as one contiguous DeviceRef pytree sized for the
worst-case sequence. That wastes device memory on short sequences,
duplicates shared prompt prefixes per request, and — because ``init_fn``
runs inline in the decode loop — lets one long prefill stall every other
request's decode step.

This module is the paged alternative (the vLLM/PagedAttention discipline
mapped onto the actor data plane):

* :class:`PagePool` — a per-device allocator of fixed-size **pages**
  (``page_tokens`` token slots × the cache's per-token leaf shapes). Every
  page leaf is a :class:`~repro.core.memref.DeviceRef`, so pages inherit
  the data plane's rights enforcement, byte accounting, and leak checks.
  The pool registers itself with the process-wide
  :class:`~repro.core.memref.RefRegistry`, which aggregates live/peak page
  counts, sharing, and fragmentation into ``memory_stats()``.
* :class:`PageTable` — one request's mapping from logical token positions
  to pages. ``prepare_append`` reserves the slot for the next token
  (allocating a fresh page at a page boundary, copy-on-write when the
  tail page is shared); ``commit_append`` installs the updated tail
  arrays only after the decode step *succeeded*, which is what keeps a
  replayed step (crashed worker) exactly-once.
* **Prefix reuse** — a completed prefill registers its pages in the
  pool's prefix cache under the prompt key. The pages are *sealed*
  (rights narrowed to ``"r"`` via ``DeviceRef.restrict``) and pinned;
  later requests with the same prompt map the very same pages with no
  new allocation and no prefill compute. A writer that reaches a shared
  page goes through copy-on-write (:meth:`PagePool.cow`); writing a
  sealed page directly raises
  :class:`~repro.core.errors.AccessViolation`.
* :func:`make_prefill_worker` / :func:`make_paged_decode_worker` — the
  actor behaviors for **disaggregated serving**: a prefill worker pool
  consumes admitted prompts and writes their KV pages; the page table is
  handed to the decode engine as plain in-process refs (zero host
  transfers — no spill, no readback). Decode steps gather pages per
  batch slot on device, so the decode batch stays full while prefills
  run elsewhere.

Pages and tables are in-process handles (they wrap device-resident
refs); cross-node disaggregation would spill at the ``repro.net`` wire
like any other ref payload and is out of scope here.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.runtime import make_rlock
from repro.core.errors import AccessViolation
from repro.core.memref import DeviceRef, as_device_array, registry

__all__ = ["Page", "PagePool", "PageTable", "PoolExhausted",
           "make_prefill_worker", "make_paged_decode_worker"]


class PoolExhausted(RuntimeError):
    """No free page and nothing evictable — the request is shed, not the
    engine killed (size ``max_pages`` for max_batch × max sequence)."""


class Page:
    """One fixed-size block of KV storage: ``page_tokens`` token slots for
    every cache leaf, each leaf a :class:`DeviceRef`.

    ``refcount`` counts the holders (requests via their page tables, plus
    the prefix cache's pin). A page is **shared** when more than one
    holder exists or when it was sealed read-only for the prefix cache;
    shared pages must never be written in place — writers copy-on-write
    through :meth:`PagePool.cow` first.
    """

    __slots__ = ("pool", "refs", "refcount", "used", "sealed")

    def __init__(self, pool: "PagePool", refs: List[DeviceRef], used: int):
        self.pool = pool
        self.refs = refs                  # one DeviceRef per cache leaf
        self.refcount = 1
        self.used = used                  # valid token slots written
        self.sealed = False

    @property
    def page_tokens(self) -> int:
        return self.refs[0].shape[0]

    @property
    def shared(self) -> bool:
        return self.sealed or self.refcount > 1

    def arrays(self) -> List[jax.Array]:
        """The per-leaf device arrays (read access — works on sealed
        pages; the decode gather path uses this)."""
        return [r.array for r in self.refs]

    def writable_arrays(self) -> List[jax.Array]:
        """The per-leaf arrays *for writing*. Raises
        :class:`AccessViolation` on a sealed (read-restricted, shared)
        page — the engine must copy-on-write first. This is the safety
        boundary the prefix cache relies on: a buggy writer cannot
        corrupt a sibling request's prefix."""
        for r in self.refs:
            if not r.writable:
                raise AccessViolation(
                    "page is read-restricted (shared prefix); writing "
                    "requires a private copy — the engine must "
                    "copy-on-write (PagePool.cow) before appending")
        return [r.array for r in self.refs]

    def _seal(self) -> None:
        """Narrow every leaf to read-only (``restrict('r')``) — called
        when the page enters the prefix cache. Idempotent."""
        if self.sealed:
            return
        narrowed = [r.restrict("r") for r in self.refs]
        for r in self.refs:
            r.release()
        self.refs = narrowed
        self.sealed = True

    def _replace(self, new_arrays: Sequence[jax.Array]) -> None:
        """Swap in updated leaf arrays (a committed decode write). Only
        legal on a private page — the engine guarantees that via
        ``prepare_append``."""
        if self.sealed:
            raise AccessViolation(
                "cannot replace the contents of a sealed (shared) page")
        old = self.refs
        self.refs = [DeviceRef(a) for a in new_arrays]
        for r in old:
            r.release()

    def __repr__(self):
        return (f"Page(tokens={self.used}/{self.page_tokens}, "
                f"refcount={self.refcount}, "
                f"{'sealed' if self.sealed else 'rw'})")


class _PrefixEntry:
    __slots__ = ("pages", "length", "first_token")

    def __init__(self, pages, length, first_token):
        self.pages = pages
        self.length = length
        self.first_token = first_token


class PagePool:
    """Fixed-capacity allocator of KV pages on one device.

    ``leaf_specs`` describes the cache's per-token layout: one
    ``(shape, dtype)`` per leaf, *excluding* the leading token axis — a
    page for leaf ``i`` is an array of shape ``(page_tokens, *shape_i)``.
    Use :meth:`for_entries` to derive the specs (and the pytree
    structure) from an example prefill result.

    All mutation goes through the pool lock; the pool registers itself
    with the DeviceRef :class:`~repro.core.memref.RefRegistry` so page
    pressure shows up in ``memory_stats()`` /
    ``DeviceManager.memory_stats()`` next to the byte watermarks.
    """

    def __init__(self, leaf_specs: Sequence[Tuple[tuple, Any]],
                 treedef=None, *, page_tokens: int = 16,
                 max_pages: int = 256, device=None, max_prefixes: int = 64):
        if page_tokens < 1:
            raise ValueError("page_tokens must be >= 1")
        if max_pages < 1:
            raise ValueError("max_pages must be >= 1")
        if not leaf_specs:
            raise ValueError("need at least one cache leaf spec")
        self.leaf_specs = [(tuple(s), np.dtype(d)) for s, d in leaf_specs]
        self.treedef = treedef
        self.page_tokens = int(page_tokens)
        self.max_pages = int(max_pages)
        self.max_prefixes = int(max_prefixes)
        self.device = getattr(device, "jax_device", device)
        # reentrant: eviction under allocation pressure releases pages
        # while the allocation already holds the lock
        self._lock = make_rlock("PagePool")
        self._pages: set = set()          # live Page objects (bookkeeping)
        self._live = 0
        self._peak = 0
        self._prefix: "OrderedDict[Any, _PrefixEntry]" = OrderedDict()
        self.counters = {"allocated": 0, "freed": 0, "cow": 0,
                         "prefix_hits": 0, "prefix_misses": 0,
                         "prefix_evicted": 0}
        registry.register_pool(self)

    @classmethod
    def for_entries(cls, example_entries, **kw) -> "PagePool":
        """Derive leaf specs from an example prefill result: a pytree
        whose leaves are ``[T, *per_token_shape]`` arrays."""
        leaves, treedef = jax.tree_util.tree_flatten(example_entries)
        if not leaves:
            raise ValueError("example entries pytree has no leaves")
        specs = [(tuple(np.shape(l)[1:]), np.asarray(l).dtype
                  if not hasattr(l, "dtype") else l.dtype) for l in leaves]
        return cls(specs, treedef, **kw)

    # -- allocation ------------------------------------------------------
    def _new_page(self, arrays: List[jax.Array], used: int) -> Page:
        with self._lock:
            if self._live >= self.max_pages:
                self._evict_for_space()
            if self._live >= self.max_pages:
                raise PoolExhausted(
                    f"page pool exhausted ({self.max_pages} pages of "
                    f"{self.page_tokens} tokens); nothing evictable")
            refs = []
            try:
                for a, (shape, dtype) in zip(arrays, self.leaf_specs):
                    arr = as_device_array(a, device=self.device)
                    if tuple(arr.shape) != (self.page_tokens,) + shape:
                        raise ValueError(
                            f"page leaf shape {tuple(arr.shape)} != "
                            f"{(self.page_tokens,) + shape}")
                    refs.append(DeviceRef(arr))
            except BaseException:
                for r in refs:
                    r.release()
                raise
            page = Page(self, refs, used)
            self._pages.add(page)
            self._live += 1
            self._peak = max(self._peak, self._live)
            self.counters["allocated"] += 1
            return page

    def alloc_page(self, used: int = 0) -> Page:
        """A fresh zero-filled private page (the decode tail allocation)."""
        arrays = [jnp.zeros((self.page_tokens,) + shape, dtype=dtype)
                  for shape, dtype in self.leaf_specs]
        return self._new_page(arrays, used)

    def write_pages(self, entries) -> Tuple[List[Page], int]:
        """Slice a prefill result (leaves ``[T, *per_token]``) into pages.

        Full pages are carved straight out of the entry arrays (no
        zero-init); a partial tail page is zero-padded to ``page_tokens``.
        On any failure the pages already carved are released — a crashed
        or replayed prefill never leaks."""
        leaves = jax.tree_util.tree_leaves(entries)
        if len(leaves) != len(self.leaf_specs):
            raise ValueError(
                f"prefill entries have {len(leaves)} leaves; pool expects "
                f"{len(self.leaf_specs)}")
        length = int(np.shape(leaves[0])[0])
        for l in leaves:
            if int(np.shape(l)[0]) != length:
                raise ValueError("prefill entry leaves disagree on length")
        pt = self.page_tokens
        n_pages = max(1, math.ceil(length / pt))
        pages: List[Page] = []
        try:
            for p in range(n_pages):
                lo, hi = p * pt, min((p + 1) * pt, length)
                arrays = []
                for leaf, (shape, dtype) in zip(leaves, self.leaf_specs):
                    chunk = jnp.asarray(leaf[lo:hi], dtype=dtype)
                    if hi - lo < pt:
                        pad = jnp.zeros((pt,) + shape, dtype=dtype)
                        chunk = pad.at[:hi - lo].set(chunk)
                    arrays.append(chunk)
                pages.append(self._new_page(arrays, used=hi - lo))
        except BaseException:
            self.release_pages(pages)
            raise
        return pages, length

    def cow(self, page: Page) -> Page:
        """Copy-on-write: a private clone of ``page`` for a diverging
        writer. JAX arrays are immutable, so the clone aliases the same
        device buffers — the actual copy happens at the first
        ``.at[...].set`` write, which is exactly the "on write" in
        copy-on-write. Counts as a fresh page against the pool cap."""
        with self._lock:
            clone = self._new_page(page.arrays(), used=page.used)
            self.counters["cow"] += 1
            return clone

    # -- holder accounting ----------------------------------------------
    def retain(self, page: Page) -> Page:
        with self._lock:
            page.refcount += 1
            return page

    def release_page(self, page: Page) -> None:
        with self._lock:
            if page not in self._pages:
                return                    # already fully freed
            page.refcount -= 1
            if page.refcount <= 0:
                for r in page.refs:
                    r.release()
                page.refs = []
                self._pages.discard(page)
                self._live -= 1
                self.counters["freed"] += 1

    def release_pages(self, pages: Sequence[Page]) -> None:
        for p in pages:
            self.release_page(p)

    # -- prefix cache ----------------------------------------------------
    @staticmethod
    def prefix_key(prompt) -> Any:
        """A hashable key for a prompt (token tuple for array-likes)."""
        try:
            arr = np.asarray(prompt)
        except Exception:
            return prompt
        if arr.dtype == object:
            return prompt
        if arr.ndim == 0:
            return (arr.item(),)
        return tuple(arr.ravel().tolist())

    def prefix_lookup(self, key) -> Optional[Tuple[List[Page], int, Any]]:
        """Map a cached prefix: returns ``(pages, length, first_token)``
        with every page retained for the caller, or None on miss. The
        pages come back sealed (read-only) — appending past them goes
        through copy-on-write."""
        with self._lock:
            entry = self._prefix.get(key)
            if entry is None:
                self.counters["prefix_misses"] += 1
                return None
            self._prefix.move_to_end(key)          # LRU touch
            for p in entry.pages:
                p.refcount += 1
            self.counters["prefix_hits"] += 1
            return list(entry.pages), entry.length, entry.first_token

    def prefix_insert(self, key, pages: List[Page], length: int,
                      first_token) -> Tuple[List[Page], int, Any]:
        """Publish a completed prefill's pages under ``key``: seals them
        read-only and pins them (one refcount held by the cache). If a
        concurrent prefill of the same prompt won the race, the caller's
        pages are released and the canonical entry returned instead —
        shared-prefix pages stay allocated exactly once."""
        with self._lock:
            entry = self._prefix.get(key)
            if entry is not None:
                self._prefix.move_to_end(key)
                for p in entry.pages:
                    p.refcount += 1
                self.release_pages(pages)          # loser's copy
                return list(entry.pages), entry.length, entry.first_token
            for p in pages:
                p._seal()
                p.refcount += 1                    # the cache's pin
            self._prefix[key] = _PrefixEntry(list(pages), length,
                                             first_token)
            while len(self._prefix) > self.max_prefixes:
                self._evict_one_locked()
            return list(pages), length, first_token

    def _evict_one_locked(self) -> bool:
        if not self._prefix:
            return False
        _, entry = self._prefix.popitem(last=False)   # LRU out
        self.release_pages(entry.pages)
        self.counters["prefix_evicted"] += 1
        return True

    def _evict_for_space(self) -> None:
        """Under allocation pressure, drop prefix entries whose pages are
        held *only* by the cache pin (their owning requests finished) —
        those free real pages; entries still mapped by live requests
        would not, so they are kept."""
        for key in list(self._prefix):
            if self._live < self.max_pages:
                return
            entry = self._prefix[key]
            if all(p.refcount == 1 for p in entry.pages):
                del self._prefix[key]
                self.release_pages(entry.pages)
                self.counters["prefix_evicted"] += 1

    def evict_prefixes(self) -> int:
        """Drop every prefix entry (tests / explicit teardown); pages
        still mapped by running requests survive until those release."""
        with self._lock:
            n = 0
            while self._evict_one_locked():
                n += 1
            return n

    # -- observability ---------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            used = sum(p.used for p in self._pages)
            slots = self._live * self.page_tokens
            shared = sum(1 for p in self._pages if p.shared)
            return {
                "page_tokens": self.page_tokens,
                "pages_total": self.max_pages,
                "pages_live": self._live,
                "pages_free": self.max_pages - self._live,
                "pages_shared": shared,
                "peak_pages": self._peak,
                "used_slots": used,
                "page_slots": slots,
                "fragmentation": (1.0 - used / slots) if slots else 0.0,
                "prefix_entries": len(self._prefix),
                **self.counters,
            }


class PageTable:
    """One request's logical-token-position → page mapping.

    ``length`` is the number of valid tokens; position ``p`` lives in
    page ``p // page_tokens`` at offset ``p % page_tokens``. The decode
    engine drives the two-phase append: :meth:`prepare_append` *reserves*
    the slot (fresh page at a boundary, copy-on-write when the tail is
    shared) before dispatching the step, and :meth:`commit_append`
    installs the worker's updated tail arrays only after the step
    succeeded — a replayed step re-reads the unmodified pages.
    """

    __slots__ = ("pool", "pages", "length")

    def __init__(self, pool: PagePool, pages: Optional[List[Page]] = None,
                 length: int = 0):
        self.pool = pool
        self.pages = list(pages) if pages else []
        self.length = int(length)

    @property
    def capacity(self) -> int:
        return len(self.pages) * self.pool.page_tokens

    def tail_offset(self) -> int:
        """Offset inside the tail page where the *next* token lands."""
        return self.length - (len(self.pages) - 1) * self.pool.page_tokens

    def prepare_append(self) -> Tuple[Page, int]:
        """Reserve the slot for token ``length``: allocate a page at a
        page boundary; copy-on-write when the tail page is shared (the
        divergence point of a shared prefix). Returns (tail, offset)."""
        pt = self.pool.page_tokens
        if self.length == self.capacity:
            self.pages.append(self.pool.alloc_page())
        else:
            tail = self.pages[-1]
            if tail.shared:
                clone = self.pool.cow(tail)
                self.pool.release_page(tail)
                self.pages[-1] = clone
        return self.pages[-1], self.length - (len(self.pages) - 1) * pt

    def commit_append(self, new_tail_arrays: Sequence[jax.Array]) -> None:
        """Install the decode step's updated tail-page arrays and advance
        ``length`` — called only after the step succeeded."""
        tail = self.pages[-1]
        tail._replace(list(new_tail_arrays))
        self.length += 1
        tail.used = max(tail.used, self.tail_offset())

    def gather(self):
        """The request's full cache as one pytree (leaves concatenated
        over its pages, ``[capacity, *per_token]``) — test/debug surface;
        the decode worker does the batched equivalent on device."""
        cols = [jnp.concatenate([p.arrays()[i] for p in self.pages])
                for i in range(len(self.pool.leaf_specs))]
        if self.pool.treedef is None:
            return tuple(cols)
        return jax.tree_util.tree_unflatten(self.pool.treedef, cols)

    def release_pages(self) -> int:
        """Return every page to the pool (idempotent). Recognized by
        :func:`repro.core.memref.tree_release`, so a speculative-race
        loser's page table handed back through the ChunkScheduler is
        reclaimed like any DeviceRef payload."""
        pages, self.pages = self.pages, []
        self.pool.release_pages(pages)
        return len(pages)

    def __repr__(self):
        return (f"PageTable({self.length} tokens over {len(self.pages)} "
                f"pages of {self.pool.page_tokens})")


# ----------------------------------------------------------------------------
# actor behaviors: the disaggregated prefill / decode split
# ----------------------------------------------------------------------------
def make_prefill_worker(prefill_fn: Callable, pool: PagePool, *,
                        share_prefixes: bool = True) -> Callable:
    """The prefill-phase actor behavior.

    ``prefill_fn(prompt) → (entries, first_token)`` where ``entries`` is
    the prompt's KV pytree with leaves ``[T, *per_token]``. The worker
    writes the entries into pool pages and returns ``(PageTable,
    first_token, prefix_hit)`` — a pure ref handoff, no host transfer.

    With ``share_prefixes`` (default) the prompt key is checked against
    the pool's prefix cache first: a hit maps the cached (sealed) pages
    with **zero** new allocation and zero prefill compute; a miss
    publishes the freshly written pages for the next request. Page
    allocation is all-or-nothing, so a worker that crashes mid-prefill
    (and is replayed exactly-once by the ChunkScheduler) leaks nothing.
    """

    def prefill(tag: str, prompt):
        if tag != "prefill":
            raise ValueError(f"prefill worker got unknown message {tag!r}")
        key = pool.prefix_key(prompt) if share_prefixes else None
        if key is not None:
            hit = pool.prefix_lookup(key)
            if hit is not None:
                pages, length, first = hit
                return PageTable(pool, pages=pages, length=length), first, True
        entries, first = prefill_fn(prompt)
        pages, length = pool.write_pages(entries)
        if key is not None:
            pages, length, first = pool.prefix_insert(key, pages, length,
                                                      first)
        return PageTable(pool, pages=pages, length=length), first, False

    return prefill


def make_paged_decode_worker(step_fn: Callable, pool: PagePool, *,
                             jit: bool = True) -> Callable:
    """The decode-phase actor behavior over paged caches.

    ``step_fn(kv, lengths[B], tokens[B]) → (next_tokens[B], entries)``
    where ``kv`` is the cache pytree with leaves ``[B, T, *per_token]``
    (``T`` = the batch's max page capacity; positions ≥ ``lengths[b]``
    are padding) and ``entries`` has leaves ``[B, *per_token]`` — the new
    token's KV entry, which the worker writes into each request's tail
    page at its reserved offset.

    Per step the worker *gathers* each request's pages into the batched
    ``kv`` on device (no host traffic), runs the jitted step, and
    returns the updated tail arrays — it never mutates the pages, so a
    crashed step replays verbatim on another replica. Writing the tail
    goes through :meth:`Page.writable_arrays`: if the engine ever handed
    over a still-shared tail, the step fails with ``AccessViolation``
    instead of corrupting a sibling request's prefix.
    """
    fn = jax.jit(step_fn) if jit else step_fn
    pt = pool.page_tokens
    nleaves = len(pool.leaf_specs)

    def decode(tag: str, tokens: tuple, rows: tuple):
        if tag != "pstep":
            raise ValueError(f"decode worker got unknown message {tag!r}")
        nreq = len(rows)
        max_pages = max(len(pages) for pages, _ in rows)
        cols = []
        for i in range(nleaves):
            shape, dtype = pool.leaf_specs[i]
            pad = None
            per_req = []
            for pages, _length in rows:
                arrs = [p.arrays()[i] for p in pages]
                if len(pages) < max_pages:
                    if pad is None:
                        pad = jnp.zeros((pt,) + shape, dtype=dtype)
                    arrs.extend([pad] * (max_pages - len(pages)))
                per_req.append(jnp.concatenate(arrs) if len(arrs) > 1
                               else arrs[0])
            cols.append(jnp.stack(per_req))
        kv = (tuple(cols) if pool.treedef is None
              else jax.tree_util.tree_unflatten(pool.treedef, cols))
        lengths = jnp.asarray([length for _, length in rows], jnp.int32)
        # claim the tail writes up front: a shared tail fails loudly here
        # (AccessViolation), before any compute is spent
        tails = [pages[-1].writable_arrays() for pages, _ in rows]
        new_tokens, entries = fn(kv, lengths, jnp.asarray(tokens))
        entry_leaves = jax.tree_util.tree_leaves(entries)
        if len(entry_leaves) != nleaves:
            raise ValueError(
                f"paged step returned {len(entry_leaves)} entry leaves; "
                f"the pool's cache has {nleaves}")
        out = []
        for b, (pages, length) in enumerate(rows):
            off = length - (len(pages) - 1) * pt
            out.append(tuple(tails[b][i].at[off].set(entry_leaves[i][b])
                             for i in range(nleaves)))
        return np.asarray(jax.device_get(new_tokens)), tuple(out)

    return decode
