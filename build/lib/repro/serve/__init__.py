"""``repro.serve`` — asynchronous continuous-batching request engine.

Layered on the actor data plane built in PRs 1–2: requests are admitted
with deadlines and priorities (:class:`RequestQueue`), formed into
shape-bucketed dynamic batches (:class:`Batcher`), and decoded
multi-step by the :class:`ServeEngine`, whose per-request caches stay
device-resident as :class:`~repro.core.memref.DeviceRef` pytrees between
steps. The paged mode (:class:`PagePool` + ``ServeEngine(cache_pool=...)``)
disaggregates serving into prefill and decode phases over a page-granular
KV-cache allocator with copy-free prefix sharing. The mesh layer
(:class:`MeshRouter` + :class:`EngineReplica`) shards requests across
engine replicas on worker nodes reached through ``repro.net``, with
prefix/session-affine routing, SLO-driven autoscaling, and exactly-once
replay of requests in flight on a node that dies. See the README's
"Serving", "Paged KV cache", and "Serve mesh" sections for diagrams and
knobs.
"""
from .batcher import Batcher
from .engine import (EngineStopped, ServeEngine, make_decode_worker,
                     make_graph_decode_worker)
from .kvpool import (Page, PagePool, PageTable, PoolExhausted,
                     make_paged_decode_worker, make_prefill_worker)
from .mesh import (EngineReplica, MeshDown, MeshRouter, ReplicaSpec,
                   local_replica_stats)
from .request import (AdmissionError, QueueClosed, QueueOverflow, Request,
                      RequestQueue, ServeResult, SLOExceeded)
from .stats import EWMA, LatencyStats

__all__ = [
    "Batcher",
    "EngineStopped", "ServeEngine", "make_decode_worker",
    "make_graph_decode_worker",
    "Page", "PagePool", "PageTable", "PoolExhausted",
    "make_paged_decode_worker", "make_prefill_worker",
    "EngineReplica", "MeshDown", "MeshRouter", "ReplicaSpec",
    "local_replica_stats",
    "AdmissionError", "QueueClosed", "QueueOverflow", "Request",
    "RequestQueue", "ServeResult", "SLOExceeded",
    "EWMA", "LatencyStats",
]
