"""Request admission: deadlines, priorities, backpressure, load shedding.

A :class:`Request` is one client decode job (prompt → up to
``max_new_tokens`` tokens) with a priority and an optional absolute
deadline. The :class:`RequestQueue` orders admitted requests by
``(priority, deadline, arrival)`` and enforces two protection mechanisms
the engine's SLO depends on:

* **backpressure** — ``submit(block=True)`` waits for queue space, pacing
  a well-behaved client down to the engine's actual throughput;
* **load shedding** — a non-blocking submit against a full queue, a
  request whose deadline already passed, or an estimated queue wait above
  the SLO budget is rejected *at admission* (cheap) instead of timing out
  after consuming device time (expensive).

The wait estimate is ``queue depth × EWMA(batch-step service time)``; the
engine feeds the EWMA after every decode step.
"""
from __future__ import annotations

import bisect
import itertools
import math
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

import numpy as np

from .stats import EWMA

__all__ = ["Request", "ServeResult", "RequestQueue",
           "AdmissionError", "QueueOverflow", "QueueClosed", "SLOExceeded"]


class AdmissionError(RuntimeError):
    """Base class for requests rejected at the queue boundary."""


class QueueOverflow(AdmissionError):
    """Non-blocking submit against a full queue (load shed)."""


class QueueClosed(AdmissionError):
    """Submit after the engine began draining/shutdown."""


class SLOExceeded(AdmissionError):
    """Admission would already bust the SLO budget (expired deadline or
    estimated queue wait beyond the budget) — shed instead of serving a
    guaranteed-late response."""


@dataclass
class ServeResult:
    """What a completed request resolves to."""

    request_id: int
    tokens: List[Any]
    latency_s: float          # submit → last token
    ttft_s: float             # submit → first token
    steps: int = 0            # decode steps this request participated in
    prefix_hit: bool = False  # paged engine: prefill served from the
                              # pool's shared-prefix cache (no KV compute)


class Request:
    """One client job travelling through queue → batcher → engine."""

    _ids = itertools.count()

    __slots__ = ("id", "prompt", "max_new_tokens", "priority", "deadline",
                 "bucket", "future", "tokens", "last_token", "t_submit",
                 "t_first", "t_ready")

    def __init__(self, prompt, *, max_new_tokens: int = 8, priority: int = 0,
                 deadline: Optional[float] = None, bucket=None):
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        self.id = next(Request._ids)
        self.prompt = prompt
        self.max_new_tokens = int(max_new_tokens)
        self.priority = int(priority)
        self.deadline = deadline          # absolute time.monotonic() or None
        #: shape bucket for batch formation — requests only batch with
        #: same-shaped peers so the stacked decode step compiles once per
        #: bucket instead of per composition
        self.bucket = bucket if bucket is not None else np.shape(prompt)
        self.future: Future = Future()
        self.tokens: List[Any] = []
        self.last_token: Any = None
        self.t_submit = time.monotonic()
        self.t_first: Optional[float] = None
        #: paged engine: when prefill finished and the page table became
        #: ready for decode (None in monolithic mode)
        self.t_ready: Optional[float] = None

    def __repr__(self):
        return (f"Request#{self.id}(bucket={self.bucket}, "
                f"prio={self.priority}, n={self.max_new_tokens})")


# sort key: urgent first — lower priority value wins, then earlier
# deadline (None sorts last), then arrival order
def _entry_key(req: Request, seq: int) -> Tuple:
    return (req.priority,
            req.deadline if req.deadline is not None else math.inf,
            seq)


class RequestQueue:
    """Thread-safe admission queue ordered by (priority, deadline, arrival).

    ``pop(bucket=...)`` returns the most urgent request *of that shape
    bucket*, leaving other buckets queued — the batcher uses this to keep
    batches shape-homogeneous without reordering across buckets.
    """

    def __init__(self, *, max_depth: int = 1024,
                 slo_budget_s: Optional[float] = None,
                 clock=time.monotonic):
        if max_depth < 1:
            raise ValueError("max_depth must be >= 1")
        self.max_depth = max_depth
        self.slo_budget_s = slo_budget_s
        self.clock = clock
        self.service_time = EWMA()
        self._entries: List[Tuple[Tuple, Request]] = []  # sorted by key
        self._seq = itertools.count()
        self._cv = threading.Condition()
        self._closed = False
        # shed/admission counters (engine.stats() surfaces these)
        self.admitted = 0
        self.shed = 0

    # -- introspection ----------------------------------------------------
    def __len__(self) -> int:
        with self._cv:
            return len(self._entries)

    def estimated_wait(self) -> float:
        """Seconds a newly admitted request would expect to queue: depth ×
        the engine-fed EWMA of batch-step service time (0 until the first
        step completes)."""
        est = self.service_time.value or 0.0
        return (len(self) + 1) * est

    def note_service_time(self, seconds: float) -> None:
        self.service_time.update(seconds)

    # -- admission --------------------------------------------------------
    def submit(self, req: Request, *, block: bool = False,
               timeout: Optional[float] = None) -> Request:
        """Admit ``req`` or raise an :class:`AdmissionError` subclass."""
        with self._cv:
            if self._closed:
                raise QueueClosed("request queue is closed")
            now = self.clock()
            if req.deadline is not None and req.deadline <= now:
                self.shed += 1
                raise SLOExceeded(
                    f"request {req.id} deadline already passed at admission")
            if len(self._entries) >= self.max_depth:
                if not block:
                    self.shed += 1
                    raise QueueOverflow(
                        f"queue full ({self.max_depth}); request {req.id} "
                        "shed")
                end = None if timeout is None else now + timeout
                while len(self._entries) >= self.max_depth \
                        and not self._closed:
                    remaining = None if end is None else end - self.clock()
                    if remaining is not None and remaining <= 0:
                        self.shed += 1
                        raise QueueOverflow(
                            f"queue full after {timeout}s backpressure wait")
                    self._cv.wait(remaining)
                if self._closed:
                    raise QueueClosed("request queue closed while waiting")
            if self.slo_budget_s is not None:
                est = self.service_time.value
                if est and (len(self._entries) + 1) * est > self.slo_budget_s:
                    self.shed += 1
                    raise SLOExceeded(
                        f"estimated wait {(len(self._entries) + 1) * est:.3f}s"
                        f" exceeds SLO budget {self.slo_budget_s}s")
            entry = (_entry_key(req, next(self._seq)), req)
            bisect.insort(self._entries, entry, key=lambda e: e[0])
            self.admitted += 1
            self._cv.notify_all()
        return req

    # -- consumption ------------------------------------------------------
    def pop(self, *, bucket=None, timeout: Optional[float] = None
            ) -> Optional[Request]:
        """The most urgent request (optionally only from ``bucket``), or
        None after ``timeout`` seconds with no match (``timeout=0`` is a
        non-blocking scan; ``None`` blocks until a match or close)."""
        end = None if timeout is None else self.clock() + timeout
        with self._cv:
            while True:
                for i, (_, req) in enumerate(self._entries):
                    if bucket is None or req.bucket == bucket:
                        del self._entries[i]
                        self._cv.notify_all()  # wake backpressured submits
                        return req
                if self._closed and not self._entries:
                    return None
                if end is not None:
                    remaining = end - self.clock()
                    if remaining <= 0:
                        return None
                    self._cv.wait(remaining)
                else:
                    self._cv.wait()

    def close(self) -> None:
        """Stop admissions; queued requests remain poppable (drain)."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed
