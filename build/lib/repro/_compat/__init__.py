"""Compatibility fallbacks for optional third-party dependencies."""
