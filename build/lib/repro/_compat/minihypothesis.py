"""Minimal, API-compatible subset of `hypothesis` for environments where
the real library is unavailable (this container bakes in the JAX/Pallas
toolchain but no extras; nothing may be pip-installed at test time).

Covers exactly what ``tests/test_property.py`` uses — ``@given`` with
keyword strategies, ``@settings(max_examples=..., deadline=...)``, and
``strategies.{integers,floats,lists}`` — as a deterministic random search
(seeded per test) with no shrinking. The root ``conftest.py`` installs
this module under the ``hypothesis`` name **only when** the real package
cannot be imported; with hypothesis installed this file is inert.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
from typing import Any, Callable, Optional

__all__ = ["given", "settings", "assume", "strategies", "HealthCheck"]


class _Unsatisfied(Exception):
    """Raised by assume(); the example is skipped, not failed."""


def assume(condition: bool) -> bool:
    if not condition:
        raise _Unsatisfied
    return True


class HealthCheck:  # placeholder namespace for suppress_health_check=...
    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"


class SearchStrategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example(self) -> Any:
        return self._draw(random.Random())

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda r: fn(self._draw(r)))

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        def draw(r: random.Random):
            for _ in range(1000):
                v = self._draw(r)
                if pred(v):
                    return v
            raise _Unsatisfied
        return SearchStrategy(draw)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda r: r.randint(min_value, max_value))


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_ignored) -> SearchStrategy:
    return SearchStrategy(lambda r: r.uniform(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda r: bool(r.getrandbits(1)))


def sampled_from(options) -> SearchStrategy:
    options = list(options)
    return SearchStrategy(lambda r: r.choice(options))


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int = 10, **_ignored) -> SearchStrategy:
    def draw(r: random.Random):
        n = r.randint(min_size, max_size)
        return [elements._draw(r) for _ in range(n)]
    return SearchStrategy(draw)


def tuples(*strats: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda r: tuple(s._draw(r) for s in strats))


class settings:
    """Decorator recording run parameters for ``given`` to pick up."""

    def __init__(self, max_examples: int = 100,
                 deadline: Optional[float] = None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._mh_settings = self
        return fn


def given(*pos_strategies, **kw_strategies):
    """Deterministic random search over the declared strategies.

    Each example draws from a generator seeded by (test name, example
    index), so failures reproduce run-to-run without a database.
    """
    if pos_strategies:
        raise NotImplementedError(
            "minihypothesis supports keyword strategies only")

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(fn, "_mh_settings", None)
            n = cfg.max_examples if cfg is not None else 100
            ran = 0
            attempt = 0
            while ran < n and attempt < 20 * n:
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}"
                                    f":{attempt}")
                attempt += 1
                drawn = {}
                try:
                    drawn = {k: s._draw(rng)
                             for k, s in kw_strategies.items()}
                    fn(*args, **drawn, **kwargs)
                except _Unsatisfied:
                    continue
                except Exception as exc:
                    raise AssertionError(
                        f"falsifying example (attempt {attempt - 1}): "
                        f"{drawn}") from exc
                ran += 1
            return None

        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        # pytest must not see the strategy-filled parameters as fixtures
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        params = [p for name, p in
                  inspect.signature(fn).parameters.items()
                  if name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(params)
        return wrapper

    return decorate


# `from hypothesis import strategies as st` needs a module-like attribute
strategies = types.ModuleType("hypothesis.strategies")
for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
              "tuples"):
    setattr(strategies, _name, globals()[_name])
strategies.SearchStrategy = SearchStrategy
