"""repro: OpenCL-actor-style data-parallel runtime + LM framework in JAX.

Paper: "OpenCL Actors — Adding Data Parallelism to Actor-based Programming
with CAF" (Hiesgen, Charousset, Schmidt; Agere/LNCS 2017), adapted to
JAX/TPU. See DESIGN.md.
"""
__version__ = "0.1.0"

# jax < 0.5 compat: expose the stable jax.shard_map spelling, and
# normalize Compiled.cost_analysis() to the modern single-dict return
# (older versions hand back a one-element list per executable).
import jax as _jax

if not hasattr(_jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    _jax.shard_map = _shard_map

    _orig_cost_analysis = _jax.stages.Compiled.cost_analysis

    def _cost_analysis(self):
        out = _orig_cost_analysis(self)
        if isinstance(out, list):
            out = out[0] if out else {}
        return out

    _jax.stages.Compiled.cost_analysis = _cost_analysis
del _jax
