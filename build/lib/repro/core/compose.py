"""Actor composition — multi-stage kernel pipelines (paper §3.5).

The unified builder lives in :class:`repro.core.api.Pipeline`; this
module keeps the v1 surface as thin shims plus the :class:`ComposedActor`
runtime primitive both levels share:

* :func:`compose` — **staged** composition (``Pipeline(mode="staged")``).
  ``C = B ⊙ A`` spawns a new actor that forwards any message to ``A`` and
  delegates ``A``'s response to ``B`` via a response *promise*. When
  stages exchange :class:`~repro.core.memref.DeviceRef` payloads,
  intermediate data stays device-resident; because JAX dispatch is
  asynchronous, stage *n+1* is enqueued while stage *n* still runs on the
  device — the paper's OpenCL-event chaining.

* :func:`fuse` — **fused** composition (``Pipeline(mode="fused")``; "an
  alternative level of composition uses kernels as building blocks to
  compose a single OpenCL actor", §3.6). The stage callables are traced
  into one jit program, eliminating per-stage dispatch *and* letting XLA
  fuse across stage boundaries.

Both functions are deprecated in favor of the Pipeline builder.
"""
from __future__ import annotations

import warnings
from concurrent.futures import Future
from typing import Any, Callable, Optional, Sequence, Union

from .actor import Actor, ActorRef, ActorSystem
from .memref import DeviceRef
from .signature import NDRange

__all__ = ["compose", "fuse", "ComposedActor"]


class ComposedActor(Actor):
    """Forwards messages through ``stages`` left→right, responding with the
    final stage's result (promise delegation, paper §3.5).

    Intermediate :class:`DeviceRef` results are owned by the chain: once
    the next stage has consumed a forwarded ref, it is released (paper:
    "dropping a reference argument simply releases its memory on the
    device"), so a pipeline run leaves no live intermediate refs behind.
    The caller's input refs and the final stage's result are never touched.
    """

    def __init__(self, stages: Sequence[ActorRef]):
        super().__init__()
        if not stages:
            raise ValueError("need at least one stage")
        self.stages = list(stages)

    def receive(self, *payload: Any) -> Future:
        out: Future = Future()
        self._run_stage(0, payload, out, owned=())
        return out  # promise: the runtime delegates the response

    def _run_stage(self, idx: int, payload, out: Future,
                   owned: tuple = ()) -> None:
        fut = self.stages[idx].request(*payload)

        def _done(f: Future):
            exc = f.exception()
            if exc is not None:
                for r in owned:
                    r.release()
                out.set_exception(exc)
                return
            result = f.result()
            nxt = result if isinstance(result, tuple) else (result,)
            # stage idx has consumed its inputs: refs the chain owns
            # (produced by stage idx-1) are dead now — drop their buffers,
            # EXCEPT any ref the stage passed through into its own result
            # (still in flight, or owed to the caller at the final stage).
            # release() is idempotent, so donated in_out refs are fine.
            passing = {id(v) for v in nxt if isinstance(v, DeviceRef)}
            for r in owned:
                if id(r) not in passing:
                    r.release()
            if idx + 1 == len(self.stages):
                out.set_result(result)
            else:
                self._run_stage(
                    idx + 1, nxt, out,
                    owned=tuple(v for v in nxt if isinstance(v, DeviceRef)))

        fut.add_done_callback(_done)


def compose(system: ActorSystem, *stages: ActorRef) -> ActorRef:
    """``compose(sys, A, B, C)`` builds C⊙B⊙A (A applied first).

    Deprecated shim over ``Pipeline(system, mode="staged")``;
    ``ActorRef.__mul__`` provides the paper's infix form:
    ``fuse = move_elems * count_elems * prepare`` (Listing 5).
    """
    from .api import Pipeline  # local import: avoid cycle
    warnings.warn(
        "compose() is deprecated; use repro.core.Pipeline(mode=\"staged\") "
        "— or build a dataflow Graph directly for non-linear topologies",
        DeprecationWarning, stacklevel=2)
    return Pipeline(system, mode="staged").stages(stages).build()


def fuse(system: ActorSystem, *stages: Union[ActorRef, Callable],
         nd_range: Optional[NDRange] = None, name: str = "fused",
         device=None) -> ActorRef:
    """Fuse kernel stages into a **single** jitted actor.

    Deprecated shim over ``Pipeline(system, mode="fused")``. ``stages``
    are kernel-actor refs (their traceable ``fn`` is extracted) or plain
    callables acting as adapters between stages. The fused actor takes
    the first stage's input signature and produces the last stage's
    output signature; intermediates never materialize as messages.
    """
    from .api import Pipeline  # local import: avoid cycle
    warnings.warn(
        "fuse() is deprecated; use repro.core.Pipeline(mode=\"fused\") or "
        "repro.core.Graph.build(fuse=True), which run the trace-time "
        "fusion pass", DeprecationWarning, stacklevel=2)
    return Pipeline(system, mode="fused", name=name, device=device,
                    nd_range=nd_range).stages(stages).build()
