"""``actor_facade`` — wrap a data-parallel kernel as an actor (paper §3.2).

Whenever the facade receives a message it (paper's three-part behavior,
§3.6):

1. runs the **pre-processing** function (default: pattern-match the payload
   against all ``In``/``InOut`` declarations and move host data to the
   device),
2. dispatches the **kernel** — a jit-compiled JAX/Pallas callable bound to
   this actor's device. JAX dispatch is asynchronous: the returned arrays
   are futures for device buffers, reproducing the paper's
   ``clEnqueueNDRangeKernel`` + event pipeline (Listing 4) — downstream
   actors can be messaged *before* the kernel finishes,
3. runs the **post-processing** function (default: wrap each
   ``Out``/``InOut`` result as a value — explicit host read-back — or as a
   :class:`~repro.core.memref.DeviceRef` when the spec asked for reference
   semantics).

``InOut`` arguments are donated to XLA so the update happens in place,
matching OpenCL's read-write buffer semantics; the incoming ``DeviceRef``
(if any) is **donated** (``DeviceRef.donate()``), making buffer ownership
transfer explicit — using the ref afterwards raises.

DeviceRefs are the native currency on both sides of the behavior: incoming
refs are unwrapped (with access-rights checks — an ``in`` argument needs
read rights, ``in_out`` needs read+write), outgoing arrays are wrapped as
refs whenever the spec asks for reference semantics *or* the actor was
spawned with ``emit="ref"`` (how ``Pipeline`` keeps intermediate stages
device-resident). The facade itself never calls ``to_value()``; the only
host read-back is the explicit value-semantics path, counted in the
registry as a ``readback``.
"""
from __future__ import annotations

import inspect
import warnings
from functools import partial
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import numpy as np

from .actor import Actor
from .errors import AccessViolation, SignatureMismatch
from .manager import Device, Program
from .memref import DeviceRef, as_device_array, registry
from .signature import In, InOut, KernelSignature, Local, NDRange, Out

__all__ = ["KernelActor", "detect_fn_kwargs", "eval_output_structs"]

#: static keywords a kernel callable may accept from the runtime
_KERNEL_KWARGS = ("nd_range", "out_shapes", "local_shapes")


def detect_fn_kwargs(fn: Callable) -> set:
    """Which of the runtime-supplied static keywords ``fn`` accepts — the
    single source of truth shared by :class:`KernelActor` and
    :meth:`~repro.core.api.KernelDecl.out_structs`."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # pragma: no cover - builtins
        return set()
    return {k for k in _KERNEL_KWARGS if k in params}


def eval_output_structs(fn: Callable, signature: KernelSignature,
                        nd_range: Optional[NDRange], fn_kwargs,
                        input_structs: Sequence) -> Tuple:
    """Abstract-evaluate a kernel: the output ``jax.ShapeDtypeStruct``\\ s
    for the given input structs, without running the kernel.

    This is how ``repro.core.graph`` derives *typed ports* from a
    :class:`KernelSignature` at build time (paper §3.5: composition over
    statically checkable typed actor interfaces): the kernel's traceable
    callable is bound to its static keywords (``nd_range`` /
    ``local_shapes``), then ``jax.eval_shape``'d.
    """
    static_kwargs = {}
    if "nd_range" in fn_kwargs:
        static_kwargs["nd_range"] = nd_range
    if "local_shapes" in fn_kwargs:
        static_kwargs["local_shapes"] = tuple(
            s.resolved_shape() for s in signature.local_specs)

    def wrapped(*inputs):
        out = fn(*inputs, **static_kwargs)
        return out if isinstance(out, tuple) else (out,)

    return tuple(jax.eval_shape(wrapped, *input_structs))


class KernelActor(Actor):
    """The paper's ``actor_facade`` adapted to JAX (DESIGN.md §2)."""

    def __init__(self, fn: Callable, name: str, nd_range: Optional[NDRange],
                 specs: Sequence, device: Device,
                 program: Optional[Program] = None,
                 preprocess: Optional[Callable] = None,
                 postprocess: Optional[Callable] = None,
                 donate: bool = True, emit: str = "declared",
                 fused_from: Sequence[str] = ()):
        super().__init__()
        if emit not in ("declared", "ref"):
            raise ValueError(f"emit must be 'declared' or 'ref', got {emit!r}")
        self.fn = fn
        #: node paths of the graph region this actor was fused from
        #: (empty for ordinary single-kernel actors) — introspection for
        #: the Graph fusion pass
        self.fused_from = tuple(fused_from)
        self.kernel_name = name
        self.nd_range = nd_range
        self.signature = KernelSignature(*specs)
        self.device = device
        self.program = program
        self.preprocess = preprocess
        self.postprocess = postprocess
        self.donate = donate
        #: "declared" honours each Out spec's as_ref; "ref" forces every
        #: output to stay device-resident (intermediate pipeline stages)
        self.emit = emit
        self._jitted = None
        # Kernels may want the index space / local sizes / resolved output
        # shapes; detect which keywords the callable accepts once.
        self._fn_kwargs = detect_fn_kwargs(fn)

    # -- compilation ------------------------------------------------------
    def _build(self):
        sig = self.signature
        fn = self.fn
        static_kwargs = {}
        if "nd_range" in self._fn_kwargs:
            static_kwargs["nd_range"] = self.nd_range
        if "local_shapes" in self._fn_kwargs:
            static_kwargs["local_shapes"] = tuple(
                s.resolved_shape() for s in sig.local_specs)

        def wrapped(*inputs):
            out = fn(*inputs, **static_kwargs)
            return out if isinstance(out, tuple) else (out,)

        donate = sig.donate_argnums if self.donate else ()
        jitted = jax.jit(wrapped, donate_argnums=donate)

        def build():
            return jitted
        key = ("jit", self.kernel_name, bool(donate))
        if self.program is not None:
            return self.program.compiled(key, build)
        return jitted

    def on_start(self):
        if self._jitted is None:
            self._jitted = self._build()

    # -- behavior ------------------------------------------------------
    def receive(self, *payload: Any) -> Any:
        if self.preprocess is not None:
            converted = self.preprocess(*payload)
            if converted is None:  # pattern did not match → drop (paper §2.1)
                return None
            payload = converted if isinstance(converted, tuple) else (converted,)

        sig = self.signature
        inputs = sig.match_inputs(payload)
        dev = self.device.jax_device
        arrays = []
        consumed_refs = []
        for spec, value in zip(sig.input_specs, inputs):
            if isinstance(value, DeviceRef):
                if not value.readable:
                    raise AccessViolation(
                        f"kernel {self.kernel_name!r}: {spec.direction!r} "
                        f"argument requires read rights, ref grants "
                        f"{value.access!r}")
                if spec.direction == "in_out":
                    if not value.writable:
                        raise AccessViolation(
                            f"kernel {self.kernel_name!r}: 'in_out' argument "
                            f"requires write rights, ref grants "
                            f"{value.access!r}")
                    if self.donate:
                        consumed_refs.append(value)
                arr = value.array
            else:
                # Untyped Python scalars/lists adopt the spec dtype; arrays
                # keep theirs so mismatches are caught (pattern matching).
                cast = None if hasattr(value, "dtype") else spec.np_dtype
                arr = as_device_array(value, device=dev, dtype=cast)
            if not spec.matches(arr.dtype):
                raise SignatureMismatch(
                    f"kernel {self.kernel_name!r}: argument dtype {arr.dtype} "
                    f"does not match spec {spec.np_dtype}")
            arrays.append(arr)

        if self._jitted is None:
            self.on_start()
        self.device._dispatch_started()
        try:
            with warnings.catch_warnings():
                # CPU backends may decline donation; that is fine.
                warnings.simplefilter("ignore")
                outputs = self._jitted(*arrays)
        finally:
            self.device._dispatch_finished()

        # donated buffers: ownership moved into the kernel (donate-after-use
        # on the incoming ref now raises)
        for ref in consumed_refs:
            ref.donate()

        if len(outputs) != len(sig.output_specs):
            raise SignatureMismatch(
                f"kernel {self.kernel_name!r} returned {len(outputs)} outputs, "
                f"signature declares {len(sig.output_specs)}")
        response = []
        for spec, arr in zip(sig.output_specs, outputs):
            if not spec.matches(arr.dtype):
                raise SignatureMismatch(
                    f"kernel {self.kernel_name!r}: output dtype {arr.dtype} "
                    f"does not match spec {spec.np_dtype}")
            if spec.as_ref or self.emit == "ref":
                response.append(DeviceRef(arr))      # stays device-resident
            else:
                registry.count_readback()            # explicit host read-back
                response.append(np.asarray(jax.device_get(arr)))
        result = tuple(response)
        if self.postprocess is not None:
            result = self.postprocess(*result)
            if result is not None and not isinstance(result, tuple):
                result = (result,)
        if result is None:
            return None
        return result[0] if len(result) == 1 else result

    def out_structs(self, input_structs: Sequence) -> Tuple:
        """Abstract output types for ``input_structs`` (graph port typing)."""
        return eval_output_structs(self.fn, self.signature, self.nd_range,
                                   self._fn_kwargs, input_structs)

    def clone(self, emit: Optional[str] = None) -> "KernelActor":
        """A fresh (unspawned) actor sharing this one's declaration.

        ``Pipeline._build_staged`` uses this to derive ref-emitting
        intermediate stages from existing actors without mutating them."""
        return KernelActor(fn=self.fn, name=self.kernel_name,
                           nd_range=self.nd_range,
                           specs=self.signature.specs, device=self.device,
                           program=self.program, preprocess=self.preprocess,
                           postprocess=self.postprocess, donate=self.donate,
                           emit=emit or self.emit,
                           fused_from=self.fused_from)

    def on_exit(self, reason):
        self._jitted = None
