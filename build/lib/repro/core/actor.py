"""A CAF-style actor runtime in Python (paper §2.1, §3.2).

Actors are sub-thread entities with mailboxes, run by a cooperative
scheduler (a shared thread pool approximating CAF's work-stealing
scheduler). They communicate exclusively by asynchronous message passing:

* ``send``     — fire-and-forget (CAF ``send``)
* ``request``  — returns a future for the response (CAF ``request``)
* behaviors may return a *promise* (another future) to delegate the
  response to a different actor — the mechanism the paper's composition
  builds on ("actors may return a 'promise' ... delegated to another actor
  which then becomes responsible for responding to the sender", §3.5).

Fault tolerance (paper §2.1): actors can ``monitor`` each other (the
runtime delivers a :class:`DownMessage` on termination) or ``link``
(bidirectional, delivers :class:`ExitMessage`, killing the receiver unless
it traps exits). This is the substrate the distributed supervisor in
``repro.dist.fault`` uses for checkpoint/restart.
"""
from __future__ import annotations

import itertools
import threading
import traceback
import weakref
from collections import deque
from concurrent.futures import Future, InvalidStateError, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from typing import Any, Callable, Optional, Tuple

from ..analysis.runtime import make_lock
from .errors import ActorFailed, DownMessage, ExitMessage, MailboxClosed

__all__ = ["Actor", "ActorRef", "ActorSystem", "Message"]

_MAX_MSGS_PER_SLICE = 16  # fairness: yield the worker thread periodically

#: distinguishes "caller passed no timeout" from an explicit ``None``
#: (= wait forever) in :meth:`ActorRef.ask`
_UNSET = object()


def _safe_set_result(fut: Optional[Future], value: Any) -> None:
    """Resolve a reply future, tolerating a caller that already cancelled
    it (or a racing duplicate resolution) — a cancelled request must never
    crash the actor that eventually answers it."""
    if fut is None or fut.cancelled():
        return
    try:
        fut.set_result(value)
    except InvalidStateError:
        pass


def _safe_set_exception(fut: Optional[Future], exc: BaseException) -> None:
    if fut is None or fut.cancelled():
        return
    try:
        fut.set_exception(exc)
    except InvalidStateError:
        pass


class Message:
    __slots__ = ("payload", "reply_to", "sender")

    def __init__(self, payload: Tuple[Any, ...], reply_to: Optional[Future] = None,
                 sender: Optional["ActorRef"] = None):
        self.payload = payload
        self.reply_to = reply_to
        self.sender = sender


class ActorRef:
    """Network-transparent actor handle (paper: OpenCL actors "use the same
    handle type as actors running on the CPU")."""

    __slots__ = ("actor_id", "_system",)

    def __init__(self, actor_id: int, system: "ActorSystem"):
        self.actor_id = actor_id
        self._system = system

    # -- messaging ------------------------------------------------------
    def send(self, *payload: Any, sender: Optional["ActorRef"] = None) -> None:
        self._system._enqueue(self.actor_id, Message(payload, None, sender))

    def request(self, *payload: Any) -> Future:
        fut: Future = Future()
        self._system._enqueue(self.actor_id, Message(payload, fut, None))
        return fut

    def ask(self, *payload: Any, timeout: Any = _UNSET) -> Any:
        """Synchronous request/receive (paper's ``scoped_actor`` pattern).

        ``timeout`` defaults to the owning system's ``default_ask_timeout``
        (an explicit ``None`` waits forever). On expiry the raised
        :class:`TimeoutError` names the actor and its liveness, so a
        wedged-vs-dead target is identifiable from the exception alone.
        """
        if timeout is _UNSET:
            timeout = getattr(self._system, "default_ask_timeout", 120.0)
        fut = self.request(*payload)
        try:
            return fut.result(timeout=timeout)
        except FuturesTimeout:
            if fut.done():
                # the *behavior* raised a TimeoutError — surface it rather
                # than relabeling it as an ask() timeout
                raise
            alive = "alive" if self.is_alive() else "dead"
            raise FuturesTimeout(
                f"ask() timed out after {timeout}s waiting on actor "
                f"#{self.actor_id} ({alive})") from None

    # -- supervision ------------------------------------------------------
    def monitor(self, watcher: "ActorRef") -> None:
        self._system.monitor(watcher, self)

    def link(self, other: "ActorRef") -> None:
        self._system.link(self, other)

    def exit(self, reason: Any = None) -> None:
        self._system._terminate(self.actor_id, reason)

    def is_alive(self) -> bool:
        return self._system._is_alive(self.actor_id)

    # -- distribution policy ----------------------------------------------
    def __reduce__(self):
        # Mirrors DeviceRef's explicit refusal: a ref is a process-local
        # handle (it closes over the ActorSystem and its scheduler), so
        # shipping one inside a cross-node payload fails here with an
        # actionable message instead of deep inside pickle.
        raise TypeError(
            "ActorRef is a process-local handle and cannot be pickled; "
            "for cross-node use, publish the actor on its node "
            "(NodeRuntime.publish) and resolve it with remote_actor(), "
            "or send plain data instead")

    # -- composition ------------------------------------------------------
    def __mul__(self, other: "ActorRef") -> "ActorRef":
        """``C = B * A`` applies ``A`` first, then ``B`` (paper §3.5,
        Listing 5: ``fuse = move_elems * count_elems * prepare``)."""
        from .api import Pipeline  # local import: avoid cycle
        return Pipeline(self._system, mode="staged").stages(
            [other, self]).build()

    def __repr__(self):
        return f"ActorRef#{self.actor_id}"


class Actor:
    """Base class; subclasses override :meth:`receive`."""

    def __init__(self):
        self.ref: Optional[ActorRef] = None
        self.system: Optional["ActorSystem"] = None
        self.trap_exit = False

    def receive(self, *payload: Any) -> Any:  # pragma: no cover - abstract
        raise NotImplementedError

    def on_start(self) -> None:
        """Hook run before the first message (lazy init, paper §5.1)."""

    def on_exit(self, reason: Any) -> None:
        """Cleanup hook."""


class _FunctionActor(Actor):
    def __init__(self, fn: Callable[..., Any]):
        super().__init__()
        self._fn = fn

    def receive(self, *payload: Any) -> Any:
        return self._fn(*payload)


class _ActorState:
    __slots__ = ("actor", "mailbox", "lock", "scheduled", "alive", "reason",
                 "monitors", "links", "started", "inline")

    def __init__(self, actor: Actor):
        self.actor = actor
        self.mailbox: deque = deque()
        self.lock = make_lock("ActorState")
        self.scheduled = False
        self.alive = True
        self.reason: Any = None
        self.monitors: list = []   # ActorRefs to notify with DownMessage
        self.links: list = []      # ActorRefs to notify with ExitMessage
        self.started = False
        #: True while a synchronous inline call (``try_call_inline``) is
        #: executing the behavior on a caller thread; excludes the drain
        #: loop the same way ``scheduled`` does, so the single-threaded
        #: actor contract holds across both dispatch paths
        self.inline = False


class ActorSystem:
    """Owns actors, the scheduler, and (via ``opencl_manager``) devices.

    Mirrors CAF's ``actor_system``: create one, optionally load the device
    module, spawn actors, shut down.
    """

    def __init__(self, name: str = "repro", max_workers: int = 8,
                 default_ask_timeout: Optional[float] = 120.0):
        self.name = name
        #: system-wide default for :meth:`ActorRef.ask` (seconds; ``None``
        #: waits forever) — mirrors ``ActorPool.default_timeout`` so the
        #: old hardcoded 120 s is a policy, not a constant
        self.default_ask_timeout = default_ask_timeout
        self._executor = ThreadPoolExecutor(max_workers=max_workers,
                                            thread_name_prefix=f"{name}-sched")
        self._actors: dict[int, _ActorState] = {}
        self._ids = itertools.count(1)
        self._registry_lock = make_lock("ActorSystem")
        self._shutdown = False
        self._manager = None
        self.stats = {"spawned": 0, "messages": 0, "inline_calls": 0}

    # -- spawning ------------------------------------------------------
    def spawn(self, behavior, *args, lazy_init: bool = True, **kwargs) -> ActorRef:
        """Create an actor from a function, an :class:`Actor` subclass, or
        a ``@kernel``-decorated callable (paper §2.1: "actors are created
        using the function spawn"; kernel declarations route through the
        device manager so one ``spawn`` covers both worlds)."""
        from .api import KernelDecl  # local import: avoid cycle
        if isinstance(behavior, KernelDecl):
            return self.opencl_manager().spawn(behavior, *args,
                                               lazy_init=lazy_init, **kwargs)
        if isinstance(behavior, Actor):
            actor = behavior
        elif isinstance(behavior, type) and issubclass(behavior, Actor):
            actor = behavior(*args, **kwargs)
        elif callable(behavior):
            actor = _FunctionActor(behavior)
        else:
            raise TypeError(f"cannot spawn {behavior!r}")
        with self._registry_lock:
            if self._shutdown:
                raise MailboxClosed("actor system is shut down")
            aid = next(self._ids)
            state = _ActorState(actor)
            self._actors[aid] = state
            self.stats["spawned"] += 1
        ref = ActorRef(aid, self)
        actor.ref = ref
        actor.system = self
        if not lazy_init:
            actor.on_start()
            state.started = True
        return ref

    def opencl_manager(self):
        """Device-module accessor named after the paper's
        ``system.opencl_manager()`` (Listing 2)."""
        if self._manager is None:
            from .manager import DeviceManager
            self._manager = DeviceManager(self)
        return self._manager

    # -- supervision ------------------------------------------------------
    def monitor(self, watcher: ActorRef, target: ActorRef) -> None:
        """Register ``watcher`` for a :class:`DownMessage` when ``target``
        terminates.

        The liveness re-check happens **under the target's lock**: a target
        that terminates between an unlocked check and the registration
        would otherwise have already snapshotted its monitor list, and the
        watcher would never hear about the death. If the target is (or
        just became) dead, the ``DownMessage`` is delivered immediately.

        Remote targets (``repro.net.RemoteActorRef``) carry their own
        registration path; dispatching here keeps ``system.monitor`` the
        single network-transparent entry point.
        """
        if getattr(target, "is_remote", False):
            target.monitor(watcher)
            return
        st = self._actors.get(target.actor_id)
        if st is not None:
            with st.lock:
                if st.alive:
                    st.monitors.append(watcher)
                    return
        watcher.send(DownMessage(target.actor_id, st.reason if st else None))

    def link(self, a: ActorRef, b: ActorRef) -> None:
        """Bidirectional link: built from two one-way halves, each
        registered (or fired immediately) under the dying side's lock — a
        link to an actor mid-termination can no longer leave a one-sided
        link whose ``ExitMessage`` never arrives."""
        for x in (a, b):
            if getattr(x, "is_remote", False):
                x.link(b if x is a else a)
                return
        self._link_half(a, b)
        self._link_half(b, a)

    def _link_half(self, target: ActorRef, listener: ActorRef) -> None:
        """One-way link registration: when ``target`` dies, ``listener``
        receives an :class:`ExitMessage`. Re-checks liveness under the
        target's lock and delivers immediately when the target is already
        dead (the cross-node link in ``repro.net`` is two such halves)."""
        st = self._actors.get(target.actor_id)
        if st is not None:
            with st.lock:
                if st.alive:
                    st.links.append(listener)
                    return
        listener.send(ExitMessage(target.actor_id, st.reason if st else None))

    # -- inline fast path --------------------------------------------------
    def try_call_inline(self, actor_id: int, payload: tuple
                        ) -> Tuple[bool, Any]:
        """Attempt to run ``actor_id``'s behavior synchronously on the
        calling thread, bypassing the mailbox/scheduler hop (the graph
        orchestrator's dispatch fast path).

        Returns ``(True, result)`` on success, ``(False, None)`` on a
        *miss* — the caller must then fall back to the ordinary mailbox
        path. A miss means the fast path cannot preserve actor semantics
        right now: the actor is dead, has queued messages (mailbox ordering
        must hold), is already executing (``scheduled``/``inline`` — the
        single-threaded contract), or has monitors/links attached (a
        supervised actor keeps the fully-ordered mailbox path so PR 5
        supervision semantics are untouched).

        The reentrancy guard (``_ActorState.inline``) excludes the drain
        loop exactly like ``scheduled`` does: while it is held, newly
        enqueued messages park in the mailbox and are rescheduled when the
        inline call finishes. A behavior that raises terminates the actor
        with the exception as the reason — identical to the mailbox path —
        and the exception propagates to the caller.
        """
        st = self._actors.get(actor_id)
        if st is None:
            return False, None
        with st.lock:
            if (not st.alive or st.mailbox or st.scheduled or st.inline
                    or st.monitors or st.links):
                return False, None
            st.inline = True
        try:
            actor = st.actor
            if not st.started:
                actor.on_start()
                st.started = True
            result = actor.receive(*payload)
        except Exception as exc:
            # terminate *before* releasing the guard: messages that arrived
            # mid-call are failed by the termination sweep rather than
            # handed to a drain racing the death
            self._terminate(actor_id, exc)
            self._release_inline(st, actor_id)
            raise
        self.stats["inline_calls"] += 1
        self._release_inline(st, actor_id)
        return True, result

    def _release_inline(self, st: "_ActorState", actor_id: int) -> None:
        resubmit = False
        with st.lock:
            st.inline = False
            if st.mailbox and st.alive and not st.scheduled:
                st.scheduled = True
                resubmit = True
        if resubmit:
            try:
                self._executor.submit(self._drain, actor_id)
            except RuntimeError:        # executor shut down: drain inline
                self._drain(actor_id)

    # -- scheduling internals ----------------------------------------------
    def _enqueue(self, actor_id: int, msg: Message) -> None:
        st = self._actors.get(actor_id)
        delivered = False
        if st is not None:
            # liveness re-checked under the lock: a concurrent
            # _terminate/shutdown() snapshots-and-clears the mailbox under
            # this lock, so appending after an unlocked check would strand
            # the message (and its reply future) forever
            with st.lock:
                if st.alive:
                    st.mailbox.append(msg)
                    delivered = True
                    self.stats["messages"] += 1
                    if st.scheduled or st.inline:
                        # already claimed: a running drain will see the new
                        # message, and an inline call reschedules the drain
                        # in its release path
                        return
                    st.scheduled = True
        if not delivered:
            _safe_set_exception(
                msg.reply_to, ActorFailed(f"actor #{actor_id} is not alive"))
            return
        try:
            self._executor.submit(self._drain, actor_id)
        except RuntimeError:
            # executor already shut down: drain synchronously so the
            # mailbox (and any reply futures) cannot be stranded
            self._drain(actor_id)

    def _drain(self, actor_id: int) -> None:
        st = self._actors.get(actor_id)
        if st is None:
            return
        processed = 0
        while True:
            with st.lock:
                if not st.mailbox or not st.alive or processed >= _MAX_MSGS_PER_SLICE:
                    if st.mailbox and st.alive:
                        # re-submit for fairness instead of hogging the worker
                        self._executor.submit(self._drain, actor_id)
                    else:
                        st.scheduled = False
                    return
                msg = st.mailbox.popleft()
            processed += 1
            self._process(st, actor_id, msg)

    def _process(self, st: _ActorState, actor_id: int, msg: Message) -> None:
        actor = st.actor
        try:
            if not st.started:
                actor.on_start()
                st.started = True
            if isinstance(msg.payload, tuple) and len(msg.payload) == 1 and \
                    isinstance(msg.payload[0], ExitMessage) and not actor.trap_exit:
                self._terminate(actor_id, msg.payload[0].reason)
                return
            result = actor.receive(*msg.payload)
        except Exception as exc:  # abnormal termination → fault propagation
            _safe_set_exception(msg.reply_to, exc)
            traceback.clear_frames(exc.__traceback__) if exc.__traceback__ else None
            self._terminate(actor_id, exc)
            return
        if msg.reply_to is None:
            return
        if isinstance(result, Future):
            # response promise: delegate (paper §3.5)
            _chain_future(result, msg.reply_to)
        else:
            _safe_set_result(msg.reply_to, result)

    def _terminate(self, actor_id: int, reason: Any) -> None:
        st = self._actors.get(actor_id)
        if st is None:
            return
        with st.lock:
            if not st.alive:
                return
            st.alive = False
            st.reason = reason
            pending = list(st.mailbox)
            st.mailbox.clear()
            monitors, links = list(st.monitors), list(st.links)
        for msg in pending:
            _safe_set_exception(msg.reply_to, ActorFailed(
                f"actor #{actor_id} terminated: {reason!r}"))
        try:
            st.actor.on_exit(reason)
        except Exception:  # pragma: no cover - cleanup must not crash runtime
            pass  # lint: on_exit is user code; the drain loop must survive it
        for m in monitors:
            m.send(DownMessage(actor_id, reason))
        for l in links:
            l.send(ExitMessage(actor_id, reason))

    def _is_alive(self, actor_id: int) -> bool:
        st = self._actors.get(actor_id)
        return bool(st and st.alive)

    # -- lifecycle ------------------------------------------------------
    def shutdown(self, wait: bool = True) -> None:
        with self._registry_lock:
            self._shutdown = True
            ids = list(self._actors)
        for aid in ids:
            self._terminate(aid, None)
        self._executor.shutdown(wait=wait)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False


def _chain_future(src: Future, dst: Future) -> None:
    """Forward ``src``'s outcome into ``dst`` (promise delegation).

    Cancellation propagates **backwards** (dst → src): a caller that
    cancels the outer ``request()`` future also cancels the delegated
    promise, so the in-flight work it represents is not silently leaked.
    The back-edge is a *weak* reference — a strong one would close a
    reference cycle with the forward callback and keep chained futures
    (and the DeviceRefs in their results) alive until a gc pass instead
    of dropping promptly; while the promise is pending, its owner (the
    delegate's mailbox) holds it strongly, which is exactly the window
    where cancelling it matters.
    Forward resolution guards against a dst that was cancelled between the
    check and the set (the race is unavoidable — ``Future`` has no
    compare-and-set), so a lost race never crashes the resolving actor.
    """
    src_ref = weakref.ref(src)

    def _src_done(f: Future):
        try:
            if f.cancelled():
                dst.cancel()
                return
            exc = f.exception()
            if exc is not None:
                _safe_set_exception(dst, exc)
            else:
                _safe_set_result(dst, f.result())
        except InvalidStateError:
            pass

    def _dst_done(f: Future):
        if f.cancelled():
            s = src_ref()
            if s is not None:
                s.cancel()

    dst.add_done_callback(_dst_done)
    src.add_done_callback(_src_done)
