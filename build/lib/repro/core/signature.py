"""Kernel argument signatures and index spaces (paper §3.4).

The paper's OpenCL actors are spawned with a list of ``in``, ``out``,
``in_out``, ``local`` and ``priv`` declarations mirroring the kernel
signature, plus an ``nd_range`` describing the work-item index space.
This module is the JAX/TPU adaptation:

* ``NDRange``      — global dims / offsets / local dims. On TPU the global
                     dims describe the logical index space and ``local``
                     maps to the VMEM tile (Pallas block) shape rather than
                     an OpenCL work-group, because the natural unit of TPU
                     execution is a tile feeding the MXU/VPU (DESIGN.md §2).
* ``In/Out/InOut`` — typed argument declarations. ``InOut`` additionally
                     requests **buffer donation** so XLA can update the
                     operand in place — the TPU analogue of a read-write
                     ``cl_mem``.
* ``Local``        — VMEM scratch request (OpenCL ``__local``).
* ``Priv``         — accepted for API fidelity, ignored: private memory is
                     register-allocated by Mosaic (DESIGN.md §8).

Every declaration may ask for value semantics (host round-trip) or
reference semantics (``mem_ref<T>`` → :class:`repro.core.memref.DeviceRef`)
via ``as_ref`` — the paper's ``in_out<uint, ref, ref>`` pattern.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .errors import SignatureMismatch

__all__ = [
    "NDRange",
    "dim_vec",
    "In",
    "Out",
    "InOut",
    "Local",
    "Priv",
    "KernelSignature",
]


def dim_vec(*dims: int) -> Tuple[int, ...]:
    """One- to three-dimensional index-space size (paper Listing 2)."""
    if not 1 <= len(dims) <= 3:
        raise ValueError("dim_vec takes 1..3 dimensions, got %d" % len(dims))
    return tuple(int(d) for d in dims)


@dataclasses.dataclass(frozen=True)
class NDRange:
    """N-dimensional index space (paper §2.3 "NDRange").

    ``global_dims`` identify one logical work item per tuple; ``offsets``
    shift global IDs; ``local_dims`` map to the Pallas block shape.
    """

    global_dims: Tuple[int, ...]
    offsets: Tuple[int, ...] = ()
    local_dims: Tuple[int, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "global_dims", tuple(int(d) for d in self.global_dims))
        object.__setattr__(self, "offsets", tuple(int(d) for d in self.offsets))
        object.__setattr__(self, "local_dims", tuple(int(d) for d in self.local_dims))
        if not 1 <= len(self.global_dims) <= 3:
            raise ValueError("NDRange supports 1..3 dimensions")
        if self.offsets and len(self.offsets) != len(self.global_dims):
            raise ValueError("offsets rank must match global rank")
        if self.local_dims:
            if len(self.local_dims) != len(self.global_dims):
                raise ValueError("local rank must match global rank")
            for g, l in zip(self.global_dims, self.local_dims):
                if g % l != 0:
                    raise ValueError(
                        f"global dim {g} not divisible by local dim {l}"
                    )

    @property
    def total_items(self) -> int:
        return math.prod(self.global_dims)

    def grid(self) -> Tuple[int, ...]:
        """Pallas grid: number of blocks per dimension."""
        if not self.local_dims:
            return self.global_dims
        return tuple(g // l for g, l in zip(self.global_dims, self.local_dims))

    def split(self, fractions: Sequence[float]) -> Tuple["NDRange", ...]:
        """Split the leading dimension proportionally (paper §5.4 offload).

        Returns one sub-range per non-empty fraction, with offsets adjusted
        so global IDs remain consistent across devices.
        """
        total = self.global_dims[0]
        sizes = _proportional_split(total, fractions)
        out = []
        start = self.offsets[0] if self.offsets else 0
        rest_dims = self.global_dims[1:]
        rest_offs = self.offsets[1:] if self.offsets else (0,) * len(rest_dims)
        for sz in sizes:
            if sz == 0:
                out.append(None)
                continue
            out.append(
                NDRange(
                    (sz,) + rest_dims,
                    offsets=(start,) + tuple(rest_offs),
                    local_dims=self.local_dims,
                )
            )
            start += sz
        return tuple(out)


def _proportional_split(total: int, fractions: Sequence[float]) -> Tuple[int, ...]:
    if abs(sum(fractions) - 1.0) > 1e-6:
        raise ValueError("fractions must sum to 1")
    sizes = [int(math.floor(total * f)) for f in fractions]
    # distribute the remainder to the largest fractions first
    rem = total - sum(sizes)
    order = sorted(range(len(fractions)), key=lambda i: -fractions[i])
    for i in range(rem):
        sizes[order[i % len(order)]] += 1
    return tuple(sizes)


@dataclasses.dataclass(frozen=True)
class _ArgSpec:
    dtype: Any = jnp.float32
    shape: Optional[Tuple[int, ...]] = None
    #: value (host array) or reference (DeviceRef) semantics, per direction
    as_ref: bool = False

    @property
    def np_dtype(self) -> np.dtype:
        return np.dtype(self.dtype)

    def matches(self, value_dtype) -> bool:
        return np.dtype(value_dtype) == self.np_dtype


@dataclasses.dataclass(frozen=True)
class In(_ArgSpec):
    """Read-only kernel input, extracted from the incoming message."""

    direction = "in"


@dataclasses.dataclass(frozen=True)
class Out(_ArgSpec):
    """Kernel output, allocated by the framework.

    The paper defaults the size to the number of work items; a
    ``size_fn(inputs, nd_range) -> shape`` overrides it (paper §3.4), or a
    static ``shape``/``size`` may be given (paper Listing 5 ``out<uint,
    ref>{2*k}``).
    """

    direction = "out"
    size: Optional[int] = None
    size_fn: Optional[Callable[..., Tuple[int, ...]]] = None

    def resolved_shape(self, inputs, nd_range: NDRange) -> Tuple[int, ...]:
        if self.shape is not None:
            return tuple(self.shape)
        if self.size is not None:
            return (int(self.size),)
        if self.size_fn is not None:
            shp = self.size_fn(inputs, nd_range)
            if isinstance(shp, int):
                return (shp,)
            return tuple(int(s) for s in shp)
        return (nd_range.total_items,)


@dataclasses.dataclass(frozen=True)
class InOut(_ArgSpec):
    """Read-write argument: consumed from the message, returned in the
    response, and **donated** to XLA for in-place update."""

    direction = "in_out"


@dataclasses.dataclass(frozen=True)
class Local(_ArgSpec):
    """Per-tile VMEM scratch (OpenCL ``__local``); never crosses messages."""

    direction = "local"
    size: Optional[int] = None

    def resolved_shape(self) -> Tuple[int, ...]:
        if self.shape is not None:
            return tuple(self.shape)
        if self.size is not None:
            return (int(self.size),)
        raise ValueError("Local requires shape or size")


@dataclasses.dataclass(frozen=True)
class Priv(_ArgSpec):
    """Accepted for OpenCL API fidelity; registers are Mosaic-managed."""

    direction = "priv"


class KernelSignature:
    """Orders and validates kernel arguments (paper §3.4).

    The wrapped callable receives all ``In``/``InOut`` arrays in signature
    order and must return all ``Out``/``InOut`` arrays in signature order —
    the functional-JAX bridge for OpenCL's by-reference outputs.
    """

    def __init__(self, *specs: _ArgSpec):
        self.specs = tuple(specs)
        self.input_specs = tuple(s for s in specs if s.direction in ("in", "in_out"))
        self.output_specs = tuple(s for s in specs if s.direction in ("out", "in_out"))
        self.local_specs = tuple(s for s in specs if s.direction == "local")
        #: indices (into the callable's positional args) eligible for donation
        self.donate_argnums = tuple(
            i for i, s in enumerate(self.input_specs) if s.direction == "in_out"
        )

    def match_inputs(self, payload: Sequence[Any]):
        """Pattern-match a message payload against the input specs.

        Mirrors the paper's auto-generated pattern: a message is matched
        against all ``in`` and ``in_out`` kernel arguments.
        """
        if len(payload) != len(self.input_specs):
            raise SignatureMismatch(
                f"expected {len(self.input_specs)} inputs, got {len(payload)}"
            )
        return tuple(payload)

    def __repr__(self):
        return f"KernelSignature({', '.join(type(s).__name__ for s in self.specs)})"
