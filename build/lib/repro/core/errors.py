"""Failure/exit message types for actor supervision (paper §2.1).

The actor model addresses fault-tolerance by letting actors monitor each
other: when an actor dies, the runtime sends a ``DownMessage`` to every
monitor and an ``ExitMessage`` to every link (bidirectional monitor).
"""
from __future__ import annotations

import dataclasses
from typing import Any


class ActorError(Exception):
    """Base class for actor-runtime errors."""


class ActorFailed(ActorError):
    """Raised when requesting from an actor that terminated abnormally."""


class MailboxClosed(ActorError):
    """Message sent to an actor that already terminated."""


class SignatureMismatch(ActorError):
    """Message payload does not match the kernel signature (paper §3.4)."""


class AccessViolation(ActorError):
    """Operation not permitted by a DeviceRef's access rights (paper §3.5:
    "a reference type includes ... memory access rights")."""


class DeadlineExceeded(ActorError):
    """A deadline-carrying request or chunk missed its deadline before (or
    while) being served; the serve engine surfaces this per request."""


class GraphError(ActorError):
    """Base class for dataflow-graph construction/validation errors
    (``repro.core.graph``). Every subclass message names the offending
    node path (``<graph>/<node>``) — the build-time typed-actor check the
    paper gets from CAF's typed actor interfaces (§3.5)."""


class GraphCycleError(GraphError):
    """The graph topology contains a cycle; the message lists the node
    paths along the cycle."""


class DanglingPortError(GraphError):
    """An input slot was never wired, or a produced port has no consumer
    and is not a graph output (device-resident data that would leak)."""


class ArityMismatchError(GraphError):
    """A node is wired with a different number of input ports than its
    kernel signature declares."""


class PortTypeMismatchError(GraphError):
    """An edge's dtype/shape does not match the consumer's declared
    signature (or the producer's abstract-eval'd output type)."""


@dataclasses.dataclass(frozen=True)
class DownMessage:
    """Sent to monitors when a watched actor terminates (paper §2.1)."""

    actor_id: int
    reason: Any  # None for normal termination, the exception otherwise


@dataclasses.dataclass(frozen=True)
class ExitMessage:
    """Sent over links; by default kills the receiver unless it traps exits."""

    actor_id: int
    reason: Any
