"""WAH bitmap indexing on the device (paper §4; Fusco et al. IMC'13)."""
from .wah import (build_wah_index, build_wah_index_numpy, decode_wah_bitmap,
                  wah_index_pipeline_actors)

__all__ = ["build_wah_index", "build_wah_index_numpy", "decode_wah_bitmap",
           "wah_index_pipeline_actors"]
