"""WAH bitmap-index construction, fully data-parallel (paper §4).

Follows Fusco et al. ("Indexing Million of Packets Per Second Using
GPUs", IMC'13) as summarized in the paper: (1) encode values with input
position, (2) stable sort by value, (3) derive 31-bit chunk literals via
segmented OR, (4) derive zero-fill words from chunk gaps, (5)
``fuseFillsLiterals`` — interleave + stream-compact (paper Listing 5),
(6) build the per-value lookup table.

WAH word format (Wu et al.): literal = MSB 0 + 31 payload bits;
fill = MSB 1, bit 30 = fill bit, bits 0..29 = count of 31-bit groups.
Trailing zero-fills are implicit (decode pads to ``n``).

Everything runs on static shapes with the prefix-valid convention so the
whole pipeline jits; the hot stages use the Pallas kernels. The
:func:`wah_index_pipeline_actors` variant wires the same computation as a
composed pipeline of kernel actors exchanging ``DeviceRef``s — the exact
shape of the paper's Listing 5.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

__all__ = ["build_wah_index", "build_wah_index_numpy", "decode_wah_bitmap",
           "wah_index_pipeline_actors"]

_FILL_FLAG = jnp.uint32(1) << 31
_COUNT_MASK = (1 << 30) - 1


@functools.partial(jax.jit, static_argnames=("cardinality",))
def build_wah_index(values: jax.Array, cardinality: int):
    """Build a WAH bitmap index of ``values`` (uint32 < cardinality).

    Returns ``(index_words, n_words, starts, counts)``: the compacted word
    stream, its logical length, and the per-value lookup table.
    """
    n = values.shape[0]
    values = values.astype(jnp.uint32)
    pos = jnp.arange(n, dtype=jnp.int32)

    # (1)+(2): encode with position, stable sort by value → positions stay
    # ascending within each value, hence chunk ids are ascending.
    v_sorted, pos_sorted = ops.radix_sort(values, pos)
    v_sorted = v_sorted.astype(jnp.int32)

    # (3): 31-bit chunk literals by segmented OR (distinct bits → sum).
    chunk = pos_sorted // 31
    bit = (pos_sorted % 31).astype(jnp.uint32)
    bitword = (jnp.uint32(1) << bit)

    first = jnp.ones((1,), bool)
    new_v = jnp.concatenate([first, v_sorted[1:] != v_sorted[:-1]])
    new_seg = new_v | jnp.concatenate([first, chunk[1:] != chunk[:-1]])
    seg = jnp.cumsum(new_seg.astype(jnp.int32)) - 1          # element → segment
    n_seg = seg[-1] + 1

    literals = jax.ops.segment_sum(bitword, seg, num_segments=n)
    seg_valid = jnp.arange(n) < n_seg
    literals = jnp.where(seg_valid, literals, 0).astype(jnp.uint32)
    seg_v = jnp.zeros(n, jnp.int32).at[seg].set(v_sorted)
    seg_chunk = jnp.zeros(n, jnp.int32).at[seg].set(chunk)

    # (4): zero-fill words from gaps between consecutive chunks of a value.
    prev_chunk = jnp.concatenate([jnp.full((1,), -1, jnp.int32), seg_chunk[:-1]])
    same_v = jnp.concatenate([jnp.zeros((1,), bool), seg_v[1:] == seg_v[:-1]])
    prev = jnp.where(same_v, prev_chunk, -1)
    gap = seg_chunk - prev - 1
    fills = jnp.where(seg_valid & (gap > 0),
                      _FILL_FLAG | gap.astype(jnp.uint32), 0).astype(jnp.uint32)

    # (5): fuseFillsLiterals — interleave then compact (paper Listing 5).
    fused = ops.wah_interleave(fills, literals)
    index_words, n_words = ops.stream_compact(fused)

    # (6): lookup table — words contributed per segment, summed per value.
    words_per_seg = jnp.where(seg_valid, (gap > 0).astype(jnp.int32) + 1, 0)
    counts = jax.ops.segment_sum(words_per_seg, seg_v, num_segments=cardinality)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    return index_words, n_words, starts, counts.astype(jnp.int32)


def build_wah_index_numpy(values: np.ndarray, cardinality: int):
    """Sequential CPU reference (the paper Fig. 3 CPU baseline)."""
    n = values.shape[0]
    words, starts, counts = [], np.zeros(cardinality, np.int64), np.zeros(
        cardinality, np.int64)
    for v in range(cardinality):
        starts[v] = len(words)
        positions = np.flatnonzero(values == v)
        prev_chunk = -1
        cur_chunk, cur_word = None, 0
        for p in positions:
            c, b = divmod(int(p), 31)
            if c != cur_chunk:
                if cur_chunk is not None:
                    words.append(cur_word)
                gap = c - prev_chunk - 1 if cur_chunk is None else c - cur_chunk - 1
                if cur_chunk is None:
                    gap = c
                if gap > 0:
                    words.append((1 << 31) | gap)
                prev_chunk = cur_chunk if cur_chunk is not None else -1
                cur_chunk, cur_word = c, 0
            cur_word |= (1 << b)
        if cur_chunk is not None:
            words.append(cur_word)
        counts[v] = len(words) - starts[v]
    return np.asarray(words, np.uint32), len(words), starts, counts


def decode_wah_bitmap(index_words: np.ndarray, start: int, count: int) -> np.ndarray:
    """Decode one value's WAH word stream back to a position list."""
    positions = []
    chunk = 0
    for w in np.asarray(index_words[start:start + count], np.uint32):
        w = int(w)
        if w >> 31:
            positions_len_before = len(positions)
            assert (w >> 30) & 1 == 0, "only zero-fills are emitted"
            chunk += w & _COUNT_MASK
            del positions_len_before
        else:
            for b in range(31):
                if w & (1 << b):
                    positions.append(chunk * 31 + b)
            chunk += 1
    return np.asarray(positions, np.int64)


# ----------------------------------------------------------------------------
# Actor-pipeline variant (paper Listing 5): three kernel actors composed.
# ----------------------------------------------------------------------------
def wah_index_pipeline_actors(system, k: int, mode: str = "staged"):
    """Build the prepare → count → move pipeline for length-``k`` inputs.

    The returned pipeline ref accepts ``(fills, literals)`` (uint32, length
    k) and responds with ``(index_words, n_words)``. In ``staged`` mode
    (paper Listing 5) intermediates travel as ``DeviceRef``s — data stays
    on the device between stages; ``fused`` traces the three kernels into
    one program.
    """
    from repro.core import In, NDRange, Out, Pipeline, dim_vec, kernel
    from repro.kernels.stream_compact import pallas_local_compact

    bs = 256
    assert (2 * k) % bs == 0

    def prepare_index(fills, literals):
        return ops.wah_interleave(fills, literals)

    def count_elements(index):
        blocks, cnts = pallas_local_compact(index, bs=bs,
                                            interpret=not ops.on_tpu())
        return index, blocks, cnts

    def move_valid_elements(index, blocks, cnts):
        n = index.shape[0]
        counts = cnts[:, 0]
        offsets = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)])
        total = offsets[-1]
        i = jnp.arange(n)
        blk = jnp.clip(jnp.searchsorted(offsets, i, side="right") - 1,
                       0, blocks.shape[0] - 1)
        vals = blocks[blk, jnp.clip(i - offsets[blk], 0, bs - 1)]
        out = jnp.where(i < total, vals, 0).astype(jnp.uint32)
        return out, total.astype(jnp.int32)

    rng = NDRange(dim_vec(k))
    rng_sc = NDRange(dim_vec(2 * k), local_dims=dim_vec(bs))
    prepare = kernel(In(jnp.uint32), In(jnp.uint32),
                     Out(jnp.uint32, as_ref=True),
                     nd_range=rng, name="prepare_index")(prepare_index)
    count = kernel(In(jnp.uint32),
                   Out(jnp.uint32, as_ref=True),
                   Out(jnp.uint32, as_ref=True),
                   Out(jnp.int32, as_ref=True),
                   nd_range=rng_sc, name="count_elements")(count_elements)
    move = kernel(In(jnp.uint32), In(jnp.uint32), In(jnp.int32),
                  Out(jnp.uint32), Out(jnp.int32),
                  nd_range=rng_sc, name="move_valid_elements")(
                      move_valid_elements)
    return (Pipeline(system, mode=mode, name="wah_index")
            .stage(prepare).stage(count).stage(move).build())
