import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below runs with 512 placeholder devices -----------------------
"""Multi-pod dry-run entrypoint (assignment MULTI-POD DRY-RUN).

Lowers + compiles every (architecture × input shape) cell on the
single-pod (16×16) and multi-pod (2×16×16) production meshes, prints
``memory_analysis()`` / ``cost_analysis()``, and writes one JSON artifact
per cell under ``experiments/dryrun/``.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import sys


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--arch", action="append", default=None,
                        help="architecture id (repeatable); default: all")
    parser.add_argument("--shape", action="append", default=None,
                        help="input shape name (repeatable); default: all")
    parser.add_argument("--all", action="store_true")
    parser.add_argument("--single-pod-only", action="store_true",
                        help="skip the 2-pod 512-chip mesh")
    parser.add_argument("--out", default=None, help="artifact directory")
    parser.add_argument("--plan", default=None,
                        help="JSON dict of CellPlan overrides")
    args = parser.parse_args(argv)

    from repro import configs
    from repro.launch import dryrun_lib

    archs = args.arch or configs.list_archs()
    shapes = args.shape or list(configs.SHAPES)
    overrides = json.loads(args.plan) if args.plan else None

    results = dryrun_lib.run_cells(
        archs, shapes, multi_pod_check=not args.single_pod_only,
        out_dir=args.out or dryrun_lib.ARTIFACT_DIR,
        plan_overrides=overrides)

    failed = {k: v for k, v in results.items() if v["status"] == "FAILED"}
    ok = sum(1 for v in results.values() if v["status"] == "compiled")
    skipped = sum(1 for v in results.values() if v["status"] == "skipped")
    print(f"\n== dry-run: {ok} compiled, {skipped} skipped "
          f"(documented), {len(failed)} failed ==")
    for k, v in failed.items():
        print(f"  FAILED {k}: {v['error'][:200]}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
