"""Dry-run cell lowering: (arch × input-shape × mesh) → compiled artifact
+ roofline terms. Importable without touching device state; the
``dryrun.py`` entrypoint sets the 512-device XLA flag before importing.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import ModelConfig
from repro.dist import api as dist_api
from repro.dist import sharding as sh
from repro.dist import step as step_mod
from repro.models import Model, train_input_specs
from repro.optim import AdamWConfig
from repro.roofline import analysis as roof

ARTIFACT_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


@dataclasses.dataclass
class CellPlan:
    """Per-cell distribution knobs (overridable — the §Perf lever set)."""

    grad_accum: int = 1
    accum_dtype: str = "float32"
    opt_dtype: str = "float32"
    kv_cache: str = "heads"          # decode KV layout: heads | seq
    seq_activations: bool = False    # Megatron-SP residual stream
    tp_hints: bool = False           # pin TP projection outputs (Megatron)
    fsdp: bool = False               # ZeRO param+opt sharding over 'data'
    attn_impl: str = "xla"           # xla | xla_chunked[:q_chunk]
    remat: str = "full"

    def to_dict(self):
        return dataclasses.asdict(self)


_ACT_BUDGET = 4.0e9   # rematted residual-stream bytes per device (train)
_BIG_PARAMS = 90e9    # switch optimizer/accum state to bf16 above this


def plan_for(cfg: ModelConfig, shape_name: str, mesh,
             overrides: Optional[Dict[str, Any]] = None) -> CellPlan:
    seq, global_batch, kind = configs.SHAPES[shape_name]
    plan = CellPlan()
    sizes = _mesh_axis_sizes(mesh)
    msize = sizes.get("model", 1)
    dp = int(np.prod([sizes[a] for a in sh.data_axes(mesh)]))
    plan.fsdp = cfg.param_count() >= 25e9
    if kind == "train":
        big = cfg.param_count() >= _BIG_PARAMS
        plan.opt_dtype = "bfloat16" if big else "float32"
        plan.accum_dtype = "bfloat16" if big else "float32"
        plan.seq_activations = cfg.d_model >= 8192 and seq % msize == 0
        shard_div = msize if plan.seq_activations else 1
        layers = cfg.n_layers + (cfg.encdec.n_enc_layers or 0)
        per_row = seq * cfg.d_model * 2 * max(layers, 1) / shard_div
        rows_budget = max(int(_ACT_BUDGET // max(per_row, 1)), 1)
        if plan.seq_activations:
            # d≥8k giants: saved-stack copies in the scan-of-scan dominate
            # the CPU-backend arena; one microbatch row/device bounds peak
            rows_budget = 1
        accum = 1
        while accum < global_batch // dp and \
                (global_batch // (accum * dp)) > rows_budget:
            accum *= 2
        plan.grad_accum = accum
    elif kind == "prefill":
        plan.attn_impl = "xla_chunked:512"
    else:  # decode
        plan.kv_cache = "seq"
    for k, v in (overrides or {}).items():
        setattr(plan, k, v)
    return plan


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _model_for(cfg: ModelConfig, mesh, plan: CellPlan, seq: int) -> Model:
    msize = _mesh_axis_sizes(mesh)["model"]
    padded_vocab = cfg.padded_vocab(msize)
    cfg = dataclasses.replace(cfg, remat=plan.remat)
    return Model(cfg, vocab=padded_vocab, attn_impl=plan.attn_impl,
                 max_dec_len=max(448, seq))


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str, *,
               plan_overrides: Optional[Dict[str, Any]] = None,
               compile_cell: bool = True) -> Dict[str, Any]:
    """Lower (+compile) one cell; returns the report dict (assignment §3)."""
    cfg = configs.get_config(arch)
    if not configs.shape_applicable(cfg, shape_name):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped",
                "reason": "long_500k needs sub-quadratic attention "
                          "(DESIGN.md §5)"}
    seq, global_batch, kind = configs.SHAPES[shape_name]
    plan = plan_for(cfg, shape_name, mesh, plan_overrides)
    model = _model_for(cfg, mesh, plan, seq)
    chips = int(np.prod(mesh.devices.shape))
    t0 = time.monotonic()

    if kind == "train":
        lowered = _lower_train(model, mesh, plan, seq, global_batch)
    elif kind == "prefill":
        lowered = _lower_prefill(model, mesh, plan, seq, global_batch)
    else:
        lowered = _lower_decode(model, mesh, plan, seq, global_batch)
    t_lower = time.monotonic() - t0

    report: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": kind, "chips": chips, "plan": plan.to_dict(),
        "lower_s": round(t_lower, 2), "status": "lowered",
    }
    if not compile_cell:
        return report

    t0 = time.monotonic()
    compiled = lowered.compile()
    report["compile_s"] = round(time.monotonic() - t0, 2)
    report["status"] = "compiled"
    # assignment §3: print memory/cost analysis (proves it fits / §Roofline)
    try:
        print(f"-- {arch} {shape_name} {mesh_name} memory_analysis:",
              compiled.memory_analysis(), flush=True)
    except Exception:
        pass

    msize = _mesh_axis_sizes(mesh)["model"]
    seq_dims = {seq, seq // msize, 512, 1024, 2048}
    rl = roof.analyze(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=chips,
        model_flops=roof.model_flops_for(cfg, shape_name, seq, global_batch,
                                         kind),
        step_kind=kind, seq_dims=seq_dims)
    report["roofline"] = rl.to_dict()
    return report


def _tp_spec_map(cfg, mesh, dp):
    """Megatron-style output constraints for the TP projections: heads /
    hidden sharded on 'model' (when divisible), batch on the data axes."""
    msize = _mesh_axis_sizes(mesh)["model"]
    h_ok = cfg.n_heads and cfg.n_heads % msize == 0
    kv_ok = cfg.n_kv_heads and cfg.n_kv_heads % msize == 0
    ff_ok = cfg.d_ff and cfg.d_ff % msize == 0
    return {
        "attn_q": NamedSharding(mesh, P(
            dp, None, sh.MODEL_AXIS if h_ok else None, None)),
        "attn_kv": NamedSharding(mesh, P(
            dp, None, sh.MODEL_AXIS if kv_ok else None, None)),
        "mlp_hidden": NamedSharding(mesh, P(
            dp, None, sh.MODEL_AXIS if ff_ok else None)),
    }


# ----------------------------------------------------------------------------
def _train_state_shapes(model: Model, ocfg: AdamWConfig):
    return jax.eval_shape(
        lambda: step_mod.init_train_state(model, jax.random.key(0), ocfg))


def _presplit_specs(batch_specs, accum: int):
    """[B, ...] → [A, B/A, ...]; positions [3,B,S] → [A, 3, B/A, S]."""
    out = {}
    for k, v in batch_specs.items():
        if k == "positions":
            _, b, s = v.shape
            out[k] = jax.ShapeDtypeStruct((accum, 3, b // accum, s), v.dtype)
        else:
            b = v.shape[0]
            out[k] = jax.ShapeDtypeStruct((accum, b // accum) + v.shape[1:],
                                          v.dtype)
    return out


def _presplit_shardings(batch_specs, mesh):
    out = {}
    for k, v in batch_specs.items():
        if k == "positions":           # [A, 3, B/A, S]
            out[k] = NamedSharding(mesh, P(None, None,
                                           sh._dp_spec(mesh, v.shape[2]), None))
        else:                           # [A, B/A, ...]
            out[k] = NamedSharding(
                mesh, P(None, sh._dp_spec(mesh, v.shape[1]),
                        *([None] * (len(v.shape) - 2))))
    return out


def _lower_train(model: Model, mesh, plan: CellPlan, seq: int,
                 global_batch: int):
    cfg = model.cfg
    ocfg = AdamWConfig(state_dtype=plan.opt_dtype)
    state_shapes = _train_state_shapes(model, ocfg)
    p_sh = sh.param_shardings(state_shapes["params"], cfg, mesh,
                              sh.Plan(fsdp=plan.fsdp))
    state_sh = {"params": p_sh, "opt": sh.opt_state_shardings(p_sh, mesh),
                "step": NamedSharding(mesh, P())}
    batch_specs = train_input_specs(cfg, global_batch, seq)
    presplit = plan.grad_accum > 1
    if presplit:
        batch_specs = _presplit_specs(batch_specs, plan.grad_accum)
        b_sh = _presplit_shardings(batch_specs, mesh)
    else:
        b_sh = sh.batch_shardings(batch_specs, mesh)

    train_step = step_mod.build_train_step(
        model, ocfg, grad_accum=plan.grad_accum, accum_dtype=plan.accum_dtype,
        presplit=presplit, grad_shardings=p_sh)
    jitted = jax.jit(train_step, in_shardings=(state_sh, b_sh),
                     donate_argnums=(0,))

    mb_rows = global_batch // max(plan.grad_accum, 1)
    dp = sh._dp_spec(mesh, mb_rows)
    act = NamedSharding(mesh, P(
        dp, sh.MODEL_AXIS if plan.seq_activations else None, None))
    vocab_sh = NamedSharding(mesh, P(dp, None, sh.MODEL_AXIS))
    spec_map = _tp_spec_map(cfg, mesh, dp) if plan.tp_hints else None
    with dist_api.activation_sharding(act if plan.seq_activations else None), \
            dist_api.vocab_sharding(vocab_sh), \
            dist_api.spec_map(spec_map):
        return jitted.lower(state_shapes, batch_specs)


def _lower_prefill(model: Model, mesh, plan: CellPlan, seq: int,
                   global_batch: int):
    cfg = model.cfg
    param_shapes = model.param_shapes()
    p_sh = sh.param_shardings(param_shapes, cfg, mesh,
                              sh.Plan(fsdp=plan.fsdp))
    batch_specs = train_input_specs(cfg, global_batch, seq)
    batch_specs.pop("labels")
    b_sh = sh.batch_shardings(batch_specs, mesh)

    def prefill(params, batch):
        logits, _ = model.forward(params, batch)
        return logits[:, -1, :]  # last-position logits (serving prefill)

    jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh))
    return jitted.lower(param_shapes, batch_specs)


def _lower_decode(model: Model, mesh, plan: CellPlan, seq: int,
                  global_batch: int):
    cfg = model.cfg
    param_shapes = model.param_shapes()
    plan_obj = sh.Plan(kv_cache=plan.kv_cache, fsdp=plan.fsdp)
    p_sh = sh.param_shardings(param_shapes, cfg, mesh, plan_obj)

    if cfg.family == "encdec":
        frames = jax.ShapeDtypeStruct(
            (global_batch, cfg.encdec.n_frames, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
        cache_shapes = jax.eval_shape(
            lambda p, f: model.init_cache(global_batch, seq, params=p,
                                          frames=f),
            param_shapes, frames)
    else:
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(global_batch, seq))
    c_sh = sh.cache_shardings(cache_shapes, cfg, mesh, plan_obj)
    tok = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)
    t_sh = NamedSharding(mesh, P(sh._dp_spec(mesh, global_batch), None))

    serve_step = step_mod.build_serve_step(model)
    jitted = jax.jit(serve_step, in_shardings=(p_sh, c_sh, t_sh),
                     donate_argnums=(1,))
    return jitted.lower(param_shapes, cache_shapes, tok)


# ----------------------------------------------------------------------------
def run_cells(arch_list, shape_list, *, multi_pod_check: bool = True,
              out_dir: str = ARTIFACT_DIR,
              plan_overrides: Optional[Dict] = None,
              verbose: bool = True) -> Dict[str, Any]:
    from repro.launch.mesh import make_production_mesh
    os.makedirs(out_dir, exist_ok=True)
    results = {}
    mesh_single = make_production_mesh(multi_pod=False)
    mesh_multi = make_production_mesh(multi_pod=True) if multi_pod_check else None
    for arch in arch_list:
        for shape in shape_list:
            key = f"{arch}__{shape}"
            for mesh, mname in ((mesh_single, "1pod-256"),
                                *(((mesh_multi, "2pod-512"),)
                                  if multi_pod_check else ())):
                tag = f"{key}__{mname}"
                try:
                    rep = lower_cell(arch, shape, mesh, mname,
                                     plan_overrides=plan_overrides)
                except Exception as exc:  # noqa: BLE001 — report, keep going
                    rep = {"arch": arch, "shape": shape, "mesh": mname,
                           "status": "FAILED", "error": repr(exc)[:2000]}
                results[tag] = rep
                with open(os.path.join(out_dir, tag + ".json"), "w") as f:
                    json.dump(rep, f, indent=1)
                if verbose:
                    rl = rep.get("roofline", {})
                    print(f"[{rep['status']:9s}] {tag} "
                          f"compile={rep.get('compile_s', '-')}s "
                          f"bottleneck={rl.get('bottleneck', '-')} "
                          f"err={rep.get('error', '')[:120]}", flush=True)
    return results
