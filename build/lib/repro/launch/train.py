"""Production training launcher.

On real hardware this runs under the pod mesh with the per-arch plan from
``dryrun_lib.plan_for``; on this container it runs any arch's smoke config
end-to-end (the 512-device path is exercised by ``dryrun.py``).

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --steps 100 --batch 8 --seq 64 [--smoke/--full] [--ckpt DIR]
"""
from __future__ import annotations

import argparse
import os
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (pod-scale only)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    import jax
    from repro import configs
    from repro.checkpoint import checkpoint as ckpt
    from repro.data import Prefetcher, SyntheticLM
    from repro.dist import step as step_mod
    from repro.models import Model
    from repro.optim import AdamWConfig, schedule

    cfg = (configs.get_config if args.full else configs.get_smoke_config)(
        args.arch)
    model = Model(cfg)
    ocfg = AdamWConfig(lr=args.lr)
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq, seed=0)
    sched = schedule.warmup_cosine(max(args.steps // 10, 1), args.steps)
    train_step = jax.jit(step_mod.build_train_step(
        model, ocfg, grad_accum=args.grad_accum, lr_schedule=sched))

    start_step = 0
    state = step_mod.init_train_state(model, jax.random.key(0), ocfg)
    if args.ckpt and ckpt.latest_step(args.ckpt) is not None:
        state, manifest = ckpt.restore(args.ckpt, target=state)
        state = jax.tree.map(jax.numpy.asarray, state)
        start_step = manifest["step"]
        print(f"restored step {start_step} from {args.ckpt}")

    pf = Prefetcher(data, depth=2, start_step=start_step)
    t0 = time.perf_counter()
    try:
        for i in range(start_step, args.steps):
            step_idx, batch = pf.next()
            assert step_idx == i
            state, metrics = train_step(
                state, {k: jax.numpy.asarray(v) for k, v in batch.items()})
            if (i + 1) % args.log_every == 0:
                tok_s = ((i + 1 - start_step) * args.batch * args.seq /
                         (time.perf_counter() - t0))
                print(f"step {i + 1:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"tok/s={tok_s:,.0f}", flush=True)
            if args.ckpt and (i + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt, i + 1, state)
    finally:
        pf.close()
    if args.ckpt:
        ckpt.save(args.ckpt, args.steps, state)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
