"""Production mesh construction (assignment MULTI-POD DRY-RUN §1).

``make_production_mesh`` is a FUNCTION so importing this module never
touches JAX device state; callers (dryrun.py) set
``--xla_force_host_platform_device_count`` before first JAX use.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    from jax.sharding import Mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)}; the "
            "dry-run entrypoint must set xla_force_host_platform_device_count")
    if devices[0].platform == "tpu":  # topology-aware order on real hardware
        from jax.experimental import mesh_utils
        devs = mesh_utils.create_device_mesh(shape, devices=devices[:n])
    else:
        devs = np.asarray(devices[:n]).reshape(shape)
    return Mesh(devs, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary small meshes for tests (e.g. (2, 2) on 4 host devices)."""
    import jax
    from jax.sharding import Mesh

    n = int(np.prod(shape))
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, axes)
