"""Three-process serve-mesh demo: a MeshRouter on the driver sharding
requests across EngineReplica actors on worker nodes, surviving a
worker SIGKILL mid-traffic.

    python -m repro.launch.serve_mesh --workers 2 --rps 40 --duration 6

The driver listens, ``multiprocessing``-spawns generic worker processes
(:func:`repro.launch.node.run_worker` — the same binary every
distributed demo uses; behaviors ship at spawn time), ``spawn_remote``\\ s
one engine replica per worker, and drives an offered-load sweep. Midway
one worker is SIGKILLed: the router's monitor fires on NodeDown, the
requests in flight on the dead replica replay on the survivors, and the
demo asserts **zero lost and zero duplicated requests** — every
submitted request resolves exactly once with the tokens the toy model
predicts. The returned summary records achieved RPS and p99 latency
before / during / after the failure window; ``benchmarks/bench_mesh.py``
snapshots it into ``BENCH_PR8.json``.

Everything here is module-level so both sides of the spawn can import it
(the worker needs :func:`toy_engine` importable to build the shipped
:class:`~repro.serve.mesh.ReplicaSpec`).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

__all__ = ["toy_engine", "run_demo", "main"]


# ----------------------------------------------------------------------------
# toy decode model (module-level: shipped to workers inside a ReplicaSpec)
# ----------------------------------------------------------------------------
def toy_engine(system, *, service_delay_s: float = 0.01, n_workers: int = 1,
               max_batch: int = 8, max_wait_ms: float = 2.0):
    """Engine factory for :class:`~repro.serve.mesh.ReplicaSpec`: the
    counter toy model (cache row ``[seed, step]``, token ``seed*1000 +
    step`` — every request's output is predictable, so exactly-once is
    checkable from results alone), slowed by ``service_delay_s`` per
    decode step to simulate real model cost. The sleep forces
    ``jit_step=False``: inside a jitted step it would only fire at trace
    time."""
    import jax.numpy as jnp

    from repro.serve import ServeEngine

    def step(cache, tokens):
        if service_delay_s:
            time.sleep(service_delay_s)
        next_tok = (cache[:, 0] * 1000 + cache[:, 1]).astype(jnp.int32)
        return next_tok, cache.at[:, 1].add(1)

    def init(prompt):
        return jnp.asarray([int(prompt), 0], jnp.int32), 0

    return ServeEngine(system, step, init, n_workers=n_workers,
                       max_batch=max_batch, max_wait_ms=max_wait_ms,
                       jit_step=False)


def expected_tokens(seed: int, n: int) -> List[int]:
    return [seed * 1000 + i for i in range(n)]


# ----------------------------------------------------------------------------
# driver
# ----------------------------------------------------------------------------
def _window_metrics(records, done_times, start: float, end: float,
                    label: str) -> Dict[str, Any]:
    """Achieved RPS (completions landing in the window) and p99 latency
    (requests *submitted* in the window) for one wall-clock slice."""
    done_in = [t for t in done_times.values() if start <= t < end]
    lats = sorted(done_times[i] - sub for i, (sub, _) in enumerate(records)
                  if start <= sub < end and i in done_times)
    span = max(end - start, 1e-9)
    return {
        "window": label,
        "start_s": round(start, 3),
        "end_s": round(end, 3),
        "completed": len(done_in),
        "achieved_rps": len(done_in) / span,
        "p99_ms": (lats[min(len(lats) - 1,
                            int(round(0.99 * (len(lats) - 1))))] * 1e3
                   if lats else 0.0),
    }


def run_demo(workers: int = 2, *, rps: float = 40.0, duration_s: float = 6.0,
             kill_at_s: float = 2.0, recover_window_s: float = 1.5,
             max_new_tokens: int = 4, service_delay_s: float = 0.01,
             kill_one: bool = True, timeout: float = 120.0) -> dict:
    """Run the 1-driver + ``workers``-worker mesh sweep; returns a
    summary dict (also asserts the acceptance invariants — an
    AssertionError here is a real regression)."""
    import multiprocessing as mp

    from repro.core import ActorSystem
    from repro.net import NodeRuntime
    from repro.serve import MeshRouter, ReplicaSpec

    from .node import run_worker

    summary: dict = {"workers": workers, "offered_rps": rps,
                     "duration_s": duration_s, "kill_one": kill_one}
    system = ActorSystem("mesh-driver")
    node = NodeRuntime(system, name="driver", listen=("127.0.0.1", 0))
    ctx = mp.get_context("spawn")
    children: Dict[str, Any] = {}
    killer: Optional[threading.Timer] = None
    try:
        for i in range(workers):
            name = f"worker{i}"
            p = ctx.Process(target=run_worker, args=(node.address, name),
                            daemon=True)
            p.start()
            children[name] = p
        for name in children:
            if not node.wait_for_peer(name, timeout):
                raise TimeoutError(f"{name} never connected")

        spec = ReplicaSpec(toy_engine, service_delay_s=service_delay_s)
        router = MeshRouter(system, node, spec=spec, slo_budget_s=5.0,
                            min_replicas=workers, max_replicas=workers,
                            control_interval=0.1, max_attempts=5)
        for name in children:
            router.spawn_replica(name)
        router.start()
        # first touch builds each replica's engine (lazy on_start), and a
        # short warm-up sweep pays every replica's first-step cost before
        # the clock starts — the pre-failure window should measure steady
        # state, not cold start
        for rep in list(router._replicas.values()):
            rep.ref.ask("ping", timeout=timeout)
        n_warm = 4 * workers
        for f in [router.submit(0, max_new_tokens=2)
                  for _ in range(n_warm)]:
            f.result(timeout)

        victim = f"worker{workers - 1}"
        if kill_one:
            killer = threading.Timer(kill_at_s, children[victim].kill)
            killer.start()

        t0 = time.monotonic()
        records: List[tuple] = []        # (submit_rel_s, future)
        done_times: Dict[int, float] = {}  # index -> completion_rel_s

        def on_done(i, fut):
            done_times[i] = time.monotonic() - t0

        interval = 1.0 / rps
        n = 0
        while True:
            rel = time.monotonic() - t0
            if rel >= duration_s:
                break
            fut = router.submit(n, max_new_tokens=max_new_tokens)
            fut.add_done_callback(lambda f, i=n: on_done(i, f))
            records.append((rel, fut))
            n += 1
            time.sleep(max(0.0, (t0 + n * interval) - time.monotonic()))

        # every request resolves — lost requests would hang/raise here,
        # duplicates are impossible by construction (a future resolves
        # once; first-wins)
        for i, (_, fut) in enumerate(records):
            res = fut.result(timeout)
            assert res.tokens == expected_tokens(i, max_new_tokens), \
                f"request {i} got wrong tokens {res.tokens}"
        assert len(done_times) == len(records), "a completion went missing"

        s = router.stats()
        summary["submitted"] = s["submitted"] - n_warm
        summary["completed"] = s["completed"] - n_warm
        summary["replayed"] = s["replayed"]
        summary["replicas_lost"] = s["replicas_lost"]
        summary["lost"] = s["submitted"] - s["completed"]
        assert s["completed"] == len(records) + n_warm, s
        assert s["failed"] == 0 and s["shed"] == 0, s

        if kill_one:
            assert s["replicas_lost"] == 1, s
            assert s["replayed"] >= 1, \
                f"no request was in flight on {victim} at kill time: {s}"

        end = max(done_times.values())
        pre = _window_metrics(records, done_times, 0.0, kill_at_s, "pre")
        during = _window_metrics(records, done_times, kill_at_s,
                                 kill_at_s + recover_window_s, "during")
        post = _window_metrics(records, done_times,
                               kill_at_s + recover_window_s,
                               max(duration_s, end), "post")
        summary["windows"] = [pre, during, post]
        if kill_one:
            assert post["achieved_rps"] >= 0.8 * pre["achieved_rps"], \
                (f"throughput did not recover: pre {pre['achieved_rps']:.1f} "
                 f"rps, post {post['achieved_rps']:.1f} rps")
        router.shutdown()
        return summary
    finally:
        if killer is not None:
            killer.cancel()
        node.shutdown()
        system.shutdown()
        for p in children.values():
            if p.is_alive():
                p.kill()
            p.join(timeout=30)


def main(argv=None) -> None:
    import argparse
    import json

    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--workers", type=int, default=2)
    p.add_argument("--rps", type=float, default=40.0)
    p.add_argument("--duration", type=float, default=6.0)
    p.add_argument("--kill-at", type=float, default=2.0)
    p.add_argument("--no-kill", action="store_true",
                   help="skip the mid-run worker SIGKILL")
    args = p.parse_args(argv)
    out = run_demo(args.workers, rps=args.rps, duration_s=args.duration,
                   kill_at_s=args.kill_at, kill_one=not args.no_kill)
    print(json.dumps(out, indent=2, default=str))


if __name__ == "__main__":
    main()
