"""Worker-node launcher: join a cluster and serve actors until the
driver goes away.

    python -m repro.launch.node --connect 127.0.0.1:45123 --name worker0

A bare worker node publishes nothing of its own — the driver populates it
with ``NodeRuntime.spawn_remote(peer, behavior, publish=...)``. That keeps
the worker binary generic: behaviors live in driver-side code (any
picklable module-level callable / Actor subclass / KernelDecl) and are
shipped at spawn time, the same way CAF ships typed actor messages to a
remote ``middleman``.

:func:`run_worker` is the library entry point the two-process tests and
``examples/dist_pipeline.py`` run in their child processes (it must be an
importable module-level function for ``multiprocessing``'s spawn start
method to pickle).
"""
from __future__ import annotations

import argparse
from typing import Optional, Tuple

__all__ = ["run_worker", "main"]


def run_worker(addr: Tuple[str, int], name: str, *,
               compress: bool = False,
               max_workers: int = 8,
               timeout: Optional[float] = None) -> None:
    """Connect to the driver at ``addr`` and serve until it disconnects.

    Blocks in ``NodeRuntime.join()``; on return the local actor system is
    shut down. Runs in a fresh process, so imports stay inside."""
    from repro.core import ActorSystem
    from repro.net import NodeRuntime
    from repro.serve.mesh import local_replica_stats

    system = ActorSystem(name, max_workers=max_workers)
    node = NodeRuntime(system, name=name, compress=compress)
    # any EngineReplica the driver spawn_remotes here reports its load
    # through peer_stats (a mesh router reads this out of band of the
    # per-replica "stats" message path)
    node.add_stats_provider("serve", local_replica_stats)
    try:
        node.connect(tuple(addr))
        node.join(timeout=timeout)
    finally:
        node.shutdown()
        system.shutdown()


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--connect", required=True, metavar="HOST:PORT",
                   help="driver node address to dial")
    p.add_argument("--name", default=None, help="cluster-unique node name")
    p.add_argument("--compress", action="store_true",
                   help="int8-compress float refs at the wire boundary")
    p.add_argument("--max-workers", type=int, default=8,
                   help="actor scheduler threads")
    args = p.parse_args(argv)
    host, _, port = args.connect.rpartition(":")
    import os
    run_worker((host, int(port)), args.name or f"worker-{os.getpid():x}",
               compress=args.compress, max_workers=args.max_workers)


if __name__ == "__main__":
    main()
