"""Serving launcher: a thin CLI over :class:`repro.serve.ServeEngine`.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --requests 32 --batch 8 --steps 64

Each request decodes ``--steps`` greedy tokens against its own
device-resident cache; the engine batches requests (gang-scheduled — the
model cache carries a batch-uniform decode position, so mid-batch joins
are disabled) and reports per-request p50/p95/p99 latency plus the
DeviceRef traffic counters. ``--sync`` keeps the legacy single-process
loop (also the only path for encoder–decoder models, whose cache needs
per-request encoder frames).
"""
from __future__ import annotations

import argparse
import time

__all__ = ["main", "check_cache_capacity"]


def check_cache_capacity(steps: int, capacity: int) -> int:
    """Guard the decode length against the allocated cache.

    A decode of ``steps`` tokens occupies ``steps + 1`` cache slots (the
    prompt token plus one per generated token); a longer decode would
    silently wrap the ring buffer / overwrite live KV entries instead of
    failing loudly. Returns ``capacity`` so call sites can chain it.
    """
    if steps < 0:
        raise ValueError(f"steps must be >= 0, got {steps}")
    if steps + 1 > capacity:
        raise ValueError(
            f"decode of {steps} steps needs {steps + 1} cache slots but "
            f"only {capacity} were allocated; raise the cache capacity or "
            "shorten the decode")
    return capacity


def _run_engine(args, cfg, model, params, serve_step) -> int:
    import jax.numpy as jnp
    import numpy as np
    from repro.core import ActorSystem, memory_stats
    from repro.serve import ServeEngine

    capacity = args.steps + 1
    check_cache_capacity(args.steps, capacity)

    def step_fn(cache, tokens):
        nxt, _, cache = serve_step(params, cache, tokens[:, None])
        return nxt[:, 0], cache

    def init_fn(prompt):
        return model.init_cache(1, capacity), int(prompt)

    # Per-leaf batch axis, detected by diffing abstract cache shapes for
    # batch sizes 1 and 2 (layer-scanned leaves carry the layer count on
    # axis 0 and batch on axis 1). Leaves with no batch axis — the scalar
    # decode position — are batch-uniform and shared, which gang
    # scheduling keeps aligned.
    import jax
    s1 = jax.tree_util.tree_leaves(
        jax.eval_shape(lambda: model.init_cache(1, capacity)))
    s2 = jax.tree_util.tree_leaves(
        jax.eval_shape(lambda: model.init_cache(2, capacity)))
    batch_axes = [next((ax for ax, (a, b) in enumerate(zip(x.shape, y.shape))
                        if a != b), None)
                  for x, y in zip(s1, s2)]

    def combine(leaves, i):
        ax = batch_axes[i]
        return leaves[0] if ax is None else jnp.concatenate(leaves, axis=ax)

    def split(leaf, b, i):
        ax = batch_axes[i]
        if ax is None:
            return leaf
        return jax.lax.slice_in_dim(leaf, b, b + 1, axis=ax)

    with ActorSystem(name="serve") as system:
        engine = ServeEngine(system, step_fn, init_fn,
                             n_workers=args.workers, max_batch=args.batch,
                             allow_join=False, combine=combine, split=split)
        t0 = time.perf_counter()
        with engine:
            futs = [engine.submit(0, max_new_tokens=args.steps)
                    for _ in range(args.requests)]
            results = [f.result(timeout=600) for f in futs]
        dt = time.perf_counter() - t0
        stats = engine.stats()
    lat = stats["latency"]
    toks = sum(len(r.tokens) for r in results)
    print(f"{cfg.name}: {args.requests} requests × {args.steps} steps "
          f"(batch {args.batch}, {args.workers} workers) in {dt:.2f}s "
          f"({toks / dt:,.0f} tok/s)")
    print(f"latency p50={lat['p50_ms']:.1f}ms p95={lat['p95_ms']:.1f}ms "
          f"p99={lat['p99_ms']:.1f}ms | engine steps={stats['steps']} "
          f"requeues={stats['requeues']}")
    print("memref:", {k: v for k, v in memory_stats().items()
                      if k in ("transfers", "readbacks", "live_refs")})
    print("sample:", np.asarray(results[0].tokens)[:16].tolist())
    return 0


def _run_paged(args, cfg) -> int:
    """Paged-mode demo: disaggregated prefill/decode over a PagePool.

    Runs a single-layer greedy attention decoder at the config's model
    dims (token embedding + q/k/v/o projections) whose KV entries live in
    fixed-size pages: prefill workers write each prompt's pages (identical
    prompts share read-sealed pages through the prefix cache), the decode
    loop gathers pages per batch slot. Ends with a page-pressure report
    from ``DeviceManager.memory_stats()``.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import ActorSystem, memory_stats
    from repro.serve import PagePool, ServeEngine

    d = int(getattr(cfg, "d_model", 64))
    vocab = int(getattr(cfg, "vocab_size", 997) or 997)
    keys = jax.random.split(jax.random.key(0), 5)
    scale = 1.0 / np.sqrt(d)
    emb = jax.random.normal(keys[0], (vocab, d), jnp.float32) * scale
    wq, wk, wv, wo = (jax.random.normal(k, (d, d), jnp.float32) * scale
                      for k in keys[1:])

    def _attend(q, k, v, lengths):
        # q [B, d]; k/v [B, T, d]; positions >= length are masked out
        T = k.shape[1]
        scores = jnp.einsum("bd,btd->bt", q, k) / np.sqrt(d)
        mask = jnp.arange(T)[None, :] < lengths[:, None]
        scores = jnp.where(mask, scores, -jnp.inf)
        att = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bt,btd->bd", att, v)

    def prefill_fn(prompt):
        toks = jnp.asarray(np.asarray(prompt, dtype=np.int64) % vocab)
        x = emb[toks]                       # [T, d]
        entries = {"k": x @ wk, "v": x @ wv}
        q = (x[-1] @ wq)[None, :]
        o = _attend(q, entries["k"][None], entries["v"][None],
                    jnp.asarray([toks.shape[0]]))
        logits = (o @ wo) @ emb.T
        return entries, int(jnp.argmax(logits, axis=-1)[0])

    def step_fn(kv, lengths, tokens):
        x = emb[tokens % vocab]             # [B, d]
        entry = {"k": x @ wk, "v": x @ wv}
        # the incoming token's KV joins the context it attends over
        k = kv["k"].at[jnp.arange(x.shape[0]), lengths].set(entry["k"])
        v = kv["v"].at[jnp.arange(x.shape[0]), lengths].set(entry["v"])
        o = _attend(x @ wq, k, v, lengths + 1)
        logits = (o @ wo) @ emb.T
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), entry

    rng = np.random.default_rng(0)
    # mixed workload with repeats: every third request replays prompt 0,
    # so the pool's prefix cache gets exercised
    base_prompts = [rng.integers(0, vocab, size=l).tolist()
                    for l in (24, 6, 48, 12)]
    prompts = [base_prompts[0] if i % 3 == 0
               else base_prompts[i % len(base_prompts)]
               for i in range(args.requests)]

    with ActorSystem(name="serve-paged") as system:
        manager = system.opencl_manager()
        pool = PagePool.for_entries(prefill_fn(base_prompts[1])[0],
                                    page_tokens=16,
                                    max_pages=args.pages)
        engine = ServeEngine(system, step_fn=step_fn, cache_pool=pool,
                             prefill_fn=prefill_fn,
                             prefill_workers=args.prefill_workers,
                             n_workers=args.workers, max_batch=args.batch)
        t0 = time.perf_counter()
        with engine:
            futs = [engine.submit(p, max_new_tokens=args.steps)
                    for p in prompts]
            results = [f.result(timeout=600) for f in futs]
        dt = time.perf_counter() - t0
        stats = engine.stats()
        pressure = manager.memory_stats()
    lat = stats["latency"]
    toks = sum(len(r.tokens) for r in results)
    print(f"{cfg.name} [paged]: {args.requests} requests × {args.steps} "
          f"steps (batch {args.batch}, {args.workers} decode + "
          f"{args.prefill_workers} prefill workers) in {dt:.2f}s "
          f"({toks / dt:,.0f} tok/s)")
    print(f"latency p50={lat['p50_ms']:.1f}ms p95={lat['p95_ms']:.1f}ms "
          f"p99={lat['p99_ms']:.1f}ms | occupancy={stats['occupancy']:.2f} "
          f"prefills={stats['prefills']} prefix_hits={stats['prefix_hits']}")
    ps = stats["pool"]
    print(f"pool: {ps['pages_live']}/{ps['pages_total']} pages live "
          f"(peak {ps['peak_pages']}), shared={ps['pages_shared']}, "
          f"cow={ps['cow']}, fragmentation={ps['fragmentation']:.2f}")
    for name, dev in pressure.items():
        print(f"device {name}: pages_total={dev['pages_total']} "
              f"pages_free={dev['pages_free']} "
              f"pages_shared={dev['pages_shared']} "
              f"fragmentation={dev['fragmentation']:.2f}")
    print("memref:", {k: v for k, v in memory_stats().items()
                      if k in ("transfers", "readbacks", "live_refs")})
    print("sample:", np.asarray(results[0].tokens)[:16].tolist())
    return 0


def _run_sync(args, cfg, model, params, serve_step) -> int:
    import jax.numpy as jnp
    import numpy as np

    capacity = args.steps + 1
    check_cache_capacity(args.steps, capacity)
    if cfg.family == "encdec":
        rng = np.random.default_rng(0)
        frames = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.encdec.n_frames, cfg.d_model)),
            jnp.dtype(cfg.compute_dtype))
        cache = model.init_cache(args.batch, capacity, params=params,
                                 frames=frames)
    else:
        cache = model.init_cache(args.batch, capacity)

    toks = jnp.zeros((args.batch, 1), jnp.int32)
    outs = []
    t0 = time.perf_counter()
    for _ in range(args.steps):
        toks, _, cache = serve_step(params, cache, toks)
        outs.append(np.asarray(toks))
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: {args.steps} steps × {args.batch} requests "
          f"in {dt:.2f}s ({args.steps * args.batch / dt:,.0f} tok/s)")
    print("sample:", np.concatenate(outs, axis=1)[0, :16].tolist())
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=32,
                    help="engine mode: how many requests to serve")
    ap.add_argument("--batch", type=int, default=8,
                    help="max batch size (sync mode: the static batch)")
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--workers", type=int, default=2,
                    help="engine mode: decode worker replicas")
    ap.add_argument("--sync", action="store_true",
                    help="legacy synchronous loop instead of the engine")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV-cache demo: disaggregated prefill/decode "
                         "over a PagePool (single-layer attention at the "
                         "config's dims)")
    ap.add_argument("--prefill-workers", type=int, default=2,
                    help="paged mode: prefill worker replicas")
    ap.add_argument("--pages", type=int, default=512,
                    help="paged mode: PagePool capacity in pages")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)

    import jax
    from repro import configs
    from repro.dist import step as step_mod
    from repro.models import Model

    cfg = (configs.get_config if args.full else configs.get_smoke_config)(
        args.arch)
    if args.paged:
        return _run_paged(args, cfg)
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    if args.sync or cfg.family == "encdec":
        serve_step = jax.jit(step_mod.build_serve_step(model),
                             donate_argnums=(1,))
        return _run_sync(args, cfg, model, params, serve_step)
    # engine mode: the worker jits the batched step itself (and retries
    # must be able to replay a cache, so no donation here)
    serve_step = step_mod.build_serve_step(model)
    return _run_engine(args, cfg, model, params, serve_step)


if __name__ == "__main__":
    raise SystemExit(main())
