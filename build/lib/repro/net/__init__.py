"""Network-transparent distribution: nodes, brokers, and the spill-based
wire format (paper §2.1/§3.5 taken across the process boundary).

    system = ActorSystem("driver")
    node = NodeRuntime(system, name="driver", listen=("127.0.0.1", 0))
    # ... a worker process connects and publishes actors ...
    node.wait_for_peer("worker")
    stage = node.remote_actor("worker", "stage-square")
    out_ref = stage.ask(DeviceRef.put(x))   # spill → wire → unspill → ref

Remote handles are ordinary :class:`~repro.core.ActorRef`\\ s
(:class:`RemoteActorRef`), so pools, schedulers, pipelines, and the
``dist.fault`` supervisors take them unchanged.
"""
from .node import NodeDown, NodeRuntime, PayloadError, RemoteActorRef
from . import wire

__all__ = ["NodeDown", "NodeRuntime", "PayloadError", "RemoteActorRef",
           "wire"]
