"""Two-process demo: a 3-stage pipeline whose middle stage lives on
another node, plus node-death supervision and exactly-once chunk
re-issue.

This module is importable from both sides of a ``multiprocessing`` spawn
(behaviors and the child entry point must be module-level for pickling);
``examples/dist_pipeline.py`` and the slow two-process tests both drive
:func:`main`.

What it demonstrates (the PR's acceptance criteria):

1. **Network transparency** — the middle stage is a
   :class:`~repro.net.RemoteActorRef` used exactly like a local ref.
2. **Spill-based wire format** — the stage boundary is one (optionally
   int8-compressed) spill/unspill pair per wire hop, asserted via
   ``memory_stats()`` counters **on both sides** (each process has its own
   ref registry).
3. **Cross-node supervision + exactly-once** — SIGKILLing the worker
   process mid-run delivers a :class:`~repro.core.errors.DownMessage` to
   local monitors, and the chunks in flight on the dead node are re-issued
   on the surviving local worker with every result counted exactly once.
"""
from __future__ import annotations

import threading
from typing import Optional, Tuple

__all__ = ["main", "run_child"]

#: per-chunk compute time for the kill-mid-run phase — long enough that
#: chunks are in flight on the remote node when it is killed
CHUNK_DELAY_S = 0.15

#: never set — waited on with a timeout to simulate per-chunk compute.
#: Behaviors must not time.sleep (blocking-call-in-behavior): an Event
#: wait is interruptible in principle, a sleep never is.
_simulated_work = threading.Event()


def _simulate_compute() -> None:
    _simulated_work.wait(CHUNK_DELAY_S)


# ----------------------------------------------------------------------------
# behaviors (module-level: shipped to / run on the worker node)
# ----------------------------------------------------------------------------
def stage_square(ref):
    """Middle pipeline stage (remote): ref in → ref out, on-device."""
    from repro.core import DeviceRef
    return DeviceRef(ref.array * ref.array)


def chunk_work(i: int):
    """A deliberately slow chunk for the kill-mid-run phase."""
    _simulate_compute()
    return ("remote", i)


def run_child(addr: Tuple[str, int], name: str, compress: bool) -> None:
    """Worker-process entry: join the cluster, publish the stage and the
    chunk worker, serve until the driver goes away (or is killed)."""
    from repro.core import ActorSystem
    from repro.net import NodeRuntime

    system = ActorSystem(name)
    node = NodeRuntime(system, name=name, compress=compress)
    try:
        # publish BEFORE connecting: the driver's wait_for_peer returns as
        # soon as the hello handshake lands, so a lookup RPC can arrive
        # immediately — publishing after connect loses that race
        node.publish("stage-square", system.spawn(stage_square))
        node.publish("chunk-worker", system.spawn(chunk_work))
        node.connect(tuple(addr))
        node.join()
    finally:
        node.shutdown()
        system.shutdown()


# ----------------------------------------------------------------------------
# driver
# ----------------------------------------------------------------------------
def main(n: int = 4096, chunks: int = 12, *, compress: bool = True,
         kill_mid_run: bool = True, timeout: float = 120.0) -> dict:
    """Run the demo; returns a summary dict (also asserts the acceptance
    invariants — an AssertionError here is a real regression)."""
    import multiprocessing as mp

    import jax.numpy as jnp
    import numpy as np

    from repro.core import (ActorPool, ActorSystem, ChunkScheduler, DeviceRef,
                            DownMessage, memory_stats, reset_transfer_stats)
    from repro.net import NodeRuntime

    summary: dict = {"compress": compress}
    system = ActorSystem("driver")
    node = NodeRuntime(system, name="driver", listen=("127.0.0.1", 0),
                       compress=compress)
    ctx = mp.get_context("spawn")
    child = ctx.Process(target=run_child,
                        args=(node.address, "worker", compress), daemon=True)
    child.start()
    try:
        if not node.wait_for_peer("worker", timeout):
            raise TimeoutError("worker process never connected")

        # -- phase 1: 3-stage pipeline, stage 2 across the wire ------------
        prepare = system.spawn(
            lambda x: DeviceRef(jnp.asarray(x, dtype=jnp.float32) + 1.0))
        remote_square = node.remote_actor("worker", "stage-square", timeout)
        reduce_ = system.spawn(lambda ref: float(ref.to_value().sum()))

        x = np.arange(n, dtype=np.float32)
        reset_transfer_stats()
        ref1 = prepare.ask(x)                    # stage 1 (local, on-device)
        ref2 = remote_square.ask(ref1)           # stage 2 (remote): 2 hops
        total = reduce_.ask(ref2)                # stage 3 (local)
        expect = float(((x + 1.0) ** 2).sum())
        rel = abs(total - expect) / expect
        tol = 2e-2 if compress else 1e-5         # int8 wire is lossy
        assert rel < tol, f"pipeline result off by {rel:.3%}"

        driver_stats = memory_stats()
        worker_stats = node.peer_stats("worker", timeout)
        # exactly one spill/unspill pair per wire hop, on each side:
        # driver spills the request (hop 1) and unspills the reply (hop 2);
        # the worker mirrors it. Registries are per-process, so the two
        # snapshots are genuinely independent.
        assert driver_stats["spills"] == 1, driver_stats
        assert driver_stats["unspills"] == 1, driver_stats
        assert worker_stats["spills"] == 1, worker_stats
        assert worker_stats["unspills"] == 1, worker_stats
        summary.update(pipeline_result=total, rel_err=rel,
                       driver_stats=driver_stats, worker_stats=worker_stats)

        if not kill_mid_run:
            return summary

        # -- phase 2: kill the worker node mid-run -------------------------
        remote_worker = node.remote_actor("worker", "chunk-worker", timeout)
        local_worker = system.spawn(
            lambda i: (_simulate_compute(), ("local", i))[1])
        downs: list = []
        got_down = threading.Event()
        watcher = system.spawn(lambda m: (downs.append(m), got_down.set()))
        system.monitor(watcher, remote_worker)

        pool = ActorPool(system, [local_worker, remote_worker])
        sched = ChunkScheduler(pool, max_attempts=4)
        killer = threading.Timer(CHUNK_DELAY_S * 2.5, child.kill)
        killer.start()
        try:
            results = sched.run([(i,) for i in range(chunks)], timeout=timeout)
        finally:
            killer.cancel()
        ids = sorted(i for _, i in results)
        assert ids == list(range(chunks)), f"not exactly-once: {ids}"
        assert got_down.wait(timeout), "no DownMessage after node death"
        assert isinstance(downs[0], DownMessage)
        assert downs[0].actor_id == remote_worker.actor_id
        assert not remote_worker.is_alive()
        summary.update(
            chunks=chunks,
            reissued=sched.stats["failed"],
            sources={src for src, _ in results},
            down=repr(downs[0]),
        )
        return summary
    finally:
        node.shutdown()
        system.shutdown()
        if child.is_alive():
            child.kill()
        child.join(timeout=30)


if __name__ == "__main__":
    import json
    out = main()
    print(json.dumps({k: (sorted(v) if isinstance(v, set) else v)
                      for k, v in out.items()}, indent=2, default=str))
