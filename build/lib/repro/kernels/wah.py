"""WAH ``prepare_index`` kernel (paper §4, Listing 5; Fusco et al. IMC'13).

``fuseFillsLiterals`` first interleaves the fill and literal arrays into a
combined index array (``out[2i] = fills[i], out[2i+1] = literals[i]``)
before stream-compacting the zero entries. The interleave is a pure
layout transform — on TPU one VPU-tile-sized block of each input per grid
step, written as an interleaved double-width block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pallas_wah_interleave"]


def _interleave_kernel(f_ref, l_ref, o_ref, *, bs: int):
    f = f_ref[...]                                   # (1, bs)
    l = l_ref[...]                                   # (1, bs)
    pair = jnp.stack([f[0], l[0]], axis=1)           # (bs, 2)
    o_ref[...] = pair.reshape(1, 2 * bs)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def pallas_wah_interleave(fills: jax.Array, literals: jax.Array, *,
                          bs: int = 512, interpret: bool = False) -> jax.Array:
    (n,) = fills.shape
    assert fills.shape == literals.shape
    assert n % bs == 0, (n, bs)
    nb = n // bs
    out = pl.pallas_call(
        functools.partial(_interleave_kernel, bs=bs),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, bs), lambda b: (b, 0)),
            pl.BlockSpec((1, bs), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2 * bs), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 2 * bs), fills.dtype),
        interpret=interpret,
    )(fills.reshape(nb, bs), literals.reshape(nb, bs))
    return out.reshape(2 * n)
