"""Stream compaction (paper §4; Billeter et al. HPG'09) adapted to TPU.

The GPU algorithm is a 3-phase compaction built on intra-warp shuffles:
(1) per-work-group valid counts, (2) prefix over counts, (3) move.
Warp shuffles have no TPU analogue (DESIGN.md §2), so the per-block local
compaction is re-expressed as a **one-hot permutation matmul** on the MXU:

    p        = cumsum(valid) - 1                 # destination within block
    onehot   = (p[src] == dst) & valid[src]      # (bs × bs) 0/1 matrix
    compact  = onehot @ values                   # exact in f32 via 16-bit split

One Pallas pass emits, per block, the locally-compacted values and the
valid count. The global move (Billeter's phase 3) is a single XLA gather
assembled from the per-block counts in ``ops.stream_compact`` — irregular
data movement is XLA's job on TPU; regular compute stays in the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pallas_local_compact"]


def _local_compact_kernel(x_ref, out_ref, cnt_ref, *, bs: int, drop_value: int):
    x = x_ref[...].astype(jnp.uint32)                       # (1, bs)
    valid = x != jnp.uint32(drop_value)                     # (1, bs)
    incl = jnp.cumsum(valid.astype(jnp.int32), axis=1)      # (1, bs)
    p = incl - 1                                            # (1, bs) dest idx
    cnt_ref[0, 0] = incl[0, bs - 1]

    dst = jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 0)  # row = destination
    onehot = ((p == dst) & valid).astype(jnp.float32)       # (bs, bs)
    lo = (x & jnp.uint32(0xFFFF)).astype(jnp.float32)       # (1, bs)
    hi = (x >> jnp.uint32(16)).astype(jnp.float32)
    comp_lo = jnp.dot(onehot, lo.reshape(bs, 1),
                      preferred_element_type=jnp.float32)   # (bs, 1) exact
    comp_hi = jnp.dot(onehot, hi.reshape(bs, 1),
                      preferred_element_type=jnp.float32)
    comp = (comp_hi.astype(jnp.uint32) << jnp.uint32(16)) | \
        comp_lo.astype(jnp.uint32)
    out_ref[...] = comp.reshape(1, bs)


@functools.partial(jax.jit, static_argnames=("bs", "drop_value", "interpret"))
def pallas_local_compact(x: jax.Array, *, bs: int = 256, drop_value: int = 0,
                         interpret: bool = False):
    """Per-block compaction. ``x`` is uint32 of length divisible by ``bs``.

    Returns ``(blocks, counts)``: ``blocks[b, :counts[b]]`` are the
    surviving elements of block ``b`` in order.
    """
    (n,) = x.shape
    assert n % bs == 0, (n, bs)
    nb = n // bs
    xb = x.reshape(nb, bs)
    return pl.pallas_call(
        functools.partial(_local_compact_kernel, bs=bs, drop_value=drop_value),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, bs), lambda b: (b, 0))],
        out_specs=[
            pl.BlockSpec((1, bs), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bs), jnp.uint32),
            jax.ShapeDtypeStruct((nb, 1), jnp.int32),
        ],
        interpret=interpret,
    )(xb)
