"""Online-softmax (flash) attention forward kernel for TPU.

The LM-training hot spot. Grid ``(B, H, Sq/bq, Skv/bk)`` — the KV axis is
innermost so the (m, l, acc) running-softmax state lives in VMEM scratch
carried across sequential grid steps (the TPU substitute for a GPU
thread-block loop). GQA is handled in the KV index_map (``h // group``)
so grouped KV heads are never materialized. Supports causal and local-
window (RecurrentGemma) masking with right-aligned positions so
``Skv > Sq`` (decode/chunked-prefill) works.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["pallas_flash_attention"]

_NEG = -1e30
_LANES = 128


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               bq: int, bk: int, kv_steps: int, scale: float,
               causal: bool, window: Optional[int], pos_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Only blocks that can contain unmasked entries do work.
    q_last = qi * bq + bq - 1 + pos_offset          # largest query position
    k_first = ki * bk                               # smallest key position
    needed = True
    if causal:
        needed = k_first <= q_last
    if window is not None:
        k_last = ki * bk + bk - 1
        q_first = qi * bq + pos_offset
        needed = jnp.logical_and(needed, k_last > q_first - window)

    @pl.when(needed)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

        qpos = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + \
            (qi * bq + pos_offset)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) + ki * bk
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > (qpos - window)
        logits = jnp.where(mask, logits, _NEG)

        m_prev = m_ref[:, :1]                        # (bq, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(logits, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)                  # (bq, bk)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == kv_steps - 1)
    def _finish():
        l = l_ref[:, :1]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "scale", "interpret"))
def pallas_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: Optional[int] = None,
                           bq: int = 128, bk: int = 128,
                           scale: Optional[float] = None,
                           interpret: bool = False) -> jax.Array:
    """q: [B,H,Sq,D]; k,v: [B,Hkv,Skv,D] with Hkv | H. Returns [B,H,Sq,D]."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    kv_steps = skv // bk
    scale_val = (d ** -0.5) if scale is None else scale
    pos_offset = skv - sq  # right-aligned query positions

    grid = (b, h, sq // bq, kv_steps)
    return pl.pallas_call(
        functools.partial(_fa_kernel, bq=bq, bk=bk, kv_steps=kv_steps,
                          scale=scale_val, causal=causal, window=window,
                          pos_offset=pos_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, hh, qq, kk: (bb, hh, qq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, qq, kk, g=group: (bb, hh // g, kk, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, qq, kk, g=group: (bb, hh // g, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bb, hh, qq, kk: (bb, hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _LANES), jnp.float32),   # m
            pltpu.VMEM((bq, _LANES), jnp.float32),   # l
            pltpu.VMEM((bq, d), jnp.float32),        # acc
        ],
        interpret=interpret,
    )(q, k, v)
