"""Mandelbrot iteration kernel (paper §5.4 offload workload).

One VPU tile of pixels per grid step. Coordinates are derived in-kernel
from the global IDs (``broadcasted_iota`` over the tile + grid offsets) —
the TPU analogue of the OpenCL kernel calling ``get_global_id`` — so the
only input is a tiny scalar description of the viewport and the only
output is the iteration-count image. The escape-time loop runs masked
(SIMD predication) exactly like the GPU version.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pallas_mandelbrot"]


def _mandelbrot_kernel(o_ref, *, max_iter: int, re_min: float, im_min: float,
                       re_step: float, im_step: float, bh: int, bw: int,
                       row_offset: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    rows = jax.lax.broadcasted_iota(jnp.float32, (bh, bw), 0)
    cols = jax.lax.broadcasted_iota(jnp.float32, (bh, bw), 1)
    # global pixel coordinates of this tile (NDRange offsets, paper §3.4)
    y = rows + (i * bh + row_offset)
    x = cols + j * bw
    cr = re_min + x * re_step
    ci = im_min + y * im_step

    def body(_, carry):
        zr, zi, count = carry
        zr2, zi2 = zr * zr, zi * zi
        alive = (zr2 + zi2) <= 4.0
        nzr = zr2 - zi2 + cr
        nzi = 2.0 * zr * zi + ci
        zr = jnp.where(alive, nzr, zr)
        zi = jnp.where(alive, nzi, zi)
        return zr, zi, count + alive.astype(jnp.int32)

    zr = jnp.zeros((bh, bw), jnp.float32)
    zi = jnp.zeros((bh, bw), jnp.float32)
    cnt = jnp.zeros((bh, bw), jnp.int32)
    _, _, cnt = jax.lax.fori_loop(0, max_iter, body, (zr, zi, cnt))
    o_ref[...] = cnt


@functools.partial(jax.jit, static_argnames=(
    "height", "width", "max_iter", "re_min", "re_max", "im_min", "im_max",
    "bh", "bw", "row_offset", "total_height", "interpret"))
def pallas_mandelbrot(*, height: int, width: int, max_iter: int,
                      re_min: float, re_max: float, im_min: float, im_max: float,
                      bh: int = 8, bw: int = 128, row_offset: int = 0,
                      total_height: int | None = None,
                      interpret: bool = False) -> jax.Array:
    """Iteration counts for an ``height × width`` viewport slice.

    ``row_offset``/``total_height`` support the paper's fractional offload:
    a worker renders rows [row_offset, row_offset+height) of a
    ``total_height``-row image with consistent coordinates.
    """
    assert height % bh == 0 and width % bw == 0
    th = total_height if total_height is not None else height
    re_step = (re_max - re_min) / max(width - 1, 1)
    im_step = (im_max - im_min) / max(th - 1, 1)
    grid = (height // bh, width // bw)
    return pl.pallas_call(
        functools.partial(_mandelbrot_kernel, max_iter=max_iter,
                          re_min=re_min, im_min=im_min, re_step=re_step,
                          im_step=im_step, bh=bh, bw=bw, row_offset=row_offset),
        grid=grid,
        out_specs=pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((height, width), jnp.int32),
        interpret=interpret,
    )()
