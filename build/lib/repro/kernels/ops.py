"""Public jit'd wrappers for the kernel layer.

Each op dispatches between the Pallas kernel (TPU target; ``interpret=True``
executes the kernel body on CPU for validation) and the pure-jnp oracle in
:mod:`repro.kernels.ref`. ``impl`` ∈ {"auto", "pallas", "ref"}: "auto"
selects Pallas on TPU and interpreted Pallas elsewhere for the compaction/
sort/interleave family, and the oracle for attention (where interpreted
execution would be prohibitively slow at model shapes).

These wrappers also hold the XLA halves of the TPU adaptations: the
compaction gather and the radix-scatter permutation (see the kernel module
docstrings for why the irregular move lives in XLA on TPU).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import pallas_flash_attention
from .mandelbrot import pallas_mandelbrot
from .matmul import pallas_matmul
from .radix_sort import pallas_radix_pass
from .stream_compact import pallas_local_compact
from .wah import pallas_wah_interleave

__all__ = ["matmul", "mandelbrot", "stream_compact", "radix_sort",
           "wah_interleave", "flash_attention", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_pallas(impl: str) -> Tuple[bool, bool]:
    """→ (use_pallas, interpret)."""
    if impl == "ref":
        return False, False
    if impl == "pallas":
        return True, not on_tpu()
    if impl == "auto":
        return True, not on_tpu()
    raise ValueError(f"impl={impl!r}")


# ----------------------------------------------------------------------------
def matmul(a, b, *, impl: str = "auto", bm: int = 128, bn: int = 128,
           bk: int = 128):
    use, interp = _use_pallas(impl)
    m, k = a.shape
    _, n = b.shape
    if not use or m % bm or n % bn or k % bk:
        return ref.matmul(a, b)
    return pallas_matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=interp)


# ----------------------------------------------------------------------------
def mandelbrot(*, height: int, width: int, max_iter: int,
               re_min: float, re_max: float, im_min: float, im_max: float,
               row_offset: int = 0, total_height: Optional[int] = None,
               impl: str = "auto"):
    use, interp = _use_pallas(impl)
    th = total_height if total_height is not None else height
    if use and height % 8 == 0 and width % 128 == 0:
        return pallas_mandelbrot(height=height, width=width, max_iter=max_iter,
                                 re_min=re_min, re_max=re_max, im_min=im_min,
                                 im_max=im_max, row_offset=row_offset,
                                 total_height=th, interpret=interp)
    re_step = (re_max - re_min) / max(width - 1, 1)
    im_step = (im_max - im_min) / max(th - 1, 1)
    x = re_min + jnp.arange(width, dtype=jnp.float32)[None, :] * re_step
    y = im_min + (jnp.arange(height, dtype=jnp.float32)[:, None] + row_offset) * im_step
    return ref.mandelbrot(x, y, max_iter)


# ----------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("bs", "drop_value", "impl"))
def stream_compact(x, *, bs: int = 256, drop_value: int = 0,
                   impl: str = "auto"):
    """Compacted array (prefix-valid layout) + surviving count."""
    use, interp = _use_pallas(impl)
    n = x.shape[0]
    if not use or n % bs:
        return ref.stream_compact(x, drop_value)
    blocks, counts = pallas_local_compact(x.astype(jnp.uint32), bs=bs,
                                          drop_value=drop_value,
                                          interpret=interp)
    counts = counts[:, 0]                                 # (nb,)
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32),
                               jnp.cumsum(counts)])       # (nb+1,)
    total = offsets[-1]
    # Billeter phase 3 as one gather: output i comes from block
    # searchsorted(offsets, i) at local index i - offsets[block].
    i = jnp.arange(n)
    blk = jnp.searchsorted(offsets, i, side="right") - 1
    blk = jnp.clip(blk, 0, blocks.shape[0] - 1)
    local = i - offsets[blk]
    vals = blocks[blk, jnp.clip(local, 0, bs - 1)]
    out = jnp.where(i < total, vals, 0).astype(x.dtype)
    return out, total.astype(jnp.int32)


# ----------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("bits_per_pass", "bs", "impl"))
def radix_sort(keys, values=None, *, bits_per_pass: int = 8, bs: int = 256,
               impl: str = "auto"):
    """Stable LSD radix sort of uint32 keys (+ optional payload)."""
    use, interp = _use_pallas(impl)
    n = keys.shape[0]
    if not use or n % bs or bits_per_pass > 8:
        return ref.radix_sort_u32(keys, values, bits_per_pass=bits_per_pass)
    k = keys.astype(jnp.uint32)
    idx = jnp.arange(n, dtype=jnp.int32)
    nb, nbins = n // bs, 1 << bits_per_pass
    for p in range(32 // bits_per_pass):
        shift = p * bits_per_pass
        hist, rank = pallas_radix_pass(k, bs=bs, bits=bits_per_pass,
                                       shift=shift, interpret=interp)
        # global base per digit (exclusive over bins, summed over blocks)
        total = jnp.sum(hist, axis=0)                          # (nbins,)
        gbase = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                 jnp.cumsum(total)[:-1]])      # (nbins,)
        # per-(block, digit) offset: exclusive cumsum over blocks
        bprefix = jnp.concatenate(
            [jnp.zeros((1, nbins), jnp.int32),
             jnp.cumsum(hist, axis=0)[:-1]], axis=0)           # (nb, nbins)
        digit = ((k >> jnp.uint32(shift)) & jnp.uint32(nbins - 1)).astype(jnp.int32)
        blk = jnp.arange(n, dtype=jnp.int32) // bs
        dest = gbase[digit] + bprefix[blk, digit] + rank.reshape(-1)
        k = jnp.zeros_like(k).at[dest].set(k)
        idx = jnp.zeros_like(idx).at[dest].set(idx)
    if values is None:
        return k
    return k, jnp.take(values, idx)


# ----------------------------------------------------------------------------
def wah_interleave(fills, literals, *, bs: int = 512, impl: str = "auto"):
    use, interp = _use_pallas(impl)
    n = fills.shape[0]
    if not use or n % bs:
        return ref.wah_interleave(fills, literals)
    return pallas_wah_interleave(fills, literals, bs=bs, interpret=interp)


# ----------------------------------------------------------------------------
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, impl: str = "auto",
                    bq: int = 128, bk: int = 128):
    if impl == "pallas" or (impl == "auto" and on_tpu()):
        return pallas_flash_attention(q, k, v, causal=causal, window=window,
                                      bq=bq, bk=bk, interpret=not on_tpu())
    return ref.flash_attention(q, k, v, causal=causal, window=window)
