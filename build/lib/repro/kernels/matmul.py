"""MXU-tiled matrix multiply (paper §3.3 illustration kernel).

The OpenCL kernel assigns one work-item per output element; the TPU
adaptation assigns one *tile* per grid step so that every step performs a
(bm × bk) · (bk × bn) MXU contraction from VMEM, with a float32 VMEM
scratch accumulator carried across the K grid dimension (TPU grids execute
sequentially, so the scratch is the carry — the role OpenCL work-group
state played on the GPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["pallas_matmul"]


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def pallas_matmul(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
                  bk: int = 128, interpret: bool = False) -> jax.Array:
    """``a @ b`` with explicit (bm, bn, bk) VMEM tiling.

    Block sizes default to 128 — the MXU systolic dimension — and must
    divide the operand shapes (pad at the call site otherwise).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, bm, bn, bk)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
