"""LSD radix sort digit pass (paper §4: "radix sort using a fixed
cardinality of 16 bits") adapted to TPU.

Per digit pass the GPU version builds per-work-group histograms and ranks
with warp ballots. The TPU kernel computes, per block and entirely on the
MXU/VPU:

    onehot[src, bin] = (digit[src] == bin)            # (bs × nbins)
    hist[bin]        = ones(1,bs) @ onehot            # digit histogram
    before           = strict_lower_tri(bs) @ onehot  # prefix per bin
    rank[src]        = Σ_bin before[src,bin] * onehot[src,bin]

The wrapper (``ops.radix_sort``) turns (hist, rank) into global
destination indices with two tiny cumsums and applies the permutation with
one XLA scatter per pass — the irregular move again delegated to XLA,
mirroring the compaction design (DESIGN.md §2).

The Pallas path supports digit widths up to 8 bits (nbins ≤ 256 keeps the
onehot in VMEM); the paper's 16-bit cardinality runs on the oracle path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pallas_radix_pass"]


def _radix_pass_kernel(x_ref, hist_ref, rank_ref, *, bs: int, nbins: int,
                       shift: int):
    x = x_ref[...].astype(jnp.uint32)                          # (1, bs)
    digit = ((x >> jnp.uint32(shift)) & jnp.uint32(nbins - 1)).astype(jnp.int32)
    bins = jax.lax.broadcasted_iota(jnp.int32, (bs, nbins), 1)
    onehot = (digit.reshape(bs, 1) == bins).astype(jnp.float32)  # (bs, nbins)

    ones_row = jnp.ones((1, bs), jnp.float32)
    hist = jnp.dot(ones_row, onehot, preferred_element_type=jnp.float32)
    hist_ref[...] = hist.astype(jnp.int32)                     # (1, nbins)

    r = jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 1)
    tril = (c < r).astype(jnp.float32)                         # strictly lower
    before = jnp.dot(tril, onehot, preferred_element_type=jnp.float32)
    rank = jnp.sum(before * onehot, axis=1)                    # (bs,)
    rank_ref[...] = rank.astype(jnp.int32).reshape(1, bs)


@functools.partial(jax.jit, static_argnames=("bs", "bits", "shift", "interpret"))
def pallas_radix_pass(x: jax.Array, *, bs: int = 256, bits: int = 8,
                      shift: int = 0, interpret: bool = False):
    """One digit pass. Returns ``(hist[nb, nbins], rank[nb, bs])``."""
    assert bits <= 8, "Pallas path supports ≤8-bit digits (VMEM onehot)"
    (n,) = x.shape
    assert n % bs == 0, (n, bs)
    nb, nbins = n // bs, 1 << bits
    xb = x.reshape(nb, bs)
    return pl.pallas_call(
        functools.partial(_radix_pass_kernel, bs=bs, nbins=nbins, shift=shift),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, bs), lambda b: (b, 0))],
        out_specs=[
            pl.BlockSpec((1, nbins), lambda b: (b, 0)),
            pl.BlockSpec((1, bs), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, nbins), jnp.int32),
            jax.ShapeDtypeStruct((nb, bs), jnp.int32),
        ],
        interpret=interpret,
    )(xb)
