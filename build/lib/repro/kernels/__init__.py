"""Pallas TPU kernels for the paper's hot spots + jit'd wrappers + oracles.

Layout per assignment: ``<name>.py`` holds the ``pl.pallas_call`` +
``BlockSpec`` kernel, ``ops.py`` the public jit'd wrappers, ``ref.py`` the
pure-jnp oracles.
"""
from . import ops, ref
from .ops import (flash_attention, mandelbrot, matmul, radix_sort,
                  stream_compact, wah_interleave)

__all__ = ["ops", "ref", "flash_attention", "mandelbrot", "matmul",
           "radix_sort", "stream_compact", "wah_interleave"]
