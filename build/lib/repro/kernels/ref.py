"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth used by the per-kernel
allclose sweeps in ``tests/test_kernels_*.py`` and by the models/examples
when running on backends without Pallas support (``impl='ref'``).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "matmul",
    "mandelbrot",
    "stream_compact",
    "radix_sort_u32",
    "wah_interleave",
    "flash_attention",
]


# ----------------------------------------------------------------------------
# paper §3.3 — square (and rectangular) matrix product
# ----------------------------------------------------------------------------
def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


# ----------------------------------------------------------------------------
# paper §5.4 — Mandelbrot iteration counts
# ----------------------------------------------------------------------------
def mandelbrot(re0: jax.Array, im0: jax.Array, max_iter: int) -> jax.Array:
    """Iteration counts (int32) for z <- z^2 + c until |z| > 2.

    ``re0``/``im0`` are broadcastable coordinate grids. Implemented with a
    masked fori_loop — identical math to the kernel.
    """
    shape = jnp.broadcast_shapes(re0.shape, im0.shape)
    zr = jnp.zeros(shape, jnp.float32)
    zi = jnp.zeros(shape, jnp.float32)
    count = jnp.zeros(shape, jnp.int32)

    def body(_, carry):
        zr, zi, count = carry
        zr2, zi2 = zr * zr, zi * zi
        alive = (zr2 + zi2) <= 4.0
        new_zr = zr2 - zi2 + re0
        new_zi = 2.0 * zr * zi + im0
        zr = jnp.where(alive, new_zr, zr)
        zi = jnp.where(alive, new_zi, zi)
        count = count + alive.astype(jnp.int32)
        return zr, zi, count

    _, _, count = jax.lax.fori_loop(0, max_iter, body, (zr, zi, count))
    return count


# ----------------------------------------------------------------------------
# paper §4 — stream compaction (Billeter et al.)
# ----------------------------------------------------------------------------
def stream_compact(x: jax.Array, drop_value: int = 0) -> Tuple[jax.Array, jax.Array]:
    """Remove all entries equal to ``drop_value``.

    Returns ``(compacted, count)`` where ``compacted`` has the input length
    with the ``count`` surviving elements first (prefix-valid layout — the
    TPU-friendly static-shape convention; OpenCL returns the new length in
    the config buffer the same way, paper Listing 5).
    """
    valid = x != drop_value
    count = jnp.sum(valid, dtype=jnp.int32)
    # stable order of survivors: sort by (invalid, original index)
    key = jnp.where(valid, 0, 1).astype(jnp.int32)
    order = jnp.argsort(key, stable=True)
    compacted = jnp.where(jnp.arange(x.shape[0]) < count, x[order], 0)
    return compacted.astype(x.dtype), count


# ----------------------------------------------------------------------------
# paper §4 — LSD radix sort (fixed digit cardinality, paper uses 16 bits)
# ----------------------------------------------------------------------------
def radix_sort_u32(keys: jax.Array, values: Optional[jax.Array] = None,
                   bits_per_pass: int = 16):
    """Stable LSD radix sort of uint32 keys (optionally with a payload).

    Matches the paper's "radix sort using a fixed cardinality of 16 bits".
    The oracle uses jnp.argsort per digit pass to mirror pass structure.
    """
    assert 32 % bits_per_pass == 0
    k = keys.astype(jnp.uint32)
    idx = jnp.arange(k.shape[0])
    for p in range(32 // bits_per_pass):
        digit = (k >> (p * bits_per_pass)) & ((1 << bits_per_pass) - 1)
        order = jnp.argsort(digit.astype(jnp.int32), stable=True)
        k = k[order]
        idx = idx[order]
    if values is None:
        return k
    return k, jnp.take(values, idx)


# ----------------------------------------------------------------------------
# paper §4 — fuseFillsLiterals 'prepare_index': interleave fills & literals
# ----------------------------------------------------------------------------
def wah_interleave(fills: jax.Array, literals: jax.Array) -> jax.Array:
    """out[2i] = fills[i]; out[2i+1] = literals[i] (length 2k)."""
    assert fills.shape == literals.shape
    return jnp.stack([fills, literals], axis=1).reshape(-1)


# ----------------------------------------------------------------------------
# LM training hot spot — online-softmax attention oracle
# ----------------------------------------------------------------------------
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None) -> jax.Array:
    """Reference attention. Shapes: q [B,H,Sq,D], k/v [B,Hkv,Skv,D]; GQA is
    expressed by Hkv dividing H. ``window`` limits attention to the last
    ``window`` positions (local attention, RecurrentGemma-style)."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    assert h % hkv == 0
    group = h // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    scale = (d ** -0.5) if scale is None else scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    skv = k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (skv - sq)  # right-aligned positions
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > (qpos - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
