"""Dynamic concurrency checks: tracked locks and the ref-leak sentinel.

This is the runtime half of ``repro.analysis``. The static linter can
only see lock acquisitions the AST spells out; this module records the
*actual* acquisition-order graph while code runs, so the test suite
itself becomes the witness that the hierarchy documented in ``ORDER.md``
holds.

Everything here is **off by default**. Every lock-owning module in the
runtime creates its locks through the :func:`make_lock` /
:func:`make_rlock` seam; with ``REPRO_ANALYSIS`` unset those return
plain ``threading.Lock``/``RLock`` objects (zero overhead beyond one
function call at construction). Set ``REPRO_ANALYSIS=1`` and the same
seam hands out :class:`TrackedLock` / :class:`TrackedRLock` instead,
which

* maintain a per-thread stack of held locks,
* record every ``held → acquiring`` edge in a process-wide graph,
* raise :class:`LockOrderViolation` the moment an acquisition would
  close a cycle in that graph (a potential deadlock — caught *before*
  the process actually deadlocks, because the check runs on the edge,
  not on the block), and
* raise when an acquisition inverts the canonical order from
  ``ORDER.md`` (``repro.analysis.order``), even if no second thread has
  run the opposite interleaving yet.

``conftest.py`` exposes the same flag as a pytest plugin: a per-test
DeviceRef leak sentinel plus an end-of-session lock-graph summary, so
``REPRO_ANALYSIS=1 pytest`` gates every PR on "zero cycles, zero leaked
refs".

This module deliberately imports nothing from the rest of ``repro`` —
it sits *below* every runtime module (they import the seam from here),
so it must stay dependency-free apart from the standard library and
``repro.analysis.order``.
"""
from __future__ import annotations

import os
import threading
from typing import Any, Dict, List, Optional, Tuple

from .order import LOCK_RANKS, rank_of

__all__ = [
    "LockOrderViolation",
    "TrackedLock",
    "TrackedRLock",
    "make_lock",
    "make_rlock",
    "analysis_enabled",
    "lock_order_graph",
    "lock_order_cycles",
    "same_name_nestings",
    "recorded_violations",
    "reset_lock_graph",
]


def analysis_enabled() -> bool:
    """True when ``REPRO_ANALYSIS`` requests dynamic tracking."""
    return os.environ.get("REPRO_ANALYSIS", "").strip().lower() not in (
        "", "0", "false", "off")


class LockOrderViolation(RuntimeError):
    """An acquisition that closes a cycle in the observed lock graph,
    inverts the canonical ``ORDER.md`` hierarchy, or re-enters a
    non-reentrant lock on the same thread."""


class _Graph:
    """Process-wide acquisition-order graph over lock *names*."""

    def __init__(self):
        self.lock = threading.Lock()
        # name -> {name -> first-seen site string}
        self.edges: Dict[str, Dict[str, str]] = {}
        # (name, name) nestings between *different instances of the same
        # name* — not ranked by ORDER.md, reported separately
        self.same_name: Dict[str, str] = {}
        # violations raised so far (kept for the pytest summary even if
        # the raising test swallowed the exception)
        self.violations: List[str] = []

    def add_edge(self, a: str, b: str, site: str) -> None:
        with self.lock:
            self.edges.setdefault(a, {}).setdefault(b, site)

    def would_cycle(self, a: str, b: str) -> Optional[List[str]]:
        """Path ``b →* a`` in the current graph (adding ``a → b`` would
        close it into a cycle); returns the path or None."""
        with self.lock:
            seen = set()
            stack: List[Tuple[str, List[str]]] = [(b, [b])]
            while stack:
                node, path = stack.pop()
                if node == a:
                    return path
                if node in seen:
                    continue
                seen.add(node)
                for nxt in self.edges.get(node, ()):
                    stack.append((nxt, path + [nxt]))
        return None

    def cycles(self) -> List[List[str]]:
        """Every elementary cycle reachable in the recorded graph
        (deduplicated by node set) — empty on a healthy run."""
        out: List[List[str]] = []
        seen_sets = set()
        with self.lock:
            edges = {a: list(bs) for a, bs in self.edges.items()}
        for start in edges:
            stack = [(start, [start])]
            while stack:
                node, path = stack.pop()
                for nxt in edges.get(node, ()):
                    if nxt == start:
                        key = frozenset(path)
                        if key not in seen_sets:
                            seen_sets.add(key)
                            out.append(path + [start])
                    elif nxt not in path:
                        stack.append((nxt, path + [nxt]))
        return out


_graph = _Graph()
_held = threading.local()   # per-thread list of [lock, count] entries


def _held_stack() -> List[list]:
    st = getattr(_held, "stack", None)
    if st is None:
        st = _held.stack = []
    return st


def lock_order_graph() -> Dict[str, Dict[str, str]]:
    """Snapshot of the observed ``held → acquired`` edges (name-keyed;
    the value is the first call site that recorded the edge)."""
    with _graph.lock:
        return {a: dict(bs) for a, bs in _graph.edges.items()}


def lock_order_cycles() -> List[List[str]]:
    """Cycles in the observed graph — the dynamic analogue of the
    static ``lock-order`` rule's report. Empty on a healthy run."""
    return _graph.cycles()


def same_name_nestings() -> Dict[str, str]:
    """Nestings between two different instances sharing one name (e.g.
    two per-actor ``ActorState`` locks) — legal only under a documented
    instance-level tie-break, so they are surfaced for review rather
    than failed."""
    with _graph.lock:
        return dict(_graph.same_name)


def recorded_violations() -> List[str]:
    """Messages of every LockOrderViolation raised so far (kept even if
    the caller swallowed the exception)."""
    with _graph.lock:
        return list(_graph.violations)


def reset_lock_graph() -> None:
    """Forget recorded edges/violations (test isolation)."""
    with _graph.lock:
        _graph.edges.clear()
        _graph.same_name.clear()
        _graph.violations.clear()


def _site() -> str:
    """A terse ``file:line`` for the acquisition site (first frame
    outside this module)."""
    import traceback
    for frame in reversed(traceback.extract_stack(limit=12)[:-2]):
        if not frame.filename.endswith("runtime.py"):
            return f"{os.path.basename(frame.filename)}:{frame.lineno}"
    return "?"


def _violation(msg: str) -> LockOrderViolation:
    with _graph.lock:
        _graph.violations.append(msg)
    return LockOrderViolation(msg)


class TrackedLock:
    """Drop-in ``threading.Lock`` that records acquisition order.

    ``name`` keys the process-wide graph and (when listed in
    ``ORDER.md``) the canonical-rank check. Cycle and rank checks run on
    the *edge* — i.e. while attempting the acquisition — so a potential
    deadlock raises instead of hanging.
    """

    _reentrant = False

    def __init__(self, name: str):
        self.name = name
        self._inner = self._make_inner()

    def _make_inner(self):
        return threading.Lock()

    # -- the checks -------------------------------------------------------
    def _check_before(self, blocking: bool) -> None:
        stack = _held_stack()
        for entry in stack:
            held = entry[0]
            if held is self:
                if not self._reentrant:
                    raise _violation(
                        f"lock {self.name!r} re-acquired by the thread "
                        f"already holding it (non-reentrant self-deadlock) "
                        f"at {_site()}")
                return   # reentrant re-acquire: no new edges
        if not stack:
            return
        held_top = stack[-1][0]
        if held_top is self:
            return
        a, b = held_top.name, self.name
        if a == b:
            # two different instances of the same named lock: not ranked,
            # recorded separately (see same_name_nestings)
            with _graph.lock:
                _graph.same_name.setdefault(a, _site())
            return
        rb = rank_of(b)
        if rb is not None:
            # Compare against the innermost rank across *all* held locks,
            # not just the top of stack — an unranked lock in between must
            # not mask an inversion (ranked -> unranked -> outer ranked).
            worst_name: Optional[str] = None
            worst_rank: Optional[int] = None
            for held_entry in stack:
                r = rank_of(held_entry[0].name)
                if r is not None and (worst_rank is None or r > worst_rank):
                    worst_name, worst_rank = held_entry[0].name, r
            if worst_rank is not None and rb < worst_rank:
                raise _violation(
                    f"canonical lock-order violation: acquiring {b!r} "
                    f"(rank {rb}) while holding {worst_name!r} "
                    f"(rank {worst_rank}) at {_site()} — ORDER.md says "
                    f"{b!r} is an outer lock and must be taken first")
        if blocking:
            path = _graph.would_cycle(a, b)
            if path is not None:
                raise _violation(
                    f"lock-order cycle: acquiring {b!r} while holding "
                    f"{a!r} at {_site()}, but the reverse order "
                    f"{' -> '.join(path)} -> {a!r} was already observed "
                    "— two threads interleaving these paths deadlock")
            # Non-blocking probes record their edge only on *success*
            # (see acquire()): a failed try-lock never blocks, so it must
            # not seed phantom edges that later read as cycles.
            _graph.add_edge(a, b, _site())

    def _on_acquired(self) -> None:
        stack = _held_stack()
        if stack and stack[-1][0] is self:
            stack[-1][1] += 1
        else:
            stack.append([self, 1])

    def _on_released(self) -> None:
        stack = _held_stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i][0] is self:
                stack[i][1] -= 1
                if stack[i][1] <= 0:
                    del stack[i]
                return

    # -- lock protocol ----------------------------------------------------
    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._check_before(blocking)
        got = self._inner.acquire(blocking, timeout)
        if got:
            if not blocking:
                # non-blocking probes (e.g. Condition._is_owned) record
                # their edge only on success, to keep probe noise out
                stack = _held_stack()
                if stack and stack[-1][0] is not self:
                    a, b = stack[-1][0].name, self.name
                    if a != b:
                        _graph.add_edge(a, b, _site())
            self._on_acquired()
        return got

    def release(self) -> None:
        self._inner.release()
        self._on_released()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def __repr__(self):
        rank = rank_of(self.name)
        return (f"{type(self).__name__}({self.name!r}, "
                f"rank={'unranked' if rank is None else rank})")


class TrackedRLock(TrackedLock):
    """Drop-in ``threading.RLock`` with the same tracking.

    Implements the private ``_release_save`` / ``_acquire_restore`` /
    ``_is_owned`` trio so ``threading.Condition`` waits correctly on a
    recursively held tracked lock (a plain release() would only pop one
    recursion level).
    """

    _reentrant = True

    def _make_inner(self):
        return threading.RLock()

    # -- Condition support -------------------------------------------------
    def _release_save(self):
        state = self._inner._release_save()
        stack = _held_stack()
        count = 0
        for i in range(len(stack) - 1, -1, -1):
            if stack[i][0] is self:
                count = stack[i][1]
                del stack[i]
                break
        return (state, count)

    def _acquire_restore(self, saved):
        state, count = saved
        self._inner._acquire_restore(state)
        if count:
            _held_stack().append([self, count])

    def _is_owned(self):
        return self._inner._is_owned()


def make_lock(name: str):
    """The lock-constructor seam: a plain ``threading.Lock`` normally, a
    :class:`TrackedLock` under ``REPRO_ANALYSIS=1``. ``name`` should be
    the class-level lock name listed in ``ORDER.md`` (unlisted names are
    tracked for cycles but not ranked)."""
    if analysis_enabled():
        return TrackedLock(name)
    return threading.Lock()


def make_rlock(name: str):
    """Reentrant variant of :func:`make_lock`."""
    if analysis_enabled():
        return TrackedRLock(name)
    return threading.RLock()


# ----------------------------------------------------------------------------
# DeviceRef leak sentinel (driven by the pytest plugin in conftest.py)
# ----------------------------------------------------------------------------
def settled_ref_growth(before: int, *, timeout: float = 2.0,
                       poll: float = 0.02) -> int:
    """How many more DeviceRefs are live than ``before``, after giving
    garbage collection and in-flight actor callbacks ``timeout`` seconds
    to settle. Returns <= 0 when everything was reclaimed.

    Imports ``repro.core.memref`` lazily so merely importing this module
    never pulls in jax.
    """
    import gc
    import time

    from repro.core.memref import live_ref_count

    deadline = time.monotonic() + timeout
    growth = live_ref_count() - before
    while growth > 0 and time.monotonic() < deadline:
        gc.collect()
        growth = live_ref_count() - before
        if growth <= 0:
            break
        time.sleep(poll)  # lint: leak-sentinel settle poll, test-only path
    return growth
