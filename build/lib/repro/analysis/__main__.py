"""CLI: ``python -m repro.analysis [paths] --baseline FILE``.

Exit status:

* ``0`` — no findings outside the baseline (stale baseline entries are
  reported as warnings but do not fail the run — *except* that an entry
  whose finding still exists obviously keeps the run green only while
  the finding is baselined; delete the line after fixing the code).
* ``1`` — at least one finding not covered by the baseline, or a file
  that could not be parsed.
* ``2`` — usage error.

``--write-baseline`` regenerates the baseline from the current tree
(use when adopting the linter, never to silence a regression).
"""
from __future__ import annotations

import argparse
import sys
from typing import List

from .lint import compare, load_baseline, run_rules, write_baseline


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Actor-runtime lint: ref lifecycle, blocking calls "
                    "in behaviors, silent excepts, static lock order.")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to lint (default: src/repro)")
    ap.add_argument("--baseline", metavar="FILE", default=None,
                    help="fingerprint file of accepted pre-existing "
                         "findings; only findings NOT listed fail the run")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write all current findings to --baseline and "
                         "exit 0")
    ap.add_argument("--list", action="store_true", dest="list_all",
                    help="print every finding, including baselined ones")
    args = ap.parse_args(argv)

    paths = args.paths or ["src/repro"]
    findings, errors = run_rules(paths)

    for err in errors:
        print(f"error: {err}", file=sys.stderr)

    if args.write_baseline:
        if not args.baseline:
            print("error: --write-baseline requires --baseline FILE",
                  file=sys.stderr)
            return 2
        n = write_baseline(args.baseline, findings)
        print(f"wrote {n} fingerprint(s) to {args.baseline}")
        return 0

    baseline = load_baseline(args.baseline) if args.baseline else []
    new, stale = compare(findings, baseline)

    shown = findings if args.list_all else new
    for f in shown:
        tag = "" if f in new else " [baselined]"
        print(f.render() + tag)

    for b in stale:
        print(f"warning: stale baseline entry (finding fixed? delete the "
              f"line): {b}", file=sys.stderr)

    total, n_new = len(findings), len(new)
    print(f"{total} finding(s), {n_new} new, "
          f"{total - n_new} baselined, {len(stale)} stale baseline "
          f"entr{'y' if len(stale) == 1 else 'ies'}", file=sys.stderr)
    return 1 if (new or errors) else 0


if __name__ == "__main__":
    sys.exit(main())
