"""Static and dynamic analysis for the actor runtime.

* ``repro.analysis.lint`` + ``repro.analysis.rules`` — the AST linter
  (``python -m repro.analysis [paths] --baseline analysis-baseline.txt``).
* ``repro.analysis.runtime`` — ``TrackedLock``/``TrackedRLock`` and the
  ``make_lock``/``make_rlock`` seam (activated by ``REPRO_ANALYSIS=1``),
  plus the DeviceRef leak-sentinel helper used by the pytest plugin.
* ``repro.analysis.order`` / ``ORDER.md`` — the canonical cross-module
  lock hierarchy both halves enforce.

This package must stay importable without jax: the runtime modules
import the lock seam at import time, and the CLI lints source trees
that may not be runnable in the linting environment.
"""
from .order import CANONICAL_LOCK_ORDER, LOCK_RANKS, order_path, rank_of
from .runtime import (LockOrderViolation, TrackedLock, TrackedRLock,
                      analysis_enabled, lock_order_cycles,
                      lock_order_graph, make_lock, make_rlock,
                      recorded_violations, reset_lock_graph)

__all__ = [
    "CANONICAL_LOCK_ORDER", "LOCK_RANKS", "order_path", "rank_of",
    "LockOrderViolation", "TrackedLock", "TrackedRLock",
    "analysis_enabled", "lock_order_cycles", "lock_order_graph",
    "make_lock", "make_rlock", "recorded_violations", "reset_lock_graph",
]
