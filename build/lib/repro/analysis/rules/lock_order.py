"""lock-order: static acquisition-order graph from nested ``with``.

The prepass harvests two cross-module facts:

1. **Lock names** — assignments of the form
   ``self.ATTR = make_lock("Name")`` / ``make_rlock("Name")`` (the
   seam every runtime module constructs its locks through), plus plain
   ``threading.Lock()/RLock()`` sites, which get the synthesized name
   ``Class.ATTR``. ``self.CV = threading.Condition(self.LOCK)`` aliases
   the condition attribute to its underlying lock's name.
2. **Nesting edges** — syntactically nested ``with self.X:`` blocks
   whose context expressions resolve to known locks. (The static view
   only sees lexical nesting; the dynamic ``TrackedLock`` graph covers
   nesting through calls.)

The rule then reports, per module:

* **rank inversions** — an edge ``outer → inner`` where ``ORDER.md``
  ranks ``inner`` *above* ``outer`` (the inner acquisition should have
  come first), and
* **cycles** — strongly-connected knots in the global edge graph,
  reported once, on the module owning the cycle's first edge.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from ..lint import Finding, ModuleInfo, ProjectContext
from ..order import rank_of

# edge: (outer_name, inner_name, relpath, path, line, qualname)
Edge = Tuple[str, str, str, str, int, str]


def _lock_name_from_call(call: ast.Call, cls: str, attr: str,
                         ) -> Optional[str]:
    f = call.func
    callee = f.id if isinstance(f, ast.Name) else (
        f.attr if isinstance(f, ast.Attribute) else "")
    if callee in ("make_lock", "make_rlock"):
        if call.args and isinstance(call.args[0], ast.Constant) and \
                isinstance(call.args[0].value, str):
            return call.args[0].value
        return f"{cls}.{attr}" if cls else attr
    if callee in ("Lock", "RLock"):
        return f"{cls}.{attr}" if cls else attr
    return None


def _harvest_module(mod: ModuleInfo) -> Dict[Tuple[str, str], str]:
    """(class_name, attr) -> lock name for this module; module-level
    locks use class_name ''. Conditions alias their wrapped lock."""
    table: Dict[Tuple[str, str], str] = {}

    def scan(node: ast.AST, cls: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                scan(child, child.name)
                continue
            if isinstance(child, ast.Assign) and \
                    isinstance(child.value, ast.Call) and \
                    len(child.targets) == 1:
                tgt = child.targets[0]
                attr = None
                if isinstance(tgt, ast.Attribute) and \
                        isinstance(tgt.value, ast.Name) and \
                        tgt.value.id == "self":
                    attr = tgt.attr
                elif isinstance(tgt, ast.Name):
                    attr = tgt.id
                if attr is not None:
                    name = _lock_name_from_call(child.value, cls, attr)
                    if name is not None:
                        table[(cls, attr)] = name
                    else:
                        # Condition(self._lock) aliases to the lock
                        f = child.value.func
                        callee = f.id if isinstance(f, ast.Name) else (
                            f.attr if isinstance(f, ast.Attribute) else "")
                        if callee == "Condition" and child.value.args:
                            a0 = child.value.args[0]
                            if isinstance(a0, ast.Attribute) and \
                                    isinstance(a0.value, ast.Name) and \
                                    a0.value.id == "self" and \
                                    (cls, a0.attr) in table:
                                table[(cls, attr)] = table[(cls, a0.attr)]
            scan(child, cls)

    scan(mod.tree, "")
    return table


def _resolve(expr: ast.expr, cls: str,
             table: Dict[Tuple[str, str], str]) -> Optional[str]:
    if isinstance(expr, ast.Attribute) and \
            isinstance(expr.value, ast.Name) and expr.value.id == "self":
        return table.get((cls, expr.attr))
    if isinstance(expr, ast.Name):
        return table.get(("", expr.id))
    return None


def _enclosing_class(mod: ModuleInfo, fn: ast.AST) -> str:
    qual = mod.qualname_of(fn)
    return qual.split(".")[0] if "." in qual else ""


def prepass_lock_order(ctx: ProjectContext) -> None:
    tables: Dict[str, Dict[Tuple[str, str], str]] = {}
    for mod in ctx.modules:
        t = _harvest_module(mod)
        tables[mod.relpath] = t
        for (cls, attr), name in t.items():
            ctx.lock_names[f"{mod.relpath}::{cls}::{attr}"] = name

    edges: List[Edge] = []
    for mod in ctx.modules:
        table = tables[mod.relpath]
        if not table:
            continue
        funcs = [n for n in ast.walk(mod.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for fn in funcs:
            cls = _enclosing_class(mod, fn)
            qual = mod.qualname_of(fn)

            def walk(node: ast.AST, held: List[Tuple[str, int]]) -> None:
                for child in ast.iter_child_nodes(node):
                    if isinstance(child, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                        continue   # nested defs run later, not here
                    if isinstance(child, ast.With):
                        acquired: List[Tuple[str, int]] = []
                        for item in child.items:
                            name = _resolve(item.context_expr, cls, table)
                            if name is None:
                                continue
                            for outer, _ in held + acquired:
                                if outer != name and not \
                                        mod.is_suppressed(child.lineno):
                                    edges.append((
                                        outer, name, mod.relpath,
                                        mod.path, child.lineno, qual))
                            acquired.append((name, child.lineno))
                        walk(child, held + acquired)
                    else:
                        walk(child, held)

            walk(fn, [])
    ctx.lock_edges = edges   # type: ignore[attr-defined]


def _find_cycles(edges: List[Edge]) -> List[List[str]]:
    adj: Dict[str, Set[str]] = {}
    for a, b, *_ in edges:
        adj.setdefault(a, set()).add(b)
    cycles: List[List[str]] = []
    seen_sets = set()
    for start in adj:
        stack = [(start, [start])]
        while stack:
            node, path = stack.pop()
            for nxt in adj.get(node, ()):
                if nxt == start:
                    key = frozenset(path)
                    if key not in seen_sets:
                        seen_sets.add(key)
                        cycles.append(path + [start])
                elif nxt not in path:
                    stack.append((nxt, path + [nxt]))
    return cycles


def rule_lock_order(mod: ModuleInfo, ctx: ProjectContext,
                    ) -> Iterable[Finding]:
    edges: List[Edge] = getattr(ctx, "lock_edges", [])
    out: List[Finding] = []
    mine = [e for e in edges if e[2] == mod.relpath]
    for outer, inner, _rel, path, line, qual in mine:
        ro, ri = rank_of(outer), rank_of(inner)
        if ro is not None and ri is not None and ri < ro:
            out.append(Finding(
                path=path, relpath=mod.relpath, rule="lock-order",
                line=line, qualname=qual,
                detail=f"inversion:{outer}->{inner}",
                message=(f"acquires {inner!r} (rank {ri}) while holding "
                         f"{outer!r} (rank {ro}); ORDER.md ranks "
                         f"{inner!r} as the outer lock — invert the "
                         "nesting or update ORDER.md"),
            ))
    # report each global cycle once, on the module owning its first edge
    for cycle in _find_cycles(edges):
        pairs = list(zip(cycle, cycle[1:]))
        sites = [e for e in edges if (e[0], e[1]) in pairs]
        if not sites:
            continue
        first = min(sites, key=lambda e: (e[2], e[4]))
        if first[2] != mod.relpath:
            continue
        out.append(Finding(
            path=first[3], relpath=mod.relpath, rule="lock-order",
            line=first[4], qualname=first[5],
            detail="cycle:" + "->".join(sorted(set(cycle))),
            message=("lock acquisition cycle "
                     f"{' -> '.join(cycle)} — two threads entering "
                     "from different points deadlock"),
        ))
    return out
