"""blocking-call-in-behavior: no sleeping/joining inside actor code.

An actor behavior runs on a scheduler worker (or a drain loop borrowed
from the sender via ``try_call_inline``); blocking it stalls every
message behind it and — as PR 8's heartbeat hang showed — can wedge
shutdown entirely when the blocked call never wakes to observe the
closed flag. The enforced style is event-driven waiting
(``Event.wait(timeout)``, future callbacks via ``add_done_callback``),
never ``time.sleep``, ``Future.result()``, or a synchronous
``ref.ask()`` from inside a behavior.

What counts as a *behavior* (the places this rule looks inside):

* functions passed positionally to ``spawn`` / ``spawn_remote`` /
  ``spawn_pool`` (either a name bound to a ``def`` in the same module,
  or an inline ``lambda``),
* ``receive`` methods of classes whose base-class name contains
  ``Actor``,
* inner functions returned by ``make_*`` behavior factories,
* ``threading.Thread(target=...)`` targets — runtime service loops
  share the same contract: they must wake up for shutdown.

Suppress a deliberate block with ``# lint: <reason>`` on the call line.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set

from ..lint import Finding, ModuleInfo, ProjectContext

_SPAWNERS = {"spawn", "spawn_remote", "spawn_pool"}


def _callee_name(func: ast.expr) -> str:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return ""


def _collect_defs(tree: ast.Module) -> Dict[str, List[ast.AST]]:
    """Every def/lambda-bound name in the module (all scopes — a lint
    resolves names by best effort, not full scoping)."""
    defs: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)
        elif isinstance(node, ast.Assign) and \
                isinstance(node.value, ast.Lambda):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    defs.setdefault(tgt.id, []).append(node.value)
    return defs


def _behavior_nodes(mod: ModuleInfo) -> Dict[ast.AST, str]:
    """AST nodes (FunctionDef or Lambda) that are actor behaviors,
    mapped to the reason they qualify."""
    defs = _collect_defs(mod.tree)
    behaviors: Dict[ast.AST, str] = {}

    def mark_name(name: str, why: str) -> None:
        for d in defs.get(name, ()):
            behaviors.setdefault(d, why)

    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            callee = _callee_name(node.func)
            if callee in _SPAWNERS:
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        mark_name(arg.id, f"passed to {callee}()")
                    elif isinstance(arg, ast.Lambda):
                        behaviors.setdefault(arg, f"passed to {callee}()")
            elif callee == "Thread":
                for kw in node.keywords:
                    if kw.arg != "target":
                        continue
                    v = kw.value
                    if isinstance(v, ast.Name):
                        mark_name(v.id, "Thread target")
                    elif isinstance(v, ast.Attribute):
                        mark_name(v.attr, "Thread target")
                    elif isinstance(v, ast.Lambda):
                        behaviors.setdefault(v, "Thread target")
        elif isinstance(node, ast.ClassDef):
            if any("Actor" in _callee_name(b) for b in node.bases):
                for item in node.body:
                    if isinstance(item, ast.FunctionDef) and \
                            item.name == "receive":
                        behaviors.setdefault(
                            item, f"{node.name}.receive behavior")
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name.startswith("make_"):
            returned: Set[str] = set()
            for sub in ast.walk(node):
                if isinstance(sub, ast.Return):
                    if isinstance(sub.value, ast.Name):
                        returned.add(sub.value.id)
                    elif isinstance(sub.value, ast.Lambda):
                        behaviors.setdefault(
                            sub.value, f"returned by factory {node.name}()")
            for item in ast.walk(node):
                if isinstance(item, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) and \
                        item is not node and item.name in returned:
                    behaviors.setdefault(
                        item, f"returned by factory {node.name}()")
    return behaviors


def _blocking_pattern(call: ast.Call) -> str:
    """'' or the stable pattern name of a blocking call."""
    f = call.func
    if isinstance(f, ast.Attribute):
        if f.attr == "sleep" and isinstance(f.value, ast.Name) and \
                f.value.id == "time":
            return "time.sleep"
        if f.attr == "result":
            return ".result()"
        if f.attr == "ask":
            return ".ask()"
    elif isinstance(f, ast.Name) and f.id == "sleep":
        return "time.sleep"
    return ""


def rule_blocking_call(mod: ModuleInfo, ctx: ProjectContext,
                       ) -> Iterable[Finding]:
    out: List[Finding] = []
    for fn, why in _behavior_nodes(mod).items():
        fn_name = getattr(fn, "name", "<lambda>")
        if mod.is_suppressed(fn.lineno):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            pattern = _blocking_pattern(node)
            if not pattern:
                continue
            if mod.is_suppressed(node.lineno):
                continue
            qual = mod.qualname_of(fn)
            if qual == "<module>":
                qual = fn_name
            out.append(Finding(
                path=mod.path, relpath=mod.relpath,
                rule="blocking-call-in-behavior",
                line=node.lineno, qualname=qual,
                detail=pattern,
                message=(f"`{pattern}` inside {fn_name!r} ({why}) blocks "
                         "the scheduler thread running this behavior — "
                         "use Event.wait(timeout)/add_done_callback, or "
                         "tag with `# lint: <reason>` if deliberate"),
            ))
    return out
