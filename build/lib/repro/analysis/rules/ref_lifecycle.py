"""ref-lifecycle: DeviceRef ownership bugs, linearly approximated.

DeviceRefs are linear-ish resources: ``donate()`` and ``release()`` end
a name's ownership, ``emit="ref"`` replies transfer it to the caller,
and pickling device-resident payloads silently drags arrays through
host memory unless they were ``spill()``-ed first. The shed-path cache
leak (PR 6) and the speculative-loser reclaim both came from exactly
these shapes.

The rule tracks, per function, names bound to ref-creating
expressions — ``DeviceRef(...)``, ``DeviceRef.put(...)``,
``x.restrict(...)``, ``x.spill_copy(...)``, ``tree_wrap(...)``, and
``w.ask(...)`` where ``w`` was spawned with ``emit="ref"`` in the same
function — then applies a *linear per-block* approximation (each
statement list is scanned in order; branches are independent; no
inter-procedural flow):

* **use-after-donate / use-after-release** — a name is read after a
  statement-level ``name.donate()`` / ``name.release()`` in the same
  block, without an intervening rebinding. Includes double release.
* **unreleased-ref** — a ref-bound name that is *never used again* in
  the function: not released, donated, spilled, returned, yielded,
  passed anywhere, stored anywhere. Dropping a live ref on the floor
  leans on the GC finalizer for device memory — make the release
  explicit or route it through ``tree_release``.
* **pickle-without-spill** — ``pickle.dumps(name)`` / ``dump(name,…)``
  on a tracked ref with no ``name.spill()`` earlier in the block.

False-positive escape hatch as everywhere: ``# lint: <reason>`` on the
flagged line.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set

from ..lint import Finding, ModuleInfo, ProjectContext

_CREATORS = {"tree_wrap"}
_METHOD_CREATORS = {"restrict", "spill_copy", "put"}
_ENDERS = {"donate", "release"}


def _is_ref_creator(call: ast.Call, emit_ref_actors: Set[str]) -> bool:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id == "DeviceRef" or f.id in _CREATORS
    if isinstance(f, ast.Attribute):
        if f.attr in _METHOD_CREATORS:
            # DeviceRef.put / ref.restrict / ref.spill_copy
            return True
        if f.attr == "ask" and isinstance(f.value, ast.Name) and \
                f.value.id in emit_ref_actors:
            return True
    return False


def _spawn_emits_ref(call: ast.Call) -> bool:
    if not isinstance(call.func, (ast.Name, ast.Attribute)):
        return False
    name = call.func.id if isinstance(call.func, ast.Name) else \
        call.func.attr
    if name not in ("spawn", "spawn_remote", "spawn_pool"):
        return False
    for kw in call.keywords:
        if kw.arg == "emit" and isinstance(kw.value, ast.Constant) and \
                kw.value.value == "ref":
            return True
    return False


def _names_loaded(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


def _assigned_names(stmt: ast.stmt) -> Set[str]:
    out: Set[str] = set()
    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            if isinstance(t, ast.Name):
                out.add(t.id)
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)) and \
            isinstance(stmt.target, ast.Name):
        out.add(stmt.target.id)
    elif isinstance(stmt, (ast.For, ast.AsyncFor)) and \
            isinstance(stmt.target, ast.Name):
        out.add(stmt.target.id)
    return out


def _stmt_blocks(fn: ast.AST) -> Iterable[List[ast.stmt]]:
    """Every statement list in ``fn`` (function body, if/else arms,
    loop bodies, with bodies, handlers) — each analyzed independently."""
    for node in ast.walk(fn):
        for attr in ("body", "orelse", "finalbody"):
            block = getattr(node, attr, None)
            if isinstance(block, list) and block and \
                    isinstance(block[0], ast.stmt):
                yield block


def _method_call_on(stmt: ast.stmt, methods: Set[str]):
    """(name, method) when ``stmt`` is exactly ``name.method(...)``."""
    if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
        f = stmt.value.func
        if isinstance(f, ast.Attribute) and f.attr in methods and \
                isinstance(f.value, ast.Name):
            return f.value.id, f.attr
    return None


def _escapes(fn: ast.AST, name: str) -> bool:
    """Whether ``name`` is consumed, transferred, or stored anywhere in
    ``fn`` — conservatively broad, so unreleased-ref only fires on refs
    that are bound and then *never mentioned again*."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and node.id == name and \
                isinstance(node.ctx, ast.Load):
            return True
    return False


def rule_ref_lifecycle(mod: ModuleInfo, ctx: ProjectContext,
                       ) -> Iterable[Finding]:
    out: List[Finding] = []
    funcs = [n for n in ast.walk(mod.tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in funcs:
        emit_ref_actors: Set[str] = set()
        ref_names: Dict[str, int] = {}   # name -> binding line
        # pass 1: what names hold refs / emit="ref" actor handles
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name):
                tgt = node.targets[0].id
                if _spawn_emits_ref(node.value):
                    emit_ref_actors.add(tgt)
                elif _is_ref_creator(node.value, emit_ref_actors):
                    ref_names.setdefault(tgt, node.lineno)
        if not ref_names:
            continue
        qual = mod.qualname_of(fn)

        # pass 2: linear per-block scan for ordering bugs
        for block in _stmt_blocks(fn):
            dead: Dict[str, str] = {}      # name -> how it died
            spilled: Set[str] = set()
            for stmt in block:
                ender = _method_call_on(stmt, _ENDERS)
                spill = _method_call_on(stmt, {"spill"})
                loads = _names_loaded(stmt)
                # uses *before* this statement's own kill takes effect
                for name, how in list(dead.items()):
                    if name in loads and not mod.is_suppressed(stmt.lineno):
                        out.append(Finding(
                            path=mod.path, relpath=mod.relpath,
                            rule="ref-lifecycle", line=stmt.lineno,
                            qualname=qual,
                            detail=f"use-after-{how}:{name}",
                            message=(f"ref {name!r} used after "
                                     f"`{name}.{how}()` — ownership "
                                     "already ended; the backing buffer "
                                     "may be reused or freed"),
                        ))
                        del dead[name]   # one report per death
                for name in _assigned_names(stmt):
                    dead.pop(name, None)
                    spilled.discard(name)
                if spill and spill[0] in ref_names:
                    spilled.add(spill[0])
                if ender and ender[0] in ref_names:
                    dead[ender[0]] = ender[1]
                # pickle-without-spill
                for node in ast.walk(stmt):
                    if not isinstance(node, ast.Call):
                        continue
                    f = node.func
                    is_pickle = (
                        isinstance(f, ast.Attribute) and
                        f.attr in ("dumps", "dump") and
                        isinstance(f.value, ast.Name) and
                        f.value.id == "pickle")
                    if not is_pickle or not node.args:
                        continue
                    arg = node.args[0]
                    if isinstance(arg, ast.Name) and \
                            arg.id in ref_names and \
                            arg.id not in spilled and \
                            not mod.is_suppressed(node.lineno):
                        out.append(Finding(
                            path=mod.path, relpath=mod.relpath,
                            rule="ref-lifecycle", line=node.lineno,
                            qualname=qual,
                            detail=f"pickle-without-spill:{arg.id}",
                            message=(f"pickling ref {arg.id!r} without a "
                                     f"preceding `{arg.id}.spill()` drags "
                                     "the device payload through host "
                                     "memory implicitly"),
                        ))

        # pass 3: refs bound and never mentioned again
        for name, lineno in ref_names.items():
            if _escapes(fn, name):
                continue
            if mod.is_suppressed(lineno):
                continue
            out.append(Finding(
                path=mod.path, relpath=mod.relpath,
                rule="ref-lifecycle", line=lineno, qualname=qual,
                detail=f"unreleased-ref:{name}",
                message=(f"ref {name!r} is created and never used, "
                         "released, or donated — device memory is held "
                         "until the GC finalizer runs; release it "
                         "explicitly or drop the binding"),
            ))
    return out
