"""silent-except: broad handlers that swallow errors without a trace.

Flags ``except:``, ``except Exception:`` and ``except BaseException:``
handlers whose body does nothing but ``pass``/``continue``/``...`` —
the pattern that hid real faults in the net broker and reader threads
(a decode error, a half-closed socket, a failed scale action) until
someone attached a debugger. A handler stops being silent the moment it
logs, re-raises, counts, or annotates; a handler that *must* stay
silent gets a ``# lint: <reason>`` tag on the ``except`` line so the
justification lives next to the code.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from ..lint import Finding, ModuleInfo, ProjectContext

_BROAD = ("Exception", "BaseException")


def _handler_kind(h: ast.ExceptHandler) -> str:
    """'bare', 'Exception', 'BaseException' for broad handlers; '' for
    narrow ones (which are allowed to be quiet — catching a specific
    exception is itself a statement of intent)."""
    if h.type is None:
        return "bare"
    t = h.type
    if isinstance(t, ast.Name) and t.id in _BROAD:
        return t.id
    if isinstance(t, ast.Attribute) and t.attr in _BROAD:
        return t.attr
    return ""


def _is_noop(stmt: ast.stmt) -> bool:
    if isinstance(stmt, (ast.Pass, ast.Continue)):
        return True
    if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
        return True   # docstring or bare `...`
    return False


def rule_silent_except(mod: ModuleInfo, ctx: ProjectContext,
                       ) -> Iterable[Finding]:
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        kind = _handler_kind(node)
        if not kind:
            continue
        if not all(_is_noop(s) for s in node.body):
            continue
        body_lines = [node.lineno] + [s.lineno for s in node.body]
        if mod.is_suppressed(*body_lines):
            continue
        out.append(Finding(
            path=mod.path, relpath=mod.relpath, rule="silent-except",
            line=node.lineno, qualname=mod.qualname_of(node),
            detail=kind,
            message=(f"broad `except {kind if kind != 'bare' else ''}"
                     f"{':' if kind == 'bare' else ':'}` swallows the "
                     "error with no log, counter, or re-raise — note it "
                     "somewhere observable or tag the line with "
                     "`# lint: <reason>`").replace("except :", "except:"),
        ))
    return out
