"""Rule registry for ``repro.analysis``.

A rule is ``rule(module: ModuleInfo, ctx: ProjectContext) ->
Iterable[Finding]``; register it in :data:`ALL_RULES` under its slug.
A *prepass* is ``prepass(ctx) -> None`` and runs once per lint
invocation before any rule, for cross-module fact gathering (the
lock-order rule uses one to harvest lock names and nesting edges from
every module before judging any single one).
"""
from __future__ import annotations

from .blocking_call import rule_blocking_call
from .lock_order import prepass_lock_order, rule_lock_order
from .ref_lifecycle import rule_ref_lifecycle
from .silent_except import rule_silent_except

ALL_RULES = {
    "ref-lifecycle": rule_ref_lifecycle,
    "blocking-call-in-behavior": rule_blocking_call,
    "silent-except": rule_silent_except,
    "lock-order": rule_lock_order,
}

PREPASSES = [prepass_lock_order]

__all__ = ["ALL_RULES", "PREPASSES"]
