"""The canonical cross-module lock order, parsed from ``ORDER.md``.

``ORDER.md`` (next to this module) is the single source of truth; this
module turns its numbered list into :data:`CANONICAL_LOCK_ORDER` so the
static ``lock-order`` lint rule and the dynamic
:class:`~repro.analysis.runtime.TrackedLock` consume one artifact —
editing the doc edits the checked policy, and drift between the two is
structurally impossible.
"""
from __future__ import annotations

import os
import re
from typing import Dict, List, Optional

__all__ = ["CANONICAL_LOCK_ORDER", "LOCK_RANKS", "rank_of", "order_path"]

_ITEM_RE = re.compile(r"^\s*\d+\.\s+`([A-Za-z_][A-Za-z0-9_.]*)`")


def order_path() -> str:
    """Absolute path of the ORDER.md this process is enforcing."""
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "ORDER.md")


def _parse(path: str) -> List[str]:
    # A missing ORDER.md (e.g. an install that dropped package data)
    # degrades to an empty ranking — every lock is unranked, the rank
    # check is a no-op, and the package stays importable. A present but
    # unparseable ORDER.md is a config error and still raises.
    if not os.path.exists(path):
        return []
    names: List[str] = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            m = _ITEM_RE.match(line)
            if m and m.group(1) not in names:
                names.append(m.group(1))
    if not names:
        raise RuntimeError(
            f"no lock-order entries parsed from {path}; ORDER.md must "
            "contain a numbered list of `LockName` items")
    return names


#: lock names, outermost first — acquiring ``CANONICAL_LOCK_ORDER[i]``
#: while holding ``CANONICAL_LOCK_ORDER[j]`` requires ``j < i``
CANONICAL_LOCK_ORDER: List[str] = _parse(order_path())

#: name → rank (0 = outermost); names absent from ORDER.md are unranked
LOCK_RANKS: Dict[str, int] = {n: i for i, n in
                              enumerate(CANONICAL_LOCK_ORDER)}


def rank_of(name: Optional[str]) -> Optional[int]:
    """The canonical rank of ``name`` (None when unnamed/unranked)."""
    if name is None:
        return None
    return LOCK_RANKS.get(name)
