"""Cross-node transport cost (ISSUE 5).

Measures what distribution actually costs on this runtime:

* **stage hop latency** — one ``ask`` through a local actor vs. the same
  behavior behind a :class:`~repro.net.RemoteActorRef` (two in-process
  nodes over a localhost socket, so the delta is the wire path: encode/
  spill, framing, broker dispatch, unspill/decode — no network in the
  way);
* **wire bytes** — a spilled float32 activation raw vs. int8-compressed
  (:func:`repro.dist.collectives.quantize_ref` wire format), per payload
  size.

Writes ``BENCH_PR5.json`` at the repo root so PR-over-PR transport
trajectories are diffable.

    PYTHONPATH=src python -m benchmarks.bench_net
"""
from __future__ import annotations

import json
import pathlib
import platform
import time

import numpy as np

from .common import emit, timeit

_SIZES = (1 << 10, 1 << 14, 1 << 18)   # float32 elements per activation
_ROWS: dict = {}


def run() -> None:
    from repro.core import ActorSystem, DeviceRef
    from repro.net import NodeRuntime, wire

    sa = ActorSystem("bench-a", max_workers=4)
    sb = ActorSystem("bench-b", max_workers=4)
    na = NodeRuntime(sa, name="a", listen=("127.0.0.1", 0))
    nb = NodeRuntime(sb, name="b")
    nb.connect(na.address)
    na.wait_for_peer("b", 30)
    try:
        # -- hop latency ---------------------------------------------------
        def inc_ref(ref):
            return DeviceRef(ref.array + 1)

        local = sa.spawn(inc_ref)
        nb.publish("inc", sb.spawn(inc_ref))
        remote = na.remote_actor("b", "inc")

        for n in _SIZES:
            x = np.random.RandomState(0).randn(n).astype(np.float32)
            payload = DeviceRef.put(x)
            t_local = timeit(lambda: local.ask(payload), repeat=20)
            t_remote = timeit(lambda: remote.ask(payload), repeat=20)
            emit(f"hop_local_n{n}", t_local * 1e6)
            emit(f"hop_remote_n{n}", t_remote * 1e6,
                 f"x{t_remote / max(t_local, 1e-9):.1f} vs local")
            raw = wire.encoded_size((payload,))
            comp = wire.encoded_size((payload,), compress=True)
            emit(f"wire_raw_bytes_n{n}", raw, "bytes")
            emit(f"wire_int8_bytes_n{n}", comp,
                 f"{raw / comp:.2f}x smaller")
            _ROWS[f"n{n}"] = {
                "local_hop_us": round(t_local * 1e6, 1),
                "remote_hop_us": round(t_remote * 1e6, 1),
                "wire_raw_bytes": raw,
                "wire_int8_bytes": comp,
                "compression_ratio": round(raw / comp, 2),
            }
    finally:
        na.shutdown()
        nb.shutdown()
        sa.shutdown()
        sb.shutdown()
    _write_snapshot()


def _write_snapshot() -> None:
    import jax

    snap = {
        "pr": 5,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "workload": {
            "hop": "ask(DeviceRef[float32 n]) -> DeviceRef, localhost "
                   "socket pair, in-process nodes",
            "sizes": list(_SIZES),
        },
        "sizes": _ROWS,
    }
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_PR5.json"
    out.write_text(json.dumps(snap, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
