"""Monolithic vs paged serving under mixed prefill/decode load (ISSUE 6).

The workload interleaves a steady stream of short-prompt/short-decode
requests with long prompts whose prefill costs real wall-clock time
(simulated by a sleep in the prefill path). The monolithic engine runs
``init_fn`` inline in the decode loop, so every long prefill stalls all
in-flight decodes; the paged engine runs prefills on a dedicated worker
pool and hands page tables to decode by ref handoff, so decode batches
stay full. Long prompts repeat across a few unique values, so the paged
pool's prefix cache also demonstrates exactly-once page allocation.

Reported per engine: decode-batch occupancy (filled batch slots / steps ×
max_batch), the worst inter-step stall, latency percentiles, throughput,
and the DeviceRef host-traffic deltas (the paged prefill→decode handoff
must be zero-transfer). Written to ``BENCH_PR6.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.bench_kvpool
"""
from __future__ import annotations

import json
import pathlib
import platform
import time

from .common import emit

MOD = 997
_MAX_BATCH = 4
_DECODE_WORKERS = 2
_PREFILL_WORKERS = 4
_SHORTS = 24              # short requests: prompt 4 tokens, decode 24
_SHORT_STEPS = 24
_LONGS = 8                # long requests: prompt 96 tokens, decode 8
_LONG_STEPS = 8
_UNIQUE_LONGS = 2         # longs repeat → prefix sharing
_PREFILL_SLEEP_S = 0.2    # simulated prefill cost for a long prompt
_LONG_LEN = 96
_CAPACITY = 128           # monolithic per-request cache slots
_ROWS: list = []


def _prompts():
    import numpy as np
    rng = np.random.default_rng(6)
    uniques = [rng.integers(0, MOD, size=_LONG_LEN).tolist()
               for _ in range(_UNIQUE_LONGS)]
    shorts = [rng.integers(0, MOD, size=4).tolist() for _ in range(_SHORTS)]
    longs = [uniques[i % _UNIQUE_LONGS] for i in range(_LONGS)]
    # interleave: a long arrives amid every few shorts, so prefill cost
    # lands while decodes are active
    out = []
    li = 0
    for i, p in enumerate(shorts):
        out.append((p, _SHORT_STEPS))
        if i % 3 == 2 and li < len(longs):
            out.append((longs[li], _LONG_STEPS))
            li += 1
    while li < len(longs):
        out.append((longs[li], _LONG_STEPS))
        li += 1
    return out


def _simulate(prompt, steps):
    h = list(prompt)
    last = sum(prompt) % MOD
    out = []
    for _ in range(steps):
        nxt = (sum(h) + last) % MOD
        out.append(nxt)
        h.append(nxt)
        last = nxt
    return out


def _is_long(prompt) -> bool:
    return len(prompt) >= _LONG_LEN


def _monolithic_engine(system):
    import jax.numpy as jnp
    import numpy as np

    from repro.serve import ServeEngine

    def init_fn(prompt):
        if _is_long(prompt):
            time.sleep(_PREFILL_SLEEP_S)   # prefill cost, inline in the loop
        n = len(prompt)
        kv = jnp.zeros((_CAPACITY, 1), jnp.float32)
        kv = kv.at[:n, 0].set(jnp.asarray(np.asarray(prompt, np.float32)))
        return (kv, jnp.int32(n)), int(sum(prompt) % MOD)

    def step_fn(cache, tokens):
        kv, lengths = cache                # [B, C, 1], [B]
        mask = (jnp.arange(_CAPACITY)[None, :]
                < lengths[:, None]).astype(kv.dtype)
        s = jnp.sum(kv[..., 0] * mask, axis=1)
        nxt = (s.astype(jnp.int32) + tokens) % MOD
        kv = kv.at[jnp.arange(kv.shape[0]), lengths, 0].set(
            nxt.astype(jnp.float32))
        return nxt, (kv, lengths + 1)

    return ServeEngine(system, step_fn, init_fn, n_workers=_DECODE_WORKERS,
                       max_batch=_MAX_BATCH, step_timeout=120.0)


def _paged_engine(system):
    import jax.numpy as jnp
    import numpy as np

    from repro.serve import PagePool, ServeEngine

    pool = PagePool([((1,), jnp.float32)], page_tokens=16, max_pages=256)

    def prefill_fn(prompt):
        if _is_long(prompt):
            time.sleep(_PREFILL_SLEEP_S)   # same cost, off the decode loop
        arr = jnp.asarray(np.asarray(prompt, np.float32)).reshape(-1, 1)
        return [arr], int(sum(prompt) % MOD)

    def step_fn(kv, lengths, tokens):
        k = kv[0]                          # [B, T, 1]
        T = k.shape[1]
        mask = (jnp.arange(T)[None, :] < lengths[:, None]).astype(k.dtype)
        s = jnp.sum(k[..., 0] * mask, axis=1)
        nxt = (s.astype(jnp.int32) + tokens) % MOD
        return nxt, [nxt.astype(jnp.float32)[:, None]]

    engine = ServeEngine(system, step_fn=step_fn, cache_pool=pool,
                         prefill_fn=prefill_fn,
                         prefill_workers=_PREFILL_WORKERS,
                         n_workers=_DECODE_WORKERS, max_batch=_MAX_BATCH,
                         step_timeout=120.0)
    return engine, pool


def _drive(engine, workload) -> dict:
    from repro.core import memory_stats

    before = memory_stats()
    t0 = time.perf_counter()
    futures = []
    with engine:
        for prompt, steps in workload:
            futures.append((prompt, steps,
                            engine.submit(prompt, max_new_tokens=steps)))
        results = [(p, s, f.result(timeout=600)) for p, s, f in futures]
    wall = time.perf_counter() - t0
    for prompt, steps, res in results:
        exp = _simulate(prompt, steps)
        assert res.tokens == exp, "decode mismatch — benchmark invalid"
    after = memory_stats()
    stats = engine.stats()
    toks = sum(s for _, s in workload)
    lat = stats["latency"]
    return {
        "wall_s": round(wall, 3),
        "tokens_per_s": round(toks / wall, 1),
        "occupancy": round(stats["occupancy"], 3),
        "max_step_gap_ms": round(stats["max_step_gap_ms"], 1),
        "steps": stats["steps"],
        "p50_ms": round(lat["p50_ms"], 2),
        "p99_ms": round(lat["p99_ms"], 2),
        "transfers": after["transfers"] - before["transfers"],
        "readbacks": after["readbacks"] - before["readbacks"],
        "spills": after["spills"] - before["spills"],
    }


def run() -> None:
    from repro.core import ActorSystem

    workload = _prompts()
    with ActorSystem(name="bench-kvpool", max_workers=16) as system:
        mono = _monolithic_engine(system)
        row_m = _drive(mono, workload)
        row_m["engine"] = "monolithic"
        _ROWS.append(row_m)

        engine, pool = _paged_engine(system)
        row_p = _drive(engine, workload)
        row_p["engine"] = "paged"
        estats = engine.stats()
        pstats = estats["pool"]
        row_p["prefix_hits"] = pstats["prefix_hits"]
        row_p["pages_allocated"] = pstats["allocated"]
        row_p["cow_pages"] = pstats["cow"]
        row_p["prefill_dispatch_failed"] = estats["prefill_dispatch"]["failed"]
        _ROWS.append(row_p)

        # acceptance: zero host transfers on the prefill→decode handoff
        assert row_p["transfers"] == 0 and row_p["spills"] == 0, \
            "paged handoff must be transfer-free"
        # acceptance: decode batches stay full despite the long prefills
        assert row_p["occupancy"] >= 0.8, \
            f"paged occupancy {row_p['occupancy']} < 0.8"
        # acceptance: every repeated long prompt mapped the cached pages —
        # shared-prefix pages were allocated exactly once
        assert pstats["prefix_hits"] >= _LONGS - _UNIQUE_LONGS, \
            "repeated long prompts should hit the prefix cache"
        pool.evict_prefixes()

    emit("kvpool_mono_stall", row_m["max_step_gap_ms"] * 1e3,
         f"occupancy={row_m['occupancy']}")
    emit("kvpool_paged_stall", row_p["max_step_gap_ms"] * 1e3,
         f"occupancy={row_p['occupancy']} "
         f"prefix_hits={row_p['prefix_hits']}")
    _write_snapshot()


def _write_snapshot() -> None:
    import jax

    from repro.core import memory_stats

    snap = {
        "pr": 6,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "workload": {
            "shorts": _SHORTS, "short_steps": _SHORT_STEPS,
            "longs": _LONGS, "long_steps": _LONG_STEPS,
            "unique_longs": _UNIQUE_LONGS, "long_len": _LONG_LEN,
            "prefill_sleep_s": _PREFILL_SLEEP_S,
            "max_batch": _MAX_BATCH, "decode_workers": _DECODE_WORKERS,
            "prefill_workers": _PREFILL_WORKERS,
        },
        "engines": _ROWS,
        "memref": memory_stats(),
    }
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_PR6.json"
    out.write_text(json.dumps(snap, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    run()
