"""Dataflow-graph composition benchmark (ISSUE 4): the acceptance diamond
(source → broadcast(2) → two kernel branches → zip_join → sink) run three
ways —

* ``host_roundtrip`` — every node is a standalone value-semantics actor
  and the fan-out/fan-in is orchestrated on the host: each edge pays a
  device→host read-back and a host→device upload;
* ``graph_staged``   — the same topology built with ``repro.core.Graph``:
  interior edges are lowered to ref-emitting actors, so the only host
  traffic is the final read-back;
* ``graph_mapped``   — the staged diamond with the two branches fanned
  out per-chunk through ``map_over`` (ChunkScheduler over a 2-replica
  pool each).

Besides wall time, the RefRegistry host-transfer counters for one run of
each variant are recorded — the headline number the PR-over-PR snapshot
(``BENCH_PR4.json``) tracks.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (ActorSystem, Graph, In, NDRange, Out, dim_vec,
                        kernel, memory_stats, reset_transfer_stats)

from .common import emit, timeit

_N = 512
RESULTS: dict = {}


@kernel(In(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(_N, _N)), name="g_left")
def _left(x):
    return x @ x


@kernel(In(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(_N, _N)), name="g_right")
def _right(x):
    return x * 2.0 + 1.0


@kernel(In(jnp.float32), In(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(_N, _N)), name="g_sink")
def _sink(a, b):
    return a + b


@kernel(In(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(_N, _N)), name="g_row")
def _row(x):
    return x * 2.0 + 1.0


def _traffic(fn) -> dict:
    reset_transfer_stats()
    fn()
    stats = memory_stats()
    return {"transfers": stats["transfers"], "readbacks": stats["readbacks"]}


def run() -> None:
    rng = np.random.default_rng(0)
    x = (rng.random((_N, _N), np.float32) - 0.5) / _N

    with ActorSystem(max_workers=8) as system:
        # host-roundtrip baseline: standalone value actors, host fan-in
        left_w = system.spawn(_left)
        right_w = system.spawn(_right)
        sink_w = system.spawn(_sink)

        def host_roundtrip():
            fl = left_w.request(x)
            fr = right_w.request(x)
            return sink_w.ask(fl.result(60), fr.result(60))

        def build_diamond(name, mapped: bool) -> "Graph":
            g = Graph(system, name=name)
            s = g.source("x", jnp.float32, shape=(_N, _N))
            l, r = g.broadcast(s, 2)
            if mapped:
                # chunk the element-wise branch only: a matmul is not
                # row-separable, mixing whole-node and chunked nodes is
                # exactly what the DAG builder allows
                bl = g.apply(_left, l)
                br = g.map_over(_row, r, chunks=4, replicas=2)
            else:
                bl, br = g.apply(_left, l), g.apply(_right, r)
            j1, j2 = g.zip_join(bl, br)
            g.output(g.apply(_sink, j1, j2))
            return g

        staged = build_diamond("bench_diamond", mapped=False).build()
        mapped = build_diamond("bench_diamond_map", mapped=True).build()

        want = host_roundtrip()
        np.testing.assert_allclose(staged.ask(x), want, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(mapped.ask(x), want, rtol=1e-4, atol=1e-5)

        variants = {
            "diamond_host_roundtrip": host_roundtrip,
            "diamond_graph_staged": lambda: staged.ask(x),
            "diamond_graph_mapped": lambda: mapped.ask(x),
        }
        for name, fn in variants.items():
            t = timeit(fn, repeat=7, warmup=2)
            traffic = _traffic(fn)
            emit(f"graph/{name}", t * 1e6,
                 f"transfers={traffic['transfers']} "
                 f"readbacks={traffic['readbacks']}")
            RESULTS[name] = {"us_per_call": round(t * 1e6, 1), **traffic}
    _write_snapshot()


def _write_snapshot() -> None:
    import json
    import pathlib
    import platform
    import time

    import jax

    snap = {
        "pr": 4,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "workload": {"n": _N, "shape": "diamond(source, broadcast, "
                     "2 branches, zip_join, sink)"},
        "variants": RESULTS,
    }
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_PR4.json"
    out.write_text(json.dumps(snap, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":  # pragma: no cover - manual invocation
    run()
