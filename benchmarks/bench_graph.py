"""Dataflow-graph composition benchmark (ISSUE 4 diamond + ISSUE 7 fusion).

Two workloads, each measured against a hand-written ``jax.jit`` native
baseline so the actor layer's overhead is a dimensionless ratio
(``overhead_vs_native``, the headline number of ``BENCH_PR7.json``):

**diamond** — the acceptance diamond (source → broadcast(2) → matmul /
elementwise branches → zip_join → sink), run as:

* ``host_roundtrip`` — standalone value-semantics actors, host fan-in:
  every edge pays a device→host read-back and a host→device upload;
* ``graph_staged``   — ``repro.core.Graph``: interior edges are
  ref-emitting, the only host traffic is the final read-back;
* ``graph_fused``    — the same math with the elementwise branch split
  into two kernels and ``build(fuse=True)``: the fusion pass collapses
  the branch into one jitted actor (one region, one dispatch);
* ``graph_mapped``   — the elementwise branch fanned out per-chunk via
  ``map_over``. With the default ``min_chunk_bytes`` (1 MiB) the
  512×512 f32 operand (exactly 1 MiB) stays whole — the PR 4 snapshot
  showed chunking it 4-ways cost ~6.4 ms of pure per-chunk dispatch
  constant (~300 µs × chunks × stages) for zero parallel win;
* ``graph_mapped_forced`` — ``min_chunk_bytes=0`` re-enables the
  4-way split so the regression stays measurable on purpose;
* ``native_jit``     — ``jax.jit`` of the whole composite + device_get.

**chain** — a 4-stage matmul chain (each stage ``x @ x * 0.5 + x``),
run staged (one actor per stage, ref edges), fused (one region → one
actor, inline-dispatched), and native. The fused chain is the ISSUE 7
acceptance workload: ``overhead_vs_native`` must stay ≤ 1.10.

``--smoke`` (or ``run(smoke=True)``) does a 1-warmup/3-rep pass and
asserts the ratios are finite and fused ≤ staged — cheap enough for CI;
the JSON snapshot is only written by full runs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ActorSystem, Graph, In, NDRange, Out, dim_vec,
                        kernel, memory_stats, reset_transfer_stats)

from .common import emit, timeit

_N = 512
_CHAIN_STAGES = 4
RESULTS: dict = {}
RATIOS: dict = {}


@kernel(In(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(_N, _N)), name="g_left")
def _left(x):
    return x @ x


@kernel(In(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(_N, _N)), name="g_right")
def _right(x):
    return x * 2.0 + 1.0


@kernel(In(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(_N, _N)), name="g_mul2")
def _mul2(x):
    return x * 2.0


@kernel(In(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(_N, _N)), name="g_add1")
def _add1(x):
    return x + 1.0


@kernel(In(jnp.float32), In(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(_N, _N)), name="g_sink")
def _sink(a, b):
    return a + b


@kernel(In(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(_N, _N)), name="g_row")
def _row(x):
    return x * 2.0 + 1.0


@kernel(In(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(_N, _N)), name="g_step")
def _step(x):
    return x @ x * 0.5 + x


def _traffic(fn) -> dict:
    reset_transfer_stats()
    fn()
    stats = memory_stats()
    return {"transfers": stats["transfers"], "readbacks": stats["readbacks"]}


def _build_diamond(system, name, *, mapped=False, split_branch=False,
                   min_chunk_bytes=None) -> "Graph":
    g = Graph(system, name=name)
    s = g.source("x", jnp.float32, shape=(_N, _N))
    l, r = g.broadcast(s, 2)
    bl = g.apply(_left, l)
    if mapped:
        # chunk the element-wise branch only: a matmul is not
        # row-separable, mixing whole-node and chunked nodes is
        # exactly what the DAG builder allows
        kw = {} if min_chunk_bytes is None else {
            "min_chunk_bytes": min_chunk_bytes}
        br = g.map_over(_row, r, chunks=4, replicas=2, **kw)
    elif split_branch:
        # same math as _right, as two fusible kernels: the fusion pass
        # collapses them into one region → one actor, one dispatch
        br = g.apply(_add1, g.apply(_mul2, r))
    else:
        br = g.apply(_right, r)
    j1, j2 = g.zip_join(bl, br)
    g.output(g.apply(_sink, j1, j2))
    return g


def _build_chain(system, name) -> "Graph":
    g = Graph(system, name=name)
    cur = g.source("x", jnp.float32, shape=(_N, _N))
    for _ in range(_CHAIN_STAGES):
        cur = g.apply(_step, cur)
    g.output(cur)
    return g


def _measure(name, fn, *, repeat, warmup, ref=None):
    t = timeit(fn, repeat=repeat, warmup=warmup)
    traffic = _traffic(fn)
    emit(f"graph/{name}", t * 1e6,
         f"transfers={traffic['transfers']} "
         f"readbacks={traffic['readbacks']}")
    RESULTS[name] = {"us_per_call": round(t * 1e6, 1), **traffic}
    if ref is not None:
        np.testing.assert_allclose(fn(), ref, rtol=1e-4, atol=1e-5)
    return t


def run(smoke: bool = False) -> None:
    repeat, warmup = (3, 1) if smoke else (7, 2)
    rng = np.random.default_rng(0)
    x = (rng.random((_N, _N), np.float32) - 0.5) / _N

    with ActorSystem(max_workers=8) as system:
        # -- diamond -----------------------------------------------------
        left_w = system.spawn(_left)
        right_w = system.spawn(_right)
        sink_w = system.spawn(_sink)

        def host_roundtrip():
            fl = left_w.request(x)
            fr = right_w.request(x)
            return sink_w.ask(fl.result(60), fr.result(60))

        staged = _build_diamond(system, "bench_diamond").build()
        fused = _build_diamond(system, "bench_diamond_fuse",
                               split_branch=True).build(fuse=True)
        assert fused.plan.fused_regions == [
            ["bench_diamond_fuse/g_mul2", "bench_diamond_fuse/g_add1"]]
        mapped = _build_diamond(system, "bench_diamond_map",
                                mapped=True).build()
        forced = _build_diamond(system, "bench_diamond_map4", mapped=True,
                                min_chunk_bytes=0).build()

        native_diamond = jax.jit(lambda v: v @ v + (v * 2.0 + 1.0))
        native_diamond(x)  # compile outside the timed region

        want = np.asarray(jax.device_get(native_diamond(x)))
        t_native_d = _measure(
            "diamond_native_jit",
            lambda: jax.device_get(native_diamond(x)),
            repeat=repeat, warmup=warmup)
        _measure("diamond_host_roundtrip", host_roundtrip,
                 repeat=repeat, warmup=warmup, ref=want)
        t_staged_d = _measure("diamond_graph_staged", lambda: staged.ask(x),
                              repeat=repeat, warmup=warmup, ref=want)
        t_fused_d = _measure("diamond_graph_fused", lambda: fused.ask(x),
                             repeat=repeat, warmup=warmup, ref=want)
        _measure("diamond_graph_mapped", lambda: mapped.ask(x),
                 repeat=repeat, warmup=warmup, ref=want)
        _measure("diamond_graph_mapped_forced", lambda: forced.ask(x),
                 repeat=repeat, warmup=warmup, ref=want)

        RATIOS["diamond_staged"] = round(t_staged_d / t_native_d, 3)
        RATIOS["diamond_fused"] = round(t_fused_d / t_native_d, 3)

        # -- chain -------------------------------------------------------
        chain_staged = _build_chain(system, "bench_chain").build()
        chain_fused = _build_chain(system, "bench_chain_fuse").build(
            fuse=True)
        assert len(chain_fused.plan.fused_regions) == 1
        assert len(chain_fused.plan.fused_regions[0]) == _CHAIN_STAGES

        def _chain_math(v):
            for _ in range(_CHAIN_STAGES):
                v = v @ v * 0.5 + v
            return v
        native_chain = jax.jit(_chain_math)
        native_chain(x)
        want_c = np.asarray(jax.device_get(native_chain(x)))

        t_native_c = _measure(
            "chain_native_jit",
            lambda: jax.device_get(native_chain(x)),
            repeat=repeat, warmup=warmup)
        t_staged_c = _measure("chain_graph_staged",
                              lambda: chain_staged.ask(x),
                              repeat=repeat, warmup=warmup, ref=want_c)
        t_fused_c = _measure("chain_graph_fused",
                             lambda: chain_fused.ask(x),
                             repeat=repeat, warmup=warmup, ref=want_c)

        RATIOS["chain_staged"] = round(t_staged_c / t_native_c, 3)
        RATIOS["chain_fused"] = round(t_fused_c / t_native_c, 3)

        ds = chain_fused.dispatch_stats
        assert ds["inline"] > 0 and ds["mailbox"] == 0, \
            f"fused chain did not dispatch inline: {ds}"

    for k, v in RATIOS.items():
        emit(f"graph/overhead_vs_native[{k}]", 0.0, f"ratio={v}")

    if smoke:
        for k, v in RATIOS.items():
            assert math.isfinite(v) and v > 0, f"bad ratio {k}={v}"
        assert t_fused_c <= t_staged_c, (
            f"fused chain slower than staged: {t_fused_c*1e6:.0f}us > "
            f"{t_staged_c*1e6:.0f}us")
        print("smoke ok:", RATIOS)
    else:
        _write_snapshot()


def _write_snapshot() -> None:
    import json
    import pathlib
    import platform
    import time

    snap = {
        "pr": 7,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "workload": {
            "n": _N,
            "diamond": "source, broadcast, matmul/elementwise branches, "
                       "zip_join, sink",
            "chain": f"{_CHAIN_STAGES} stages of x @ x * 0.5 + x",
        },
        "overhead_vs_native": RATIOS,
        "variants": RESULTS,
    }
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_PR7.json"
    out.write_text(json.dumps(snap, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":  # pragma: no cover - manual invocation
    import sys
    run(smoke="--smoke" in sys.argv)
