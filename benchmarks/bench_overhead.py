"""Paper Fig. 5 — single-calculation overhead of the actor facade vs the
native API (here: a direct jitted call). The paper's claim: the difference
is milliseconds-scale and independent of problem size."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ActorSystem, In, NDRange, Out, dim_vec, kernel
from repro.kernels import ops

from .common import emit, timeit


def run() -> None:
    with ActorSystem(max_workers=4) as system:
        for n in (256, 512, 1024):
            a = np.random.default_rng(0).random((n, n), np.float32)
            b = np.random.default_rng(1).random((n, n), np.float32)

            native = jax.jit(lambda x, y: ops.ref.matmul(x, y))
            aj, bj = jnp.asarray(a), jnp.asarray(b)

            def native_call():
                native(aj, bj).block_until_ready()

            m_mult = kernel(In(jnp.float32), In(jnp.float32),
                            Out(jnp.float32, shape=(n, n)),
                            nd_range=NDRange(dim_vec(n, n)),
                            name=f"m_mult_{n}")(ops.ref.matmul)
            worker = system.spawn(m_mult)

            def actor_call():
                worker.ask(a, b)

            t_native = timeit(native_call, repeat=7)
            t_actor = timeit(actor_call, repeat=7)
            overhead_ms = (t_actor - t_native) * 1e3
            emit(f"overhead_matmul_{n}", t_actor * 1e6,
                 f"native_us={t_native * 1e6:.1f};overhead_ms={overhead_ms:.2f}")


if __name__ == "__main__":
    run()
