"""Serve-engine latency percentiles vs offered load (ISSUE 3).

Open-loop clients submit at a fixed request rate against the
continuous-batching :class:`~repro.serve.ServeEngine` (toy decode step, so
the numbers measure the *runtime*: batching, queueing, actor dispatch —
not model FLOPs). For each offered load we report p50/p95/p99 end-to-end
latency and the achieved throughput; the sweep is written to
``BENCH_PR3.json`` at the repo root so PR-over-PR serve-latency
trajectories are diffable.

    PYTHONPATH=src python -m benchmarks.bench_serve
"""
from __future__ import annotations

import json
import pathlib
import platform
import time

from .common import emit

_STEPS = 4            # tokens per request
_REQUESTS = 96        # per load level
_LOADS_RPS = (50, 200, 800)
_ROWS: list = []


def _toy_engine(system):
    import jax.numpy as jnp

    from repro.serve import ServeEngine

    def step(cache, tokens):
        nxt = (cache[:, 0] * 1000 + cache[:, 1]).astype(jnp.int32)
        return nxt, cache.at[:, 1].add(1)

    def init(prompt):
        return jnp.asarray([int(prompt), 0], jnp.int32), 0

    return ServeEngine(system, step, init, n_workers=2, max_batch=8,
                       max_wait_ms=2.0)


def _offered_load(system, rate_rps: float) -> dict:
    engine = _toy_engine(system)
    interval = 1.0 / rate_rps
    futures = []
    t0 = time.perf_counter()
    with engine:
        next_at = time.perf_counter()
        for seed in range(_REQUESTS):
            lag = next_at - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
            futures.append(engine.submit(seed, max_new_tokens=_STEPS))
            next_at += interval
        for f in futures:
            f.result(timeout=300)
    wall = time.perf_counter() - t0
    stats = engine.stats()
    lat = stats["latency"]
    return {
        "offered_rps": rate_rps,
        "achieved_rps": round(_REQUESTS / wall, 1),
        "p50_ms": round(lat["p50_ms"], 2),
        "p95_ms": round(lat["p95_ms"], 2),
        "p99_ms": round(lat["p99_ms"], 2),
        "engine_steps": stats["steps"],
        "peak_batch": stats["peak_batch"],
        "requeues": stats["requeues"],
        "shed": stats["shed"],
    }


def run() -> None:
    from repro.core import ActorSystem

    with ActorSystem(name="bench-serve", max_workers=8) as system:
        # warm the jit caches so the sweep measures steady-state latency
        warm = _toy_engine(system)
        with warm:
            for f in [warm.submit(s, max_new_tokens=2) for s in range(16)]:
                f.result(timeout=300)
        for rate in _LOADS_RPS:
            row = _offered_load(system, rate)
            _ROWS.append(row)
            emit(f"serve_p99@{rate}rps", row["p99_ms"] * 1e3,
                 f"p50={row['p50_ms']}ms achieved={row['achieved_rps']}rps")
    _write_snapshot()


def _write_snapshot() -> None:
    import jax

    from repro.core import memory_stats

    snap = {
        "pr": 3,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "workload": {"requests_per_load": _REQUESTS,
                     "tokens_per_request": _STEPS,
                     "max_batch": 8, "workers": 2},
        "loads": _ROWS,
        "memref": memory_stats(),
    }
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_PR3.json"
    out.write_text(json.dumps(snap, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    run()
