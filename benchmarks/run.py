# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper figure (3, 4, 5, 6, 7/8) plus
the roofline table from the dry-run artifacts."""
import sys


def main() -> None:
    print("name,us_per_call,derived")
    from . import (bench_indexing, bench_iterated, bench_offload,
                   bench_overhead, bench_spawn)
    for mod in (bench_spawn, bench_overhead, bench_iterated, bench_offload,
                bench_indexing):
        mod.run()
    print("\n== roofline table (from dry-run artifacts) ==")
    from . import roofline_table
    roofline_table.run()


if __name__ == '__main__':
    main()
