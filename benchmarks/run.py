# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper figure (3, 4, 5, 6, 7/8) plus
the roofline table from the dry-run artifacts. Writes a ``BENCH_PR2.json``
perf snapshot (rows + DeviceRef registry traffic counters) at the repo
root so PR-over-PR trajectories are diffable."""
import json
import pathlib
import platform
import sys
import time


def main() -> None:
    print("name,us_per_call,derived")
    from . import (bench_graph, bench_indexing, bench_iterated,
                   bench_kvpool, bench_mesh, bench_net, bench_offload,
                   bench_overhead, bench_placement, bench_serve,
                   bench_spawn)
    for mod in (bench_spawn, bench_overhead, bench_iterated, bench_offload,
                bench_indexing, bench_serve, bench_kvpool, bench_graph,
                bench_net, bench_mesh, bench_placement):
        mod.run()
    print("\n== roofline table (from dry-run artifacts) ==")
    from . import roofline_table
    roofline_table.run()
    _write_snapshot()


def _write_snapshot() -> None:
    import jax

    from repro.core import memory_stats

    from .common import ROWS

    snap = {
        "pr": 2,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "rows": [{"name": n, "us_per_call": round(us, 2), "derived": d}
                 for n, us, d in ROWS],
        "memref": memory_stats(),
    }
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_PR2.json"
    out.write_text(json.dumps(snap, indent=2) + "\n")
    print(f"\nwrote {out}")


if __name__ == '__main__':
    main()
