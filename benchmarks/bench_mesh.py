"""Serve-mesh failover sweep (ISSUE 8).

Runs the 3-process acceptance demo (:func:`repro.launch.serve_mesh
.run_demo`): a MeshRouter on the driver sharding an offered-load sweep
across engine replicas on two worker processes, with one worker
SIGKILLed mid-run. Reports achieved RPS and p99 latency before / during
/ after the failure window, asserts zero lost requests and ≥80% RPS
recovery, and writes the sweep to ``BENCH_PR8.json`` at the repo root.

Also here: the ISSUE 8 satellite micro-assert that a LatencyStats poll
against a full reservoir stays sub-millisecond — the router polls every
replica's stats each scheduling tick, so a per-poll re-sort of 100k
samples (the old behavior) would tax the control loop in proportion to
uptime.

    PYTHONPATH=src python -m benchmarks.bench_mesh
"""
from __future__ import annotations

import json
import pathlib
import platform
import time

from .common import emit

_RESULT: dict = {}


def _stats_poll_micro() -> float:
    """Per-poll cost (seconds) of summary()+percentile() on a full
    100k-sample reservoir. Must stay sub-millisecond."""
    from repro.serve import LatencyStats

    st = LatencyStats()
    for i in range(100_000):
        st.record((i % 977) * 1e-4)
    t0 = time.perf_counter()
    polls = 200
    for _ in range(polls):
        st.summary()
        st.percentile(99)
    per_poll = (time.perf_counter() - t0) / polls
    assert per_poll < 1e-3, \
        f"stats poll took {per_poll * 1e3:.2f}ms on a full reservoir"
    return per_poll


def run() -> None:
    from repro.launch.serve_mesh import run_demo

    per_poll = _stats_poll_micro()
    emit("mesh_stats_poll_full_reservoir", per_poll * 1e6,
         "sub-ms required")

    summary = run_demo(2, rps=40.0, duration_s=6.0, kill_at_s=2.0,
                       recover_window_s=1.5)
    _RESULT.update(summary)
    _RESULT["stats_poll_us"] = round(per_poll * 1e6, 2)
    pre, during, post = summary["windows"]
    emit("mesh_rps_pre_failure", pre["achieved_rps"],
         f"p99={pre['p99_ms']:.1f}ms")
    emit("mesh_rps_during_failure", during["achieved_rps"],
         f"p99={during['p99_ms']:.1f}ms "
         f"replayed={summary['replayed']}")
    emit("mesh_rps_post_failure", post["achieved_rps"],
         f"p99={post['p99_ms']:.1f}ms recovery="
         f"{post['achieved_rps'] / max(pre['achieved_rps'], 1e-9):.0%}")
    assert summary["lost"] == 0, summary
    _write_snapshot()


def _write_snapshot() -> None:
    import jax

    snap = {
        "pr": 8,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "workload": {"workers": 2, "offered_rps": _RESULT["offered_rps"],
                     "duration_s": _RESULT["duration_s"],
                     "kill_one": _RESULT["kill_one"]},
        "mesh": _RESULT,
    }
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_PR8.json"
    out.write_text(json.dumps(snap, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    run()
