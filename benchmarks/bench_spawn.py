"""Paper Fig. 4 — wall-clock time to spawn N kernel actors vs N plain
(event-based) actors. Both are lazy-initialized; after spawning we round-
trip one message through the last actor (as the paper does)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import ActorSystem, In, NDRange, Out, dim_vec, kernel

from .common import emit


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(64)),
        name="inc")
def _inc(x):
    return x + 1.0


def _spawn_kernel_actors(n: int) -> float:
    t0 = time.perf_counter()
    with ActorSystem(max_workers=4) as system:
        last = None
        for _ in range(n):
            last = system.spawn(_inc)
        last.ask(np.zeros(64, np.float32))
        return time.perf_counter() - t0


def _spawn_plain_actors(n: int) -> float:
    t0 = time.perf_counter()
    with ActorSystem(max_workers=4) as system:
        last = None
        for _ in range(n):
            last = system.spawn(lambda x: x + 1)
        last.ask(0)
        return time.perf_counter() - t0


def run() -> None:
    for n in (100, 500, 1000):
        tk = _spawn_kernel_actors(n)
        tp = _spawn_plain_actors(n)
        emit(f"spawn_kernel_actors_n{n}", tk / n * 1e6,
             f"total_s={tk:.3f}")
        emit(f"spawn_plain_actors_n{n}", tp / n * 1e6,
             f"total_s={tp:.3f};kernel/plain={tk / tp:.1f}x")


if __name__ == "__main__":
    run()
