"""Paper Fig. 6 — iterated-task baseline: a sequence of dependent matmul
tasks driven by actor messages vs the native loop. The paper measured
7–8 % messaging overhead; we additionally report the **fused composition**
variant (DESIGN.md §2) where stages are traced into one XLA program —
the beyond-paper optimization that removes per-stage dispatch entirely.

The second half benchmarks the DeviceRef data plane (ISSUE 2): the same
multi-stage chain run (a) with host round-trips between every stage, (b)
staged with refs forwarded on device, (c) fused — reporting wall time
*and* the registry's host-transfer counts for each."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ActorSystem, In, NDRange, Out, Pipeline, dim_vec,
                        kernel, memory_stats, reset_transfer_stats)
from repro.kernels import ops

from .common import emit, timeit

_N = 256
_ITERS = 100
_STAGES = 4


@kernel(In(jnp.float32), Out(jnp.float32, as_ref=True),
        nd_range=NDRange(dim_vec(_N, _N)), name="m_iter")
def _m_iter(x):
    return ops.ref.matmul(x, x)


def run() -> None:
    rng = np.random.default_rng(0)
    a = rng.random((_N, _N), np.float32) / _N

    with ActorSystem(max_workers=4) as system:
        mm = jax.jit(lambda x: ops.ref.matmul(x, x))

        def native_loop():
            x = jnp.asarray(a)
            for _ in range(_ITERS):
                x = mm(x)
            x.block_until_ready()

        worker = system.spawn(_m_iter)

        def actor_loop():
            ref = worker.ask(a)
            for _ in range(_ITERS - 1):
                ref = worker.ask(ref)
            ref.to_value()

        # fused: 10 stages traced into one program, iterated 10x
        # (Pipeline auto-fuses: all stages are one traceable kernel decl)
        fused = Pipeline(system, mode="auto", name="fused10").stages(
            [_m_iter] * 10).build()

        def fused_loop():
            ref = fused.ask(a)
            for _ in range(_ITERS // 10 - 1):
                ref = fused.ask(ref)
            ref.to_value()

        t_native = timeit(native_loop, repeat=3)
        t_actor = timeit(actor_loop, repeat=3)
        t_fused = timeit(fused_loop, repeat=3)
        emit("iterated_native", t_native / _ITERS * 1e6,
             f"total_s={t_native:.3f}")
        emit("iterated_actor", t_actor / _ITERS * 1e6,
             f"overhead={100 * (t_actor - t_native) / t_native:.1f}%")
        emit("iterated_fused", t_fused / _ITERS * 1e6,
             f"vs_native={100 * (t_fused - t_native) / t_native:+.1f}%")

        _run_data_plane(system, a)


def _host_transfers(stats: dict) -> int:
    return stats["transfers"] + stats["readbacks"] + stats["spills"]


def _run_data_plane(system, a) -> None:
    """Staged-vs-fused-vs-host-roundtrip over an ``_STAGES``-long chain,
    reporting the host-transfer count alongside wall time."""
    reps = _ITERS // 10

    # (a) host round-trip: independent value-semantics workers, results
    # bounce through the host between every hop
    workers = [system.spawn(_m_stage.with_options(name=f"hop{i}"))
               for i in range(_STAGES)]

    def hop_loop():
        x = a
        for _ in range(reps):
            for w in workers:
                x = w.ask(x)
        np.asarray(x)

    # (b) staged: one pipeline, DeviceRefs forwarded between stages
    staged = Pipeline(system, mode="staged", name="staged4").stages(
        [_m_stage] * _STAGES).build()

    def staged_loop():
        x = a
        for _ in range(reps):
            x = staged.ask(x)
        np.asarray(x)

    # (c) fused: all stages traced into one program
    fused4 = Pipeline(system, mode="fused", name="fused4").stages(
        [_m_stage] * _STAGES).build()

    def fused_loop():
        x = a
        for _ in range(reps):
            x = fused4.ask(x)
        np.asarray(x)

    calls = reps * _STAGES
    for name, fn in (("chain_host_roundtrip", hop_loop),
                     ("chain_staged_refs", staged_loop),
                     ("chain_fused", fused_loop)):
        t = timeit(fn, repeat=3)
        reset_transfer_stats()
        fn()
        n_x = _host_transfers(memory_stats())
        emit(name, t / calls * 1e6, f"host_transfers_per_run={n_x}")


@kernel(In(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(_N, _N)), name="m_stage")
def _m_stage(x):
    return ops.ref.matmul(x, x)


if __name__ == "__main__":
    run()
