"""Paper Fig. 6 — iterated-task baseline: a sequence of dependent matmul
tasks driven by actor messages vs the native loop. The paper measured
7–8 % messaging overhead; we additionally report the **fused composition**
variant (DESIGN.md §2) where stages are traced into one XLA program —
the beyond-paper optimization that removes per-stage dispatch entirely."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ActorSystem, In, NDRange, Out, Pipeline, dim_vec, kernel
from repro.kernels import ops

from .common import emit, timeit

_N = 256
_ITERS = 100


@kernel(In(jnp.float32), Out(jnp.float32, as_ref=True),
        nd_range=NDRange(dim_vec(_N, _N)), name="m_iter")
def _m_iter(x):
    return ops.ref.matmul(x, x)


def run() -> None:
    rng = np.random.default_rng(0)
    a = rng.random((_N, _N), np.float32) / _N

    with ActorSystem(max_workers=4) as system:
        mm = jax.jit(lambda x: ops.ref.matmul(x, x))

        def native_loop():
            x = jnp.asarray(a)
            for _ in range(_ITERS):
                x = mm(x)
            x.block_until_ready()

        worker = system.spawn(_m_iter)

        def actor_loop():
            ref = worker.ask(a)
            for _ in range(_ITERS - 1):
                ref = worker.ask(ref)
            ref.to_value()

        # fused: 10 stages traced into one program, iterated 10x
        # (Pipeline auto-fuses: all stages are one traceable kernel decl)
        fused = Pipeline(system, mode="auto", name="fused10").stages(
            [_m_iter] * 10).build()

        def fused_loop():
            ref = fused.ask(a)
            for _ in range(_ITERS // 10 - 1):
                ref = fused.ask(ref)
            ref.to_value()

        t_native = timeit(native_loop, repeat=3)
        t_actor = timeit(actor_loop, repeat=3)
        t_fused = timeit(fused_loop, repeat=3)
        emit("iterated_native", t_native / _ITERS * 1e6,
             f"total_s={t_native:.3f}")
        emit("iterated_actor", t_actor / _ITERS * 1e6,
             f"overhead={100 * (t_actor - t_native) / t_native:.1f}%")
        emit("iterated_fused", t_fused / _ITERS * 1e6,
             f"vs_native={100 * (t_fused - t_native) / t_native:+.1f}%")


if __name__ == "__main__":
    run()
