"""Unified cost-model placement vs scattered heuristics (ISSUE 10).

The pre-PR10 runtime made cross-node offload a *static* user decision:
you spawned the kernel on the peer by hand and every call paid the raw
wire round trip, whether or not the hop was worth it. The unified
:class:`~repro.core.placement.PlacementService` prices the hop per typed
edge (BENCH_PR5-seeded latency/throughput, int8 amortization, live peer
load) and places the graph accordingly. This benchmark measures what
that buys on a two-in-process-node localhost pair:

* **end-to-end wall time** — the same one-kernel graph driven through a
  hand-placed remote actor (baseline) vs through ``Graph.build`` with
  the cost model deciding (it keeps the node local: the hop never
  amortizes against a ~300 µs local dispatch);
* **transfers avoided / bytes on wire** — request+reply hops and wire
  bytes the baseline pays that the unified placement doesn't;
* **int8 amortization** — with local cost inflated past the modeled
  round trip, the service *does* choose the hop and picks the int8
  encoding, cutting bytes-on-wire by the measured compression ratio.

``--smoke`` (or ``run(smoke=True)``) runs 3 reps and asserts the
decisions (local under honest costs, ``wire-amortized:int8`` under
inflated ones) and the byte accounting — cheap enough for CI; the
``BENCH_PR10.json`` snapshot is only written by full runs.

    PYTHONPATH=src python -m benchmarks.bench_placement
"""
from __future__ import annotations

import json
import pathlib
import platform
import time

import jax.numpy as jnp
import numpy as np

from .common import emit, timeit

_N = 1 << 16                    # float32 elements per activation (256 KiB)
_ROWS: dict = {}


def _scale_impl(x):
    return x * 2.0


def _make_kernel():
    from repro.core import In, NDRange, Out, dim_vec, kernel
    return kernel(In(jnp.float32), Out(jnp.float32),
                  nd_range=NDRange(dim_vec(_N)),
                  name="bench_scale")(_scale_impl)


def _build_graph(system, decl, name, remotes=()):
    from repro.core import Graph
    g = Graph(system, name=name)
    x = g.source("x", jnp.float32, shape=(_N,))
    g.output(g.apply(decl, x))
    return g.build(remotes=list(remotes))


def run(smoke: bool = False) -> None:
    from repro.core import ActorSystem, DeviceRef
    from repro.core.placement import (NodeTarget, PlacementService,
                                      WireCostModel, set_service)
    from repro.net import NodeRuntime, wire

    repeat = 3 if smoke else 15
    decl = _make_kernel()
    x = np.random.RandomState(0).randn(_N).astype(np.float32)

    sa = ActorSystem("bench-pa", max_workers=4)
    sb = ActorSystem("bench-pb", max_workers=4)
    na = NodeRuntime(sa, name="a", listen=("127.0.0.1", 0))
    nb = NodeRuntime(sb, name="b")
    nb.connect(na.address)
    na.wait_for_peer("b", 30)

    bench_path = pathlib.Path(__file__).resolve().parent.parent / \
        "BENCH_PR5.json"
    seeded = (WireCostModel.from_bench(str(bench_path))
              if bench_path.exists() else WireCostModel())

    prev = set_service(PlacementService(wire=seeded))
    try:
        # -- baseline: hand-placed remote kernel, raw wire ----------------
        na.compress = False
        remote = na.spawn_remote("b", decl)
        t_base = timeit(lambda: remote.ask(x), repeat=repeat)
        req_raw = wire.encoded_size((x,))
        base_bytes = 2 * req_raw            # request + ~same-size reply
        emit("placement/baseline_remote_raw_us", t_base * 1e6)
        emit("placement/baseline_wire_bytes", base_bytes, "per call")

        # -- unified: the cost model keeps the graph local ----------------
        target = NodeTarget(na, "b")
        built = _build_graph(sa, decl, "unified", remotes=[target])
        dec = built.placement_decisions[0]
        local = not isinstance(built.placements["unified/bench_scale"],
                               NodeTarget)
        t_unified = timeit(lambda: built.ask(x), repeat=repeat)
        uni_bytes = 0 if local else base_bytes
        avoided = 2 * repeat if local else 0
        emit("placement/unified_us", t_unified * 1e6,
             f"x{t_base / max(t_unified, 1e-9):.1f} vs hand-placed remote")
        emit("placement/unified_wire_bytes", uni_bytes, dec.reason)
        emit("placement/transfers_avoided", avoided,
             f"hops over {repeat} calls")

        # -- inflated local cost: the hop amortizes, int8 wins ------------
        ballast = DeviceRef(jnp.zeros(1 << 20, jnp.float32))
        na.compress = "auto"
        costly = PlacementService(
            wire=WireCostModel(latency_s=1e-4, bytes_per_s=1e8,
                               min_compress_bytes=1),
            mem_s_per_byte=1e-3)
        set_service(costly)
        built_r = _build_graph(sa, decl, "offload", remotes=[target])
        dec_r = built_r.placement_decisions[0]
        t_remote = timeit(lambda: built_r.ask(x), repeat=repeat)
        req_int8 = wire.encoded_size((DeviceRef.put(x),), compress=True)
        emit("placement/amortized_remote_us", t_remote * 1e6, dec_r.reason)
        emit("placement/int8_wire_bytes", 2 * req_int8,
             f"{req_raw / req_int8:.2f}x smaller than raw")
        ballast.release()

        _ROWS.update({
            "baseline_remote_raw_us": round(t_base * 1e6, 1),
            "unified_us": round(t_unified * 1e6, 1),
            "unified_reason": dec.reason,
            "unified_local": local,
            "baseline_wire_bytes_per_call": base_bytes,
            "unified_wire_bytes_per_call": uni_bytes,
            "transfers_avoided": avoided,
            "amortized_remote_us": round(t_remote * 1e6, 1),
            "amortized_reason": dec_r.reason,
            "int8_wire_bytes_per_call": 2 * req_int8,
            "int8_vs_raw_ratio": round(req_raw / req_int8, 2),
        })

        if smoke:
            assert local, f"cost model offloaded a ~free kernel: {dec}"
            assert dec.reason in ("least-loaded", "inherit-upstream"), dec
            assert any(a.target == "node:b" for a in dec.alternatives), \
                "the rejected hop must be in the audit record"
            assert dec_r.reason == "wire-amortized:int8", dec_r
            assert avoided > 0 and uni_bytes < base_bytes
            assert req_int8 < req_raw / 2.5, (req_raw, req_int8)
            print("smoke ok:", _ROWS["unified_reason"], "/",
                  _ROWS["amortized_reason"])
    finally:
        set_service(prev)
        na.shutdown()
        nb.shutdown()
        sa.shutdown()
        sb.shutdown()
    if not smoke:
        _write_snapshot()


def _write_snapshot() -> None:
    import jax

    snap = {
        "pr": 10,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "workload": {
            "graph": "source -> scale kernel -> output, float32 "
                     f"n={_N}, localhost socket pair, in-process nodes",
            "baseline": "hand-placed spawn_remote kernel, raw wire",
            "unified": "Graph.build(remotes=[node:b]) under the "
                       "BENCH_PR5-seeded cost model",
        },
        "results": _ROWS,
    }
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_PR10.json"
    out.write_text(json.dumps(snap, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":  # pragma: no cover - manual invocation
    import sys
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv)
