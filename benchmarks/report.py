"""Generate the EXPERIMENTS.md §Dry-run and §Roofline sections from the
dry-run artifacts (keeps the document mechanically in sync)."""
from __future__ import annotations

import json
import sys

from .roofline_table import fmt_seconds, load_reports, table


def _gb(x) -> str:
    return f"{x / 1e9:.1f}"


def dryrun_section(reports) -> str:
    out = ["### Per-cell memory + collective footprint (1pod-256, per device)",
           "",
           "| arch | shape | plan (accum/fsdp/SP/opt) | args GB | temp GB | "
           "HLO GB moved | collective GB (top kinds) | compile s |",
           "|---|---|---|---|---|---|---|---|"]
    for r in reports:
        if r.get("mesh") != "1pod-256":
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"skipped: sub-quadratic only | — |")
            continue
        if r["status"] != "compiled":
            out.append(f"| {r['arch']} | {r['shape']} | **{r['status']}** "
                       f"| — | — | — | — | — |")
            continue
        rl, plan = r["roofline"], r["plan"]
        mem = rl["memory_per_device"]
        plan_s = (f"{plan['grad_accum']}/"
                  f"{'F' if plan['fsdp'] else '-'}/"
                  f"{'S' if plan['seq_activations'] else '-'}/"
                  f"{plan['opt_dtype'][:4]}")
        cb = sorted(rl["collective_bytes"].items(), key=lambda kv: -kv[1])[:3]
        cb_s = " ".join(f"{k.replace('all-', 'a')}:{_gb(v)}" for k, v in cb)
        out.append(
            f"| {r['arch']} | {r['shape']} | {plan_s} | "
            f"{_gb(mem.get('argument_size_in_bytes', 0))} | "
            f"{_gb(mem.get('temp_size_in_bytes', 0))} | "
            f"{_gb(rl['bytes_per_device'])} | {cb_s} | {r['compile_s']} |")
    # multi-pod check summary
    multi = [r for r in reports if r.get("mesh") == "2pod-512"]
    ok = sum(1 for r in multi if r["status"] == "compiled")
    sk = sum(1 for r in multi if r["status"] == "skipped")
    out += ["", f"**Multi-pod (2×16×16 = 512 chips)**: {ok} cells compiled, "
            f"{sk} documented skips, "
            f"{len(multi) - ok - sk} failures — the `pod` axis shards "
            "(batch over `('pod','data')`; gradient all-reduce crosses pods)."]
    return "\n".join(out)


def main() -> None:
    reports = load_reports()
    print("## §Dry-run\n")
    print(dryrun_section(reports))
    print("\n## §Roofline (single-pod 16×16, per-device terms, TPU v5e "
          "constants)\n")
    print(table(reports, mesh="1pod-256"))


if __name__ == "__main__":
    main()
