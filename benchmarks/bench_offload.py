"""Paper Figs. 7/8 — heterogeneous offload sweep: a Mandelbrot frame is
split between a "host" worker pool and a "device" worker pool, the device
fraction swept 0→100 % in 10 % steps. On this container both pools are CPU
threads (host = interpreted row loop via numpy, device = jitted kernel) so
the absolute numbers differ from the paper's GPUs, but the *shape* of the
curve — monotone decline while offloading to the faster pool, with the
100 %-device time as the floor — is the reproduced claim."""
from __future__ import annotations

import numpy as np

from repro.core import ActorSystem, split_offload
from repro.kernels import ops

from .common import emit

_W, _H, _IT = 256, 128, 100
_VIEW = dict(re_min=-0.5, re_max=0.1, im_min=-0.7375, im_max=-0.1375)


def _host_rows(start: int, rows: int) -> np.ndarray:
    """'Host' pool: the un-jitted pure-jnp oracle path (op-by-op dispatch —
    naturally slower than the kernel; bit-identical per the kernel tests)."""
    return np.asarray(ops.mandelbrot(height=rows, width=_W, max_iter=_IT,
                                     row_offset=start, total_height=_H,
                                     impl="ref", **_VIEW))


def _device_rows(start: int, rows: int) -> np.ndarray:
    return np.asarray(ops.mandelbrot(height=rows, width=_W, max_iter=_IT,
                                     row_offset=start, total_height=_H,
                                     impl="pallas", **_VIEW))


def run() -> None:
    import time
    with ActorSystem(max_workers=4) as system:
        # workers take (start, rows) and render their row slice
        host = system.spawn(lambda s, n: _host_rows(s, n))
        dev = system.spawn(lambda s, n: _device_rows(s, n))

        full_ref = _host_rows(0, _H)
        for pct in range(0, 101, 10):
            frac = pct / 100.0
            t0 = time.perf_counter()
            img = split_offload(
                [dev, host], [frac, 1.0 - frac],
                make_payload=lambda s, n: (s, n),
                sizes_of=lambda fr: [round(_H * fr[0]),
                                     _H - round(_H * fr[0])],
                combine=lambda parts: np.vstack(parts))
            dt = time.perf_counter() - t0
            # Structural integrity: no dropped/duplicated rows. Boundary
            # pixels may differ by a few iterations between pools (f32
            # escape-time chaos under different fusion orders — the paper's
            # CPU/GPU pools have the same property), so require ≥98 % exact.
            assert img.shape == full_ref.shape
            match = np.mean(img == full_ref)
            assert match > 0.98, f"offload split broke output ({match:.3f})"
            emit(f"mandelbrot_offload_{pct:03d}pct", dt * 1e6,
                 f"rows_device={round(_H * frac)};pixel_match={match:.4f}")


if __name__ == "__main__":
    run()
