"""§Perf hillclimb driver: re-lower one cell with plan overrides and diff
the roofline terms against the recorded baseline.

    PYTHONPATH=src python -m benchmarks.hillclimb \
        --arch llama3-8b --shape train_4k \
        --plan '{"remat": "dots", "seq_activations": true}' [--save NAME]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--plan", default="{}")
    ap.add_argument("--save", default=None,
                    help="persist result as experiments/perf/<NAME>.json")
    ap.add_argument("--baseline",
                    default=None, help="baseline json (default: dryrun cell)")
    args = ap.parse_args()

    from repro.launch import dryrun_lib
    from repro.launch.mesh import make_production_mesh

    base_path = args.baseline or \
        f"experiments/dryrun/{args.arch}__{args.shape}__1pod-256.json"
    base = json.load(open(base_path))["roofline"]

    mesh = make_production_mesh()
    rep = dryrun_lib.lower_cell(args.arch, args.shape, mesh, "1pod-256",
                                plan_overrides=json.loads(args.plan))
    rl = rep["roofline"]

    print(f"\n{args.arch} {args.shape}  plan={rep['plan']}")
    print(f"{'term':12s} {'baseline':>12s} {'new':>12s} {'delta':>8s}")
    for term in ("compute_s", "memory_s", "collective_s"):
        b, n = base[term], rl[term]
        print(f"{term:12s} {b:12.4f} {n:12.4f} {100 * (n - b) / b:+7.1f}%")
    print(f"{'bottleneck':12s} {base['bottleneck']:>12s} {rl['bottleneck']:>12s}")
    print(f"{'roofline%':12s} {100 * base['roofline_fraction']:12.2f} "
          f"{100 * rl['roofline_fraction']:12.2f}")
    mem = rl.get("memory_per_device", {})
    print(f"temp_GB={mem.get('temp_size_in_bytes', 0) / 1e9:.1f} "
          f"args_GB={mem.get('argument_size_in_bytes', 0) / 1e9:.1f}")
    bb = rl.get("bytes_by_opcode", {})
    tot = sum(bb.values()) or 1
    tops = sorted(bb.items(), key=lambda kv: -kv[1])[:5]
    print("traffic: " + "  ".join(f"{k}={v / 1e9:.0f}GB({100 * v / tot:.0f}%)"
                                  for k, v in tops))
    cb = rl.get("collective_bytes", {})
    print("collectives: " + "  ".join(f"{k}={v / 1e9:.0f}GB"
                                      for k, v in cb.items()))
    if args.save:
        os.makedirs("experiments/perf", exist_ok=True)
        with open(f"experiments/perf/{args.save}.json", "w") as f:
            json.dump(rep, f, indent=1)


if __name__ == "__main__":
    main()
