"""Paper Fig. 3 — WAH index build time vs input size: data-parallel device
pipeline vs the sequential CPU builder. The reproduced claim is the
qualitative one (§4.2): both scale linearly, the data-parallel build wins
at scale, and the output index is identical."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.indexing import build_wah_index, build_wah_index_numpy

from .common import emit

_CARD = 64


def run() -> None:
    rng = np.random.default_rng(0)
    for n in (10_000, 100_000, 1_000_000):
        values = rng.integers(0, _CARD, n).astype(np.uint32)
        vj = jnp.asarray(values)
        # warm (compile)
        build_wah_index(vj, _CARD)[1].block_until_ready()
        t0 = time.perf_counter()
        words, n_words, starts, counts = build_wah_index(vj, _CARD)
        n_words.block_until_ready()
        t_dev = time.perf_counter() - t0

        t_cpu = None
        if n <= 100_000:  # sequential builder is O(n·card); cap runtime
            t0 = time.perf_counter()
            ref_words, ref_n, _, _ = build_wah_index_numpy(values, _CARD)
            t_cpu = time.perf_counter() - t0
            assert int(n_words) == ref_n
        emit(f"wah_index_build_n{n}", t_dev * 1e6,
             f"Mvals_per_s={n / t_dev / 1e6:.2f}" +
             (f";cpu_s={t_cpu:.3f};speedup={t_cpu / t_dev:.1f}x" if t_cpu else ""))


if __name__ == "__main__":
    run()
