"""Render the dry-run roofline artifacts as the EXPERIMENTS.md tables."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_reports(dirname: str = DRYRUN_DIR) -> List[Dict]:
    reports = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            reports.append(json.load(f))
    return reports


def fmt_seconds(s: float) -> str:
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.1f}us"


def table(reports: List[Dict], mesh: str = "1pod-256") -> str:
    rows = ["| arch | shape | kind | compute | memory | collective | "
            "bottleneck | useful/HLO | roofline-frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in reports:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                        f"skipped | — | — |")
            continue
        if r["status"] != "compiled":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                        f"**{r['status']}** | — | — |")
            continue
        rl = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{fmt_seconds(rl['compute_s'])} | {fmt_seconds(rl['memory_s'])} | "
            f"{fmt_seconds(rl['collective_s'])} | {rl['bottleneck']} | "
            f"{rl['useful_flops_ratio']:.2f} | {rl['roofline_fraction']:.2%} |")
    return "\n".join(rows)


def run() -> None:
    reports = load_reports()
    if not reports:
        print("no dry-run artifacts found; run repro.launch.dryrun first")
        return
    print(table(reports))
    compiled = [r for r in reports if r["status"] == "compiled"]
    failed = [r for r in reports if r["status"] == "FAILED"]
    print(f"\ncompiled={len(compiled)} failed={len(failed)} "
          f"skipped={len(reports) - len(compiled) - len(failed)}")


if __name__ == "__main__":
    run()
