"""Declarative kernel-actor API (v2) — the unified surface.

The v1 surface scattered kernel declaration, composition, placement, and
pooling across four call conventions (``DeviceManager.spawn`` with
positional specs, ``ActorRef.__mul__``, the free function ``fuse``, and
``ChunkScheduler``). v2 collapses them into three declarative objects:

* :func:`kernel` — capture the signature and ND-range **at definition
  site**::

      @kernel(In(jnp.float32), In(jnp.float32),
              Out(jnp.float32, shape=(n, n)),
              nd_range=NDRange(dim_vec(n, n)))
      def m_mult(a, b):
          return a @ b

      worker = system.spawn(m_mult)           # or mngr.spawn(m_mult)
      result = worker.ask(a, b)

* :class:`Pipeline` — one graph object subsuming staged composition
  (paper §3.5 promise chaining) and fused composition (§3.6 single-actor
  nesting)::

      pipe = (Pipeline(system, mode="auto")    # staged | fused | auto
              .stage(prepare).stage(count).stage(move)
              .build())

  ``auto`` fuses when every stage is traceable and placed on one device,
  and falls back to staged composition otherwise.

* :class:`ActorPool` / ``DeviceManager.spawn_pool`` — N replicas behind
  one ref, routed round-robin or by load (outstanding requests + device
  queue depth); pools plug directly into :class:`ChunkScheduler`.

The v1 functions (``compose``, ``fuse``, positional ``spawn``) remain as
thin shims over this module.
"""
from __future__ import annotations

import inspect
import itertools
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FuturesTimeout
from typing import Any, Callable, List, Optional, Sequence, Union

from ..analysis.runtime import make_lock
from .actor import ActorRef, ActorSystem
from .placement import service as placement_service
from .signature import KernelSignature, NDRange

__all__ = ["kernel", "KernelDecl", "Pipeline", "ActorPool"]

#: distinguishes "caller passed no timeout" from an explicit ``None``
#: (= wait forever) in :meth:`ActorPool.ask`
_UNSET = object()


# ----------------------------------------------------------------------------
# @kernel — declaration-site capture
# ----------------------------------------------------------------------------
class KernelDecl:
    """A declared kernel: traceable callable + captured signature/ND-range.

    Remains directly callable (the undecorated behavior), and is accepted
    by ``ActorSystem.spawn``, ``DeviceManager.spawn``/``spawn_pool``, and
    ``Pipeline.stage``.
    """

    def __init__(self, fn: Callable, specs: Sequence, *,
                 nd_range: Optional[NDRange] = None,
                 name: Optional[str] = None,
                 preprocess: Optional[Callable] = None,
                 postprocess: Optional[Callable] = None,
                 donate: bool = True):
        self.fn = fn
        self.specs = tuple(specs)
        self.nd_range = nd_range
        self.name = name or getattr(fn, "__name__", "kernel")
        self.preprocess = preprocess
        self.postprocess = postprocess
        self.donate = donate
        self.signature = KernelSignature(*self.specs)
        self.__name__ = self.name
        self.__doc__ = getattr(fn, "__doc__", None)

    def __call__(self, *args, **kwargs):
        return self.fn(*args, **kwargs)

    def with_options(self, **overrides) -> "KernelDecl":
        """A copy with some declaration fields replaced (e.g. a resized
        ``nd_range`` for a different problem shape)."""
        cfg = dict(nd_range=self.nd_range, name=self.name,
                   preprocess=self.preprocess, postprocess=self.postprocess,
                   donate=self.donate)
        specs = overrides.pop("specs", self.specs)
        fn = overrides.pop("fn", self.fn)
        unknown = set(overrides) - set(cfg)
        if unknown:
            raise TypeError(f"unknown kernel options: {sorted(unknown)}")
        cfg.update(overrides)
        return KernelDecl(fn, specs, **cfg)

    def out_structs(self, input_structs: Sequence):
        """Abstract output ``jax.ShapeDtypeStruct``\\ s for the given input
        structs — how :class:`repro.core.graph.Graph` derives typed ports
        from the signature at build time (paper §3.5)."""
        from .facade import detect_fn_kwargs, eval_output_structs
        return eval_output_structs(self.fn, self.signature, self.nd_range,
                                   detect_fn_kwargs(self.fn), input_structs)

    def __repr__(self):
        return (f"<kernel {self.name!r} {self.signature} "
                f"nd_range={self.nd_range}>")


def kernel(*specs, nd_range: Optional[NDRange] = None,
           name: Optional[str] = None,
           preprocess: Optional[Callable] = None,
           postprocess: Optional[Callable] = None,
           donate: bool = True) -> Callable[[Callable], KernelDecl]:
    """Declare a data-parallel kernel at definition site (see module doc)."""

    def decorate(fn: Callable) -> KernelDecl:
        return KernelDecl(fn, specs, nd_range=nd_range, name=name,
                          preprocess=preprocess, postprocess=postprocess,
                          donate=donate)

    return decorate


# ----------------------------------------------------------------------------
# Pipeline — unified staged/fused composition
# ----------------------------------------------------------------------------
class _Stage:
    __slots__ = ("target", "device", "name")

    def __init__(self, target, device, name):
        self.target = target
        self.device = device
        self.name = name


class Pipeline:
    """Builder for multi-stage kernel graphs.

    Stages may be :class:`KernelDecl`\\ s, existing actor refs (kernel or
    plain), or bare callables (adapters between kernel stages). ``build``
    returns an ordinary :class:`ActorRef`; messages flow through stages
    left to right.
    """

    def __init__(self, system: ActorSystem, *, mode: str = "auto",
                 name: str = "pipeline", device=None,
                 nd_range: Optional[NDRange] = None):
        if mode not in ("auto", "staged", "fused"):
            raise ValueError(f"mode must be auto|staged|fused, got {mode!r}")
        self.system = system
        self.mode = mode
        self.name = name
        self.device = device
        self.nd_range = nd_range
        self._stages: List[_Stage] = []

    # -- construction ------------------------------------------------------
    def stage(self, target, *, device=None, name: Optional[str] = None
              ) -> "Pipeline":
        """Append a stage; returns ``self`` for chaining."""
        if not (isinstance(target, (KernelDecl, ActorRef))
                or callable(target)):
            raise TypeError(f"cannot stage {target!r}")
        self._stages.append(_Stage(target, device, name))
        return self

    def stages(self, targets: Sequence) -> "Pipeline":
        """Append several stages at once."""
        for t in targets:
            self.stage(t)
        return self

    # -- introspection -----------------------------------------------------
    def _kernel_actor_of(self, ref: ActorRef):
        from .facade import KernelActor
        st = self.system._actors.get(ref.actor_id)
        actor = st.actor if st else None
        return actor if isinstance(actor, KernelActor) else None

    def _composed_stages_of(self, ref: ActorRef):
        from .compose import ComposedActor
        st = self.system._actors.get(ref.actor_id)
        actor = st.actor if st else None
        return list(actor.stages) if isinstance(actor, ComposedActor) else None

    def resolved_mode(self) -> str:
        """The mode ``build`` will use (resolves ``auto``)."""
        if self.mode != "auto":
            return self.mode
        return "fused" if self._fusable() else "staged"

    def _fusable(self) -> bool:
        devices = set()
        if self.device is not None:
            devices.add(self.device)
        has_kernel = False
        for s in self._stages:
            if s.device is not None:
                devices.add(s.device)
            if isinstance(s.target, KernelDecl):
                has_kernel = True
            elif isinstance(s.target, ActorRef):
                ka = self._kernel_actor_of(s.target)
                if ka is None:
                    return False  # opaque actor: only staged works
                has_kernel = True
                devices.add(ka.device)
            # bare callables are traceable adapters: fusable
        return has_kernel and len(devices) <= 1

    # -- build -------------------------------------------------------------
    def build(self) -> ActorRef:
        if not self._stages:
            raise ValueError("pipeline has no stages")
        mode = self.resolved_mode()
        if mode == "staged":
            return self._build_staged()
        return self._build_fused()

    def _graph_stages_of(self, ref: ActorRef):
        """The underlying stage refs of a Graph-backed linear pipe (the
        Graph analogue of :meth:`_composed_stages_of` inlining)."""
        from .graph import GraphRef
        if isinstance(ref, GraphRef) and ref.plan.chain_refs:
            return list(ref.plan.chain_refs)
        return None

    def _build_staged(self) -> ActorRef:
        """Staged (event-chained) composition, Listing 4 style — built as a
        **linear dataflow graph** (:class:`repro.core.graph.Graph`).

        Pipeline is the thin linear wrapper over the DAG builder: each
        stage becomes a chain node joined by untyped splat edges (the
        whole payload tuple flows per hop, exactly the v1 semantics), and
        the Graph lowering decides ref emission — an intermediate kernel
        stage is spawned (or cloned, never mutated) with ``emit="ref"``
        whenever its successor can unwrap a
        :class:`~repro.core.memref.DeviceRef`, so data stays
        device-resident between hops and only the final stage honours its
        declared value/reference semantics (paper §3.5).
        """
        from .graph import Graph
        mngr = self.system.opencl_manager()
        # flatten to (kind, target, device), inlining pre-composed chains
        # (v1 ComposedActor refs and Graph-backed linear pipes alike)
        entries: List[tuple] = []
        for s in self._stages:
            if isinstance(s.target, KernelDecl):
                entries.append(("decl", s.target, s.device or self.device))
            elif isinstance(s.target, ActorRef):
                inner = (self._composed_stages_of(s.target)
                         or self._graph_stages_of(s.target))
                for r in (inner if inner else [s.target]):
                    entries.append(("ref", r, None))
            else:
                entries.append(("fn", s.target, None))

        if len(entries) == 1:
            kind, target, device = entries[0]
            if kind == "decl":
                return mngr.spawn(target, device=device)
            if kind == "fn":
                return self.system.spawn(target)
            return target

        g = Graph(self.system, name=self.name)
        cur = g.chain_source()
        for kind, target, device in entries:
            cur = g.chain(target, cur, device=device)
        g.output(cur)
        return g.build()

    def _build_fused(self) -> ActorRef:
        """Fused (single-actor) composition, §3.6 style — re-routed through
        the Graph **fusion pass**: stages become a linear chain graph and
        ``Graph.build(fuse=True)`` collapses the contiguous kernel runs
        into single jitted actors. Staged and fused composition therefore
        converge on one lowering path, and fused pipelines inherit the
        graph's build-time validation, ref accounting, and the
        :meth:`~repro.core.graph.GraphRef.ask` inline-dispatch fast path.
        """
        from .graph import Graph

        entries: List[Any] = []
        device = self.device
        has_kernel = False
        for s in self._stages:
            target = s.target
            if isinstance(target, ActorRef):
                ka = self._kernel_actor_of(target)
                if ka is None:
                    raise TypeError(f"{target} is not a kernel actor; "
                                    "cannot fuse")
                # re-declare the actor's kernel so the graph pass can trace
                # it; the running actor itself is never touched
                entries.append(KernelDecl(
                    ka.fn, ka.signature.specs, nd_range=ka.nd_range,
                    name=ka.kernel_name, preprocess=ka.preprocess,
                    postprocess=ka.postprocess, donate=ka.donate))
                has_kernel = True
                device = device or s.device or ka.device
            elif isinstance(target, KernelDecl):
                entries.append(target)
                has_kernel = True
                device = device or s.device
            elif callable(target):
                entries.append(target)
            else:  # pragma: no cover - guarded in stage()
                raise TypeError(f"cannot fuse {target!r}")
        if not has_kernel:
            raise ValueError("fuse needs at least one kernel stage")
        if self.nd_range is not None:
            # the pipeline-level override resizes the first kernel's index
            # space (the old builder carried it on the fused actor, where
            # it was inert for dispatch)
            for i, e in enumerate(entries):
                if isinstance(e, KernelDecl):
                    entries[i] = e.with_options(nd_range=self.nd_range)
                    break

        g = Graph(self.system, name=self.name)
        cur = g.chain_source()
        for e in entries:
            cur = g.chain(e, cur, device=device,
                          traceable=not isinstance(e, KernelDecl))
        g.output(cur)
        return g.build(fuse=True)


def _bound_fn(fn: Callable, nd_range, local_specs,
              known_kwargs=None) -> Callable:
    """The stage's traceable callable with its static keyword arguments
    (``nd_range``/``local_shapes``) bound, mirroring the facade.
    ``known_kwargs`` reuses a :class:`KernelActor`'s cached detection."""
    if known_kwargs is not None:
        params = known_kwargs
    else:
        try:
            params = inspect.signature(fn).parameters
        except (TypeError, ValueError):  # pragma: no cover - builtins
            params = {}
    kwargs = {}
    if "nd_range" in params:
        kwargs["nd_range"] = nd_range
    if "local_shapes" in params:
        kwargs["local_shapes"] = tuple(s.resolved_shape()
                                       for s in local_specs)
    if not kwargs:
        return fn

    def bound(*inputs):
        return fn(*inputs, **kwargs)

    return bound


# ----------------------------------------------------------------------------
# ActorPool — replicated kernel actors behind one ref
# ----------------------------------------------------------------------------
class ActorPool:
    """Routes messages across worker replicas.

    Policies:

    * ``round_robin``  — cycle over live workers.
    * ``least_loaded`` — pick the live worker with the fewest outstanding
      requests, tie-broken by its device's command-queue depth
      (``Device.queue_depth()``) and then by the device's live ref bytes
      (the ``DeviceManager`` memory watermark); a slow or memory-pressured
      replica therefore stops winning work as soon as it backs up.

    Routing is **placement-aware**: when a payload carries a
    :class:`~repro.core.memref.DeviceRef`, workers whose device already
    holds that data are preferred (zero-copy dispatch), load-ranked among
    themselves.

    Pools are network-transparent: members may be
    :class:`~repro.net.RemoteActorRef`\\ s (they quack identically and key
    the routing tables by their ``"<peer>/<id>"`` ids). Off-node refs have
    no local device, so placement preference never selects them for a
    device-resident payload — when *no* member matches the payload's
    device, a round-robin pool falls back to round-robin over everyone
    (local and remote alike) instead of pretending to know their load.

    Quacks like an :class:`ActorRef` (``send``/``request``/``ask``/
    ``is_alive``) and exposes ``.workers``/``.placements`` so it plugs
    directly into :class:`~repro.core.scheduler.ChunkScheduler`.

    Both policies and the residency preference are evaluated by the
    process-wide :class:`~repro.core.placement.PlacementService` — the
    pool feeds its candidates and outstanding counters in and routes to
    whatever the service's auditable
    :class:`~repro.core.placement.PlacementDecision` picks.
    """

    def __init__(self, system: ActorSystem, workers: Sequence[ActorRef], *,
                 policy: str = "round_robin", devices: Optional[Sequence] = None,
                 default_timeout: Optional[float] = 120.0):
        if not workers:
            raise ValueError("pool needs at least one worker")
        if policy not in ("round_robin", "least_loaded"):
            raise ValueError(f"unknown policy {policy!r}")
        self.system = system
        self.policy = policy
        #: default ``ask`` timeout in seconds (None = wait forever); set
        #: per-pool instead of relying on the old hardcoded 120 s
        self.default_timeout = default_timeout
        self._workers = list(workers)
        devices = list(devices) if devices else [None] * len(self._workers)
        self._devices = {w.actor_id: d for w, d in zip(self._workers, devices)}
        self._outstanding = {w.actor_id: 0 for w in self._workers}
        self._rr = itertools.count()
        self._lock = make_lock("ActorPool")

    # -- membership ------------------------------------------------------
    @property
    def workers(self) -> List[ActorRef]:
        with self._lock:
            return list(self._workers)

    @property
    def placements(self) -> dict:
        """``actor_id → Device`` (or None) — consumed by
        :class:`~repro.core.scheduler.ChunkScheduler` for placement-aware
        chunk routing."""
        with self._lock:
            return dict(self._devices)

    def live_workers(self) -> List[ActorRef]:
        return [w for w in self.workers if w.is_alive()]

    def add_worker(self, ref: ActorRef, device=None) -> None:
        with self._lock:
            self._workers.append(ref)
            self._devices[ref.actor_id] = device
            self._outstanding.setdefault(ref.actor_id, 0)

    def is_alive(self) -> bool:
        return bool(self.live_workers())

    def outstanding(self, ref: ActorRef) -> int:
        with self._lock:
            return self._outstanding.get(ref.actor_id, 0)

    # -- routing ------------------------------------------------------
    def _pick(self, payload: tuple = (), exclude=frozenset()) -> ActorRef:
        # caller must hold self._lock (routing state: _rr, _outstanding).
        # Ranking itself — residency preference, least-loaded ordering,
        # round-robin fallback — lives in the process-wide placement
        # service; the pool only maintains membership and the outstanding
        # counters it feeds in as a cost term
        live = [w for w in self._workers if w.is_alive()]
        if not live:
            raise RuntimeError("no live workers in pool")
        if exclude:
            kept = [w for w in live if w.actor_id not in exclude]
            if kept:  # exclusion is a preference: never strand a payload
                live = kept
        decision = placement_service().rank(
            [(w.actor_id, self._devices.get(w.actor_id)) for w in live],
            payload, outstanding=self._outstanding, policy=self.policy,
            rr_tick=lambda: next(self._rr),
            context=f"pool:{self.policy}")
        return next(w for w in live if w.actor_id == decision.chosen)

    def send(self, *payload: Any) -> None:
        with self._lock:
            w = self._pick(payload)
        w.send(*payload)

    def submit(self, *payload: Any, exclude: Sequence[ActorRef] = ()
               ) -> Future:
        """Asynchronous submit: route the payload, bump the chosen worker's
        outstanding count, and return the reply future with ``.worker`` set
        to the chosen ref. Callers that track misbehaving-but-alive
        replicas (slow, suspected-bad) steer retries away from them via
        ``exclude``; note the serve engine's own retry path runs through
        :class:`~repro.core.scheduler.ChunkScheduler` instead, where a
        *crashed* replica is excluded implicitly by being dead. Exclusion
        is a preference, not a pin: if every live worker is excluded it is
        ignored rather than stranding the payload.
        """
        excluded = {getattr(w, "actor_id", w) for w in exclude}
        with self._lock:
            w = self._pick(payload, excluded)
            aid = w.actor_id
            self._outstanding[aid] = self._outstanding.get(aid, 0) + 1
        fut = w.request(*payload)

        # the decrement runs in the done-callback *under the pool lock*,
        # pairing with the locked increment above so the counter can never
        # go negative or be lost under concurrent request() callers
        def _done(_f, aid=aid):
            with self._lock:
                self._outstanding[aid] = self._outstanding.get(aid, 0) - 1

        fut.add_done_callback(_done)
        fut.worker = w
        return fut

    def request(self, *payload: Any) -> Future:
        return self.submit(*payload)

    def ask(self, *payload: Any, timeout: Any = _UNSET) -> Any:
        """Synchronous routed request. ``timeout`` defaults to the pool's
        ``default_timeout``; on expiry the raised :class:`TimeoutError`
        names the worker the payload was routed to, so a wedged replica is
        identifiable from the exception alone."""
        if timeout is _UNSET:
            timeout = self.default_timeout
        fut = self.submit(*payload)
        try:
            return fut.result(timeout=timeout)
        except FuturesTimeout:
            if fut.done():
                # the *worker* raised a TimeoutError (on 3.11+ the futures
                # class is the builtin) — surface it, don't relabel it as
                # a pool timeout pointing at a healthy replica
                raise
            w = getattr(fut, "worker", None)
            wid = getattr(w, "actor_id", "?")
            # FuturesTimeout: the class existing except-clauses around a
            # future-based API already catch (the builtin alias on 3.11+)
            raise FuturesTimeout(
                f"pool request timed out after {timeout}s; routed to worker "
                f"ActorRef#{wid} ({'alive' if w is not None and w.is_alive() else 'dead'}, "
                f"{self.outstanding(w) if w is not None else '?'} outstanding)"
            ) from None

    def map(self, payloads: Sequence[tuple], *,
            timeout: Optional[float] = 300.0, deadlines=None,
            **scheduler_kwargs) -> list:
        """Run every payload on some worker via :class:`ChunkScheduler`
        (pull-based balancing + straggler re-issue); ``deadlines`` (one
        absolute ``time.monotonic`` value or None per payload) turns on
        the scheduler's earliest-deadline-first pick."""
        from .scheduler import ChunkScheduler
        return ChunkScheduler(self, **scheduler_kwargs).run(
            payloads, timeout=timeout, deadlines=deadlines)

    def __repr__(self):
        return (f"ActorPool({len(self._workers)} workers, "
                f"policy={self.policy!r})")
