"""Heterogeneous scheduling across device pools (paper §5.4, §3.6).

The paper evaluates *fractional offload*: a workload is split between the
CPU and one or more OpenCL devices, the fraction swept from 0 % to 100 %.
This module generalizes that into a small production scheduler:

* :func:`split_offload`      — the paper's experiment: one split by fixed
                               fractions across heterogeneous workers.
* :class:`ChunkScheduler`    — chunked pull-based dispatch (more chunks
                               than workers), which gives
                               - load balancing across devices of unequal
                                 speed (paper §3.6 "scheduling kernels
                                 across multiple devices"),
                               - **straggler mitigation**: once the queue
                                 drains, outstanding chunks are re-issued
                                 speculatively to idle workers and the
                                 first completion wins,
                               - **elastic scaling**: workers may be added
                                 or removed between (or during) runs; a
                                 worker that dies (actor terminates) simply
                                 stops winning chunks and its outstanding
                                 chunks are re-issued.

At pod scale the same logic drives the elastic batch splitter in
``repro.dist.fault``: the "workers" are mesh-slice stage actors.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, List, Optional, Sequence

from ..analysis.runtime import make_rlock
from .actor import ActorRef
from .errors import DeadlineExceeded
from .memref import tree_release
from .placement import service as placement_service

__all__ = ["split_offload", "ChunkScheduler", "WorkItem"]


def split_offload(workers: Sequence[ActorRef],
                  fractions: Sequence[float],
                  make_payload: Callable[[int, int], tuple],
                  sizes_of: Callable[[Sequence[float]], Sequence[int]],
                  combine: Callable[[List[Any]], Any]) -> Any:
    """One fractional split across heterogeneous workers (paper Fig. 7/8).

    ``sizes_of(fractions)`` returns per-worker item counts; ``make_payload
    (start, size)`` builds each worker's request; ``combine`` reassembles
    ordered results. Zero-sized fractions skip their worker entirely (the
    0 %/100 % endpoints of the paper's sweep).
    """
    if len(workers) != len(fractions):
        raise ValueError("one fraction per worker")
    sizes = list(sizes_of(fractions))
    futures: list[Optional[Future]] = []
    start = 0
    for w, sz in zip(workers, sizes):
        if sz == 0:
            futures.append(None)
        else:
            futures.append(w.request(*make_payload(start, sz)))
        start += sz
    results = [None if f is None else f.result() for f in futures]
    return combine([r for r in results if r is not None])


class WorkItem:
    __slots__ = ("index", "payload", "result", "done", "attempts",
                 "issued_at", "deadline")

    def __init__(self, index: int, payload: tuple,
                 deadline: Optional[float] = None):
        self.index = index
        self.payload = payload
        self.result: Any = None
        self.done = False
        self.attempts = 0
        self.issued_at: float = 0.0
        #: absolute time.monotonic() value; an undispatched chunk whose
        #: deadline has passed is shed (DeadlineExceeded) instead of issued
        self.deadline = deadline


class ChunkScheduler:
    """Pull-based chunk dispatch with speculative re-issue of stragglers.

    Dispatch is **placement-aware** when worker placements are known (an
    :class:`~repro.core.api.ActorPool` provides them, or pass ``devices=``):
    a chunk whose payload carries a :class:`~repro.core.memref.DeviceRef`
    already resident on worker W's device is preferentially handed to W,
    so chunked ref pipelines dispatch zero-copy. (Affinity is a preference,
    not a pin — a worker with no matching chunk falls back to FIFO so
    placement can never starve it.) Refs in chunk payloads must not be
    *donated* by the kernel: a speculative re-issue would replay a
    consumed ref.

    Workers may live on **other nodes** (:class:`~repro.net.RemoteActorRef`
    members of a pool). When a remote *node* dies mid-run, every in-flight
    request to it fails at once and its refs report dead: the failed
    chunks re-queue and re-issue on surviving workers, and first-completion
    -wins keeps them exactly-once — the wire format ships request payloads
    as spill **copies** precisely so the local originals stay live for
    this replay. A chunk whose payload refs were donated would break that,
    same as the speculative case above.
    """

    def __init__(self, workers, *,
                 straggler_factor: float = 3.0, max_attempts: int = 3,
                 drain_grace: float = 10.0, devices=None):
        placements: dict = {}
        if hasattr(workers, "placements"):  # ActorPool (repro.core.api)
            placements.update(workers.placements)
        if hasattr(workers, "workers"):
            workers = workers.workers
        workers = list(workers)
        if devices is not None:
            if isinstance(devices, dict):
                placements.update(devices)
            else:
                placements.update(
                    {w.actor_id: d for w, d in zip(workers, devices)})
        self._placements = placements
        self._workers: list[ActorRef] = list(workers)
        self.straggler_factor = straggler_factor
        self.max_attempts = max_attempts
        #: how long run() waits for in-flight duplicate/late callbacks to
        #: settle before returning (keeps stats and failure-override
        #: bookkeeping deterministic); 0 restores fire-and-forget returns
        #: at the cost of stats that may still be counting afterwards
        self.drain_grace = drain_grace
        # re-entrant: a request that completes before its done-callback is
        # registered runs on_done synchronously in the issuing thread,
        # which already holds this lock
        self._lock = make_rlock("ChunkScheduler")
        self._cv = threading.Condition(self._lock)
        self.stats = {"dispatched": 0, "speculative": 0, "failed": 0,
                      "expired": 0}

    # -- elastic worker pool -------------------------------------------------
    def add_worker(self, w: ActorRef) -> None:
        with self._lock:
            self._workers.append(w)

    def remove_worker(self, w: ActorRef) -> None:
        with self._lock:
            self._workers = [x for x in self._workers if x.actor_id != w.actor_id]

    @property
    def workers(self) -> list[ActorRef]:
        return list(self._workers)

    # -- placement ------------------------------------------------------
    def _take_pending(self, pending: list, worker: ActorRef) -> "WorkItem":
        """Placement- and deadline-aware pop.

        Candidate set first (zero-copy preference unchanged): chunks whose
        DeviceRef payload is already resident on ``worker``'s device, then
        chunks with no device affinity, else everything. Within the
        candidate set the pick is earliest-deadline-first (chunks without
        a deadline sort last), falling back to FIFO on ties — so an
        SLO-bound serve batch jumps the queue without ever stealing a
        resident chunk from its device."""

        def edf(indices) -> "WorkItem":
            best = min(indices, key=lambda i: (
                pending[i].deadline if pending[i].deadline is not None
                else float("inf"), i))
            return pending.pop(best)

        dev = self._placements.get(worker.actor_id)
        jd = getattr(dev, "jax_device", None) if dev is not None else None
        if jd is None and not self._placements:
            return edf(range(len(pending)))
        # residency classification is the placement service's call — the
        # same cost source pools and graphs rank by
        local, neutral = placement_service().classify_chunks(
            [item.payload for item in pending], jd)
        if local:
            return edf(local)
        if neutral:
            return edf(neutral)
        return edf(range(len(pending)))

    # -- execution ------------------------------------------------------
    def run(self, payloads: Sequence[tuple],
            timeout: Optional[float] = 300.0,
            deadlines: Optional[Sequence[Optional[float]]] = None) -> list:
        """Execute every payload on some worker; returns ordered results.

        ``deadlines`` (one absolute ``time.monotonic`` value or None per
        payload) makes the pick earliest-deadline-first and sheds chunks
        whose deadline already passed before dispatch — those surface as
        :class:`~repro.core.errors.DeadlineExceeded`.
        """
        if deadlines is not None and len(deadlines) != len(payloads):
            raise ValueError("one deadline (or None) per payload")
        items = [WorkItem(i, p, deadlines[i] if deadlines else None)
                 for i, p in enumerate(payloads)]
        pending = list(items)            # not yet issued (FIFO)
        outstanding: dict[int, WorkItem] = {}
        remaining = len(items)
        durations: list[float] = []
        idle: list[ActorRef] = [w for w in self._workers if w.is_alive()]
        if not idle:
            raise RuntimeError("no live workers")
        deadline = None if timeout is None else time.monotonic() + timeout

        inflight = 0                     # issued requests awaiting callback

        def issue(worker: ActorRef, item: WorkItem, speculative: bool) -> None:
            nonlocal inflight
            item.attempts += 1
            item.issued_at = time.monotonic()
            self.stats["dispatched"] += 1
            if speculative:
                self.stats["speculative"] += 1
            inflight += 1
            fut = worker.request(*item.payload)
            fut.add_done_callback(lambda f: on_done(worker, item, f))

        def on_done(worker: ActorRef, item: WorkItem, fut: Future) -> None:
            nonlocal remaining, inflight
            with self._cv:
                inflight -= 1
                failed = fut.exception() is not None
                if failed:
                    self.stats["failed"] += 1
                    if worker.is_alive():
                        idle.append(worker)
                    if not item.done:
                        outstanding.pop(item.index, None)
                        if item.attempts >= self.max_attempts:
                            # permanently failed: record the exception so
                            # run() surfaces it, and stop waiting on it
                            item.done = True
                            item.result = fut.exception()
                            remaining -= 1
                        else:
                            pending.insert(0, item)  # retry soon
                else:
                    durations.append(time.monotonic() - item.issued_at)
                    if not item.done:  # first completion wins
                        item.done = True
                        item.result = fut.result()
                        outstanding.pop(item.index, None)
                        remaining -= 1
                    elif isinstance(item.result, BaseException):
                        # a speculative copy outlived a recorded permanent
                        # failure: prefer the successful result
                        item.result = fut.result()
                    else:
                        # duplicate success from a speculative race: the
                        # loser's DeviceRefs would stay registered forever
                        # (inflating live-bytes placement signals) if
                        # simply dropped
                        tree_release(fut.result())
                    idle.append(worker)
                self._cv.notify_all()

        with self._cv:
            while remaining > 0:
                # issue fresh work
                while pending and idle:
                    w = idle.pop()
                    if not w.is_alive():
                        continue
                    item = self._take_pending(pending, w)
                    if item.done:
                        idle.append(w)  # keep the worker available
                        continue
                    if item.deadline is not None \
                            and time.monotonic() > item.deadline:
                        # shed before dispatch: the deadline already passed,
                        # running it would only waste device time
                        self.stats["expired"] += 1
                        item.done = True
                        item.result = DeadlineExceeded(
                            f"chunk {item.index} missed its deadline "
                            "before dispatch")
                        remaining -= 1
                        idle.append(w)
                        continue
                    outstanding[item.index] = item
                    issue(w, item, speculative=False)
                # speculative re-issue for stragglers
                if not pending and idle and outstanding and durations:
                    med = sorted(durations)[len(durations) // 2]
                    now = time.monotonic()
                    for item in sorted(outstanding.values(), key=lambda x: x.issued_at):
                        if not idle:
                            break
                        if (now - item.issued_at) > self.straggler_factor * max(med, 1e-4) \
                                and item.attempts < self.max_attempts:
                            w = idle.pop()
                            if w.is_alive():
                                issue(w, item, speculative=True)
                if remaining == 0:
                    break
                if pending and not outstanding and inflight == 0 \
                        and not any(w.is_alive() for w in self._workers):
                    # every worker died (e.g. a poison chunk killed the
                    # whole pool): nothing can ever complete — fail fast
                    # instead of spinning until the timeout
                    raise RuntimeError(
                        f"no live workers remain; {len(pending)} chunks "
                        "undispatchable")
                wait_for = 0.05
                if deadline is not None:
                    wait_for = min(wait_for, deadline - time.monotonic())
                    if wait_for <= 0:
                        raise TimeoutError(
                            f"{remaining} chunks unfinished after {timeout}s "
                            f"(outstanding: {sorted(outstanding)}, "
                            f"pending: {len(pending)}, "
                            f"live workers: "
                            f"{sum(w.is_alive() for w in self._workers)}"
                            f"/{len(self._workers)})")
                self._cv.wait(timeout=wait_for)

            # drain callbacks for requests still in flight (speculative
            # duplicates, late failures) so stats — and any success that
            # should override a recorded permanent failure — are settled
            # before results are assembled
            drain_deadline = time.monotonic() + self.drain_grace
            if deadline is not None:
                drain_deadline = min(drain_deadline, deadline)
            while inflight > 0:
                wait_for = drain_deadline - time.monotonic()
                if wait_for <= 0:
                    break
                self._cv.wait(timeout=min(wait_for, 0.05))

        results = []
        for item in items:
            if isinstance(item.result, BaseException):
                raise item.result
            results.append(item.result)
        return results
