"""Device discovery and program bookkeeping (paper Fig. 2: manager /
platform / device / program).

* ``Platform`` wraps a JAX backend (the analogue of an OpenCL platform —
  an entry point provided by a driver).
* ``Device`` wraps a ``jax.Device`` and tracks an outstanding-dispatch
  counter, the analogue of the per-device command queue.
* ``Program`` maps kernel names to compiled callables. OpenCL compiles C
  source at runtime; the JAX analogue is trace-and-compile at first use,
  with the lowered/compiled executable cached per (name, shapes, device).
* ``DeviceManager`` is the ``actor_system`` module that "performs platform
  discovery lazily on first access and offers an interface to spawn OpenCL
  actors" (paper §3.2).
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, Optional, Sequence

import jax

from ..analysis.runtime import make_lock
from .signature import NDRange

__all__ = ["Platform", "Device", "Program", "DeviceManager"]


class Device:
    """An accelerator device with a dispatch (command-queue) counter and
    live-memory watermarks (fed by the DeviceRef registry)."""

    def __init__(self, jax_device: jax.Device, platform: "Platform"):
        self.jax_device = jax_device
        self.platform = platform
        self._inflight = 0
        self._lock = make_lock("Device")

    @property
    def name(self) -> str:
        return f"{self.jax_device.platform}:{self.jax_device.id}"

    @property
    def device_kind(self) -> str:
        return self.jax_device.device_kind

    def queue_depth(self) -> int:
        return self._inflight

    # -- memory watermarks (DeviceRef registry) -------------------------------
    def live_bytes(self) -> int:
        """Bytes currently held by live DeviceRefs on this device."""
        from .memref import registry
        return registry.live_bytes(self.jax_device)

    def peak_bytes(self) -> int:
        """High watermark of DeviceRef bytes ever resident on this device."""
        from .memref import registry
        return registry.peak_bytes(self.jax_device)

    def page_stats(self) -> dict:
        """KV page-pool pressure on this device (aggregated over every
        :class:`repro.serve.kvpool.PagePool` allocated here): capacity,
        live/free/shared pages, and the fragmentation ratio."""
        from .memref import registry
        return registry.page_stats(self.jax_device)

    def _dispatch_started(self):
        with self._lock:
            self._inflight += 1

    def _dispatch_finished(self):
        with self._lock:
            self._inflight -= 1

    def __repr__(self):
        return (f"Device({self.name}, inflight={self._inflight}, "
                f"live_bytes={self.live_bytes()})")


class Platform:
    def __init__(self, backend: str, devices: Sequence[jax.Device]):
        self.name = backend
        self.devices = [Device(d, self) for d in devices]

    def __repr__(self):
        return f"Platform({self.name}, {len(self.devices)} devices)"


class Program:
    """Named kernels + per-shape compiled-executable cache.

    ``kernels`` maps a kernel name to a traceable callable. ``retrieve``
    mirrors ``clCreateKernel``-by-name; ``compiled`` caches executables the
    way OpenCL caches ``cl_program`` binaries per device.
    """

    def __init__(self, kernels: Dict[str, Callable], device: Optional[Device] = None,
                 options: Optional[Dict[str, Any]] = None):
        self.kernels = dict(kernels)
        self.device = device
        self.options = dict(options or {})
        self._cache: Dict[Any, Any] = {}
        self._lock = make_lock("Program")

    def retrieve(self, name: str) -> Callable:
        try:
            return self.kernels[name]
        except KeyError:
            raise KeyError(f"program has no kernel named {name!r}; "
                           f"available: {sorted(self.kernels)}") from None

    def compiled(self, key: Any, build: Callable[[], Any]) -> Any:
        with self._lock:
            if key not in self._cache:
                self._cache[key] = build()
            return self._cache[key]


class DeviceManager:
    """Lazily discovers platforms and spawns kernel actors (paper §3.2)."""

    def __init__(self, system):
        self.system = system
        self._platforms: Optional[list[Platform]] = None
        self._lock = make_lock("DeviceManager")

    # -- discovery ------------------------------------------------------
    @property
    def platforms(self) -> list[Platform]:
        with self._lock:
            if self._platforms is None:
                self._platforms = self._discover()
            return self._platforms

    def _discover(self) -> list[Platform]:
        by_backend: Dict[str, list] = {}
        for d in jax.devices():
            by_backend.setdefault(d.platform, []).append(d)
        return [Platform(k, v) for k, v in sorted(by_backend.items())]

    def devices(self) -> list[Device]:
        return [d for p in self.platforms for d in p.devices]

    def find_device(self, *, platform: Optional[str] = None, index: int = 0) -> Device:
        """Default binding is the first discovered device (paper §3.6)."""
        devs = self.devices()
        if platform is not None:
            devs = [d for d in devs if d.jax_device.platform == platform]
        if not devs:
            raise LookupError(f"no device for platform={platform!r}")
        return devs[index]

    def memory_stats(self) -> Dict[str, Dict[str, Any]]:
        """Per-device memory watermarks: live DeviceRef bytes, the peak
        (high watermark), current dispatch queue depth — the signals the
        pool's least-loaded policy ranks by — plus KV page-pool pressure
        (``pages_total``/``pages_free``/``pages_shared`` and the
        fragmentation ratio) wherever a ``repro.serve.kvpool.PagePool``
        lives on the device."""
        out: Dict[str, Dict[str, Any]] = {}
        for d in self.devices():
            ps = d.page_stats()
            out[d.name] = {"live_bytes": d.live_bytes(),
                           "peak_bytes": d.peak_bytes(),
                           "queue_depth": d.queue_depth(),
                           "pages_total": ps["pages_total"],
                           "pages_free": ps["pages_free"],
                           "pages_shared": ps["pages_shared"],
                           "fragmentation": ps["fragmentation"]}
        return out

    def pick_device(self, *, context: str = "manager") -> Device:
        """Cost-ranked device choice through the process-wide
        :class:`~repro.core.placement.PlacementService` (least live
        DeviceRef bytes, then queue depth, deterministic name tie-break)
        — the load-aware counterpart of :meth:`find_device`'s static
        first-discovered binding. The decision lands in the service's
        audit ring like every other placement."""
        from .placement import service as placement_service
        return placement_service().pick_device(self.devices(),
                                               context=context).chosen

    # -- program / actor creation -------------------------------------------
    def create_program(self, kernels: Dict[str, Callable],
                       device: Optional[Device] = None, **options) -> Program:
        return Program(kernels, device or self.find_device(), options)

    def spawn(self, source, name: Optional[str] = None,
              nd_range: Optional[NDRange] = None, *specs, **kwargs):
        """Spawn an OpenCL actor (paper Listing 2/3/5).

        v2 form: ``source`` is a :func:`repro.core.kernel`-decorated
        callable (a :class:`~repro.core.api.KernelDecl`) that already
        carries its signature and ND-range; ``name``/``nd_range`` and a
        ``device=`` keyword act as per-spawn overrides.

        v1 form (deprecated shim, kept so existing callers don't break):
        ``source`` is a traceable callable (the JAX stand-in for OpenCL C
        source) or a :class:`Program` plus positional ``name``,
        ``nd_range``, and ``*specs``. Optional ``preprocess``/
        ``postprocess`` keyword arguments mirror the paper's conversion
        functions in both forms.
        """
        from .api import KernelDecl     # local import: avoid cycle
        from .facade import KernelActor
        if isinstance(source, KernelDecl):
            decl = source
            overrides = {}
            if name is not None:
                overrides["name"] = name
            if nd_range is not None:
                overrides["nd_range"] = nd_range
            if specs:
                overrides["specs"] = specs
            for opt in ("preprocess", "postprocess", "donate"):
                if opt in kwargs:
                    overrides[opt] = kwargs.pop(opt)
            if overrides:
                decl = decl.with_options(**overrides)
            device = kwargs.pop("device", None) or self.find_device()
            lazy_init = kwargs.pop("lazy_init", True)
            emit = kwargs.pop("emit", "declared")
            if kwargs:
                raise TypeError(f"unknown spawn options: {sorted(kwargs)}")
            actor = KernelActor(fn=decl.fn, name=decl.name,
                                nd_range=decl.nd_range, specs=decl.specs,
                                device=device, program=None,
                                preprocess=decl.preprocess,
                                postprocess=decl.postprocess,
                                donate=decl.donate, emit=emit)
            return self.system.spawn(actor, lazy_init=lazy_init)
        warnings.warn(
            "positional DeviceManager.spawn(source, name, nd_range, *specs) "
            "is deprecated; declare kernels with @repro.core.kernel",
            PendingDeprecationWarning, stacklevel=2)
        if isinstance(source, Program):
            program, fn = source, source.retrieve(name)
            device = kwargs.pop("device", None) or program.device or self.find_device()
        else:
            if not callable(source):
                raise TypeError("source must be a callable or Program")
            program, fn = None, source
            device = kwargs.pop("device", None) or self.find_device()
        actor = KernelActor(fn=fn, name=name or getattr(fn, "__name__", "kernel"),
                            nd_range=nd_range, specs=specs, device=device,
                            program=program, **kwargs)
        return self.system.spawn(actor)

    def spawn_pool(self, source, n: int, *, policy: str = "round_robin",
                   devices: Optional[Sequence[Device]] = None,
                   default_timeout: Optional[float] = 120.0, **kwargs):
        """Spawn ``n`` replicas of a kernel behind one pool ref.

        Replicas are placed round-robin over ``devices`` (default: every
        discovered device); the returned :class:`~repro.core.api.ActorPool`
        routes per ``policy`` ("round_robin" | "least_loaded", the latter
        keyed on outstanding requests then ``Device.queue_depth()``) and
        plugs into :class:`~repro.core.scheduler.ChunkScheduler`.
        ``default_timeout`` becomes the pool's ``ask`` timeout (None =
        wait forever).
        """
        from .api import ActorPool
        if n < 1:
            raise ValueError("pool size must be >= 1")
        devs = list(devices) if devices else self.devices()
        refs, placed = [], []
        for i in range(n):
            dev = devs[i % len(devs)]
            refs.append(self.spawn(source, device=dev, **kwargs))
            placed.append(dev)
        return ActorPool(self.system, refs, policy=policy, devices=placed,
                         default_timeout=default_timeout)
