"""Cluster-wide placement: one cost model, one decision point (§3, §5.2).

The paper's promise is that the *runtime* decides where data-parallel work
lands. As the reproduction grew, that decision scattered into five private
policies: :meth:`Graph._place` ranked by live DeviceRef bytes,
:meth:`ActorPool._pick` by payload residency, ``ChunkScheduler`` kept its
own preferred-candidate sets, ``MeshRouter`` used EWMA×inflight, and
``repro.net`` int8-compressed at whatever boundary it happened to cross.
This module unifies them behind a single process-wide
:class:`PlacementService` that owns

* the **device cost source** — per-device live/peak bytes and queue depth
  (read straight from :class:`~repro.core.memref.RefRegistry` through the
  :class:`~repro.core.manager.Device` wrappers),
* the **wire cost source** — a :class:`WireCostModel` of per-hop latency
  and bytes-on-wire for raw vs int8 transfers, seeded from BENCH_PR5's
  measured numbers and refined online from observed ``repro.net``
  round-trips (:meth:`PlacementService.observe_hop`), and
* the **replica cost source** — mesh load snapshots fed in through
  :meth:`PlacementService.observe_replica`.

Every query returns an auditable :class:`PlacementDecision` carrying the
chosen target, the scored losing alternatives, and the cost terms that
produced each score; the service keeps a bounded ring of recent decisions
(:meth:`PlacementService.decisions`) so placement behavior is testable and
debuggable in one place with a fake cost table — no multi-process setup
needed.

Lock discipline: the service lock ranks between ``DeviceManager`` and the
``RefRegistry`` leaf (see ``repro/analysis/ORDER.md``) — every dispatcher
(pool, scheduler, router, node runtime) may call in while holding its own
lock, and ranking reads device live-bytes through the registry while the
service lock is held.
"""
from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..analysis.runtime import make_lock
from .memref import payload_device

__all__ = [
    "WireCostModel", "PlacementDecision", "ScoredAlternative",
    "NodeTarget", "GraphSite", "PlacementService", "service", "set_service",
]


# ----------------------------------------------------------------------------
# wire cost model
# ----------------------------------------------------------------------------
class WireCostModel:
    """Per-hop cost of moving a payload across ``repro.net``.

    A hop costs ``latency + wire_bytes / throughput``; int8 compression
    shrinks ``wire_bytes`` by :attr:`int8_ratio` at the price of a
    quantize/dequantize pass (:attr:`compress_overhead_s` plus a
    throughput term). The defaults are seeded from the BENCH_PR5
    measurements (localhost socket pair, in-process nodes): the n=1024
    round trip pins the base latency, the n=262144 one the throughput,
    and the measured ``wire_raw/wire_int8`` ratio converges on 4.0.

    :meth:`observe` refines the estimate online from real transfer
    timings — small payloads update the latency EWMA, large ones the
    throughput EWMA, optionally per peer. Observed round-trips include
    the remote compute, so they are treated as upper bounds smoothed with
    a small ``alpha`` rather than ground truth.

    Instances are plain mutable state; concurrent mutation goes through
    the owning :class:`PlacementService`'s lock.
    """

    #: payloads at or below this many bytes are latency probes
    SMALL_BYTES = 4096

    def __init__(self, *, latency_s: float = 4.5e-3,
                 bytes_per_s: float = 100e6, int8_ratio: float = 4.0,
                 compress_overhead_s: float = 3e-4,
                 compress_bytes_per_s: float = 1e9,
                 envelope_bytes: int = 256,
                 min_compress_bytes: int = 1024,
                 alpha: float = 0.2):
        self.latency_s = float(latency_s)
        self.bytes_per_s = float(bytes_per_s)
        self.int8_ratio = float(int8_ratio)
        self.compress_overhead_s = float(compress_overhead_s)
        self.compress_bytes_per_s = float(compress_bytes_per_s)
        self.envelope_bytes = int(envelope_bytes)
        self.min_compress_bytes = int(min_compress_bytes)
        self.alpha = float(alpha)
        #: peer -> [latency_s, bytes_per_s] learned from observations
        self._peer: Dict[str, List[float]] = {}
        self.observations = 0

    # -- seeding -----------------------------------------------------------
    @classmethod
    def from_bench(cls, data, **overrides) -> "WireCostModel":
        """Seed a model from a BENCH_PR5-style snapshot: a dict (or path
        to a JSON file) whose ``"sizes"`` section maps ``n<N>`` entries to
        ``remote_hop_us`` / ``wire_raw_bytes`` / ``wire_int8_bytes`` /
        ``compression_ratio``. The smallest size pins latency, the
        largest pins throughput."""
        if isinstance(data, (str, bytes)):
            with open(data, "r", encoding="utf-8") as fh:
                data = json.load(fh)
        sizes = data.get("sizes", data)
        rows = sorted(sizes.values(), key=lambda r: r["wire_raw_bytes"])
        if not rows:
            return cls(**overrides)
        small, big = rows[0], rows[-1]
        kw: Dict[str, Any] = {}
        kw["latency_s"] = small["remote_hop_us"] * 1e-6
        span_s = (big["remote_hop_us"] - small["remote_hop_us"]) * 1e-6
        span_b = big["wire_raw_bytes"] - small["wire_raw_bytes"]
        if span_s > 0 and span_b > 0:
            kw["bytes_per_s"] = span_b / span_s
        ratios = [r["compression_ratio"] for r in rows
                  if r.get("compression_ratio")]
        if ratios:
            kw["int8_ratio"] = max(ratios)
        kw.update(overrides)
        return cls(**kw)

    # -- queries -----------------------------------------------------------
    def _params(self, peer: Optional[str]) -> Tuple[float, float]:
        if peer is not None and peer in self._peer:
            return tuple(self._peer[peer])  # type: ignore[return-value]
        return self.latency_s, self.bytes_per_s

    def wire_bytes(self, nbytes: int, compressed: bool) -> int:
        """Bytes a payload of ``nbytes`` occupies on the wire."""
        body = int(nbytes / self.int8_ratio) if compressed else int(nbytes)
        return body + self.envelope_bytes

    def hop_seconds(self, nbytes: int, compressed: bool = False,
                    peer: Optional[str] = None) -> float:
        """Estimated one-way cost of shipping ``nbytes`` to ``peer``."""
        lat, bps = self._params(peer)
        s = lat + self.wire_bytes(nbytes, compressed) / bps
        if compressed:
            s += self.compress_overhead_s + nbytes / self.compress_bytes_per_s
        return s

    def round_trip_seconds(self, in_bytes: int, out_bytes: int, *,
                           allow_compress: bool = False,
                           peer: Optional[str] = None
                           ) -> Tuple[float, str]:
        """Cheapest request+reply cost and the encoding that achieves it
        (``"raw"`` or ``"int8"``)."""
        raw = (self.hop_seconds(in_bytes, False, peer)
               + self.hop_seconds(out_bytes, False, peer))
        if not allow_compress:
            return raw, "raw"
        c = (self.hop_seconds(in_bytes, True, peer)
             + self.hop_seconds(out_bytes, True, peer))
        return (c, "int8") if c < raw else (raw, "raw")

    def amortizes(self, nbytes: int, peer: Optional[str] = None) -> bool:
        """Does int8 compression pay for itself on this hop?"""
        return (self.hop_seconds(nbytes, True, peer)
                < self.hop_seconds(nbytes, False, peer))

    def choose_compress(self, nbytes: int,
                        peer: Optional[str] = None) -> bool:
        """The wire-boundary decision ``repro.net`` delegates here when a
        node is configured with ``compress="auto"``."""
        return nbytes >= self.min_compress_bytes and \
            self.amortizes(nbytes, peer)

    # -- online refinement -------------------------------------------------
    def observe(self, nbytes: int, seconds: float, *,
                compressed: bool = False,
                peer: Optional[str] = None) -> None:
        """Fold one observed round-trip into the estimate."""
        if seconds <= 0:
            return
        self.observations += 1
        a = self.alpha
        if peer is not None and peer not in self._peer:
            self._peer[peer] = [self.latency_s, self.bytes_per_s]
        cells = ([self._peer[peer]] if peer is not None else []) or []
        if nbytes <= self.SMALL_BYTES:
            self.latency_s += a * (seconds - self.latency_s)
            for c in cells:
                c[0] += a * (seconds - c[0])
        else:
            lat = self.latency_s
            wire = self.wire_bytes(nbytes, compressed)
            rate = wire / max(seconds - lat, 1e-6)
            self.bytes_per_s += a * (rate - self.bytes_per_s)
            for c in cells:
                c[1] += a * (rate - c[1])

    def snapshot(self) -> dict:
        return {"latency_s": self.latency_s, "bytes_per_s": self.bytes_per_s,
                "int8_ratio": self.int8_ratio,
                "observations": self.observations,
                "peers": {p: {"latency_s": v[0], "bytes_per_s": v[1]}
                          for p, v in self._peer.items()}}


# ----------------------------------------------------------------------------
# decisions
# ----------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ScoredAlternative:
    """One candidate the service considered, with its score and the cost
    terms that produced it (lower cost wins)."""

    target: str
    cost: Any
    terms: Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class PlacementDecision:
    """The auditable outcome of one placement query.

    ``chosen`` is the picked object (a ``Device``, worker id, replica key,
    or :class:`NodeTarget`); ``target`` is its display name;
    ``alternatives`` are *all* scored candidates including the winner, so
    a losing candidate's terms are always reconstructible from the
    record."""

    context: str
    target: str
    chosen: Any
    cost: Any
    terms: Dict[str, Any]
    alternatives: Tuple[ScoredAlternative, ...]
    reason: str = ""

    def explain(self) -> str:
        alts = ", ".join(f"{a.target}={a.cost}" for a in self.alternatives)
        return (f"[{self.context}] -> {self.target} ({self.reason}; "
                f"cost={self.cost}; considered: {alts or 'none'})")


# ----------------------------------------------------------------------------
# remote placement targets
# ----------------------------------------------------------------------------
class NodeTarget:
    """A remote node as a graph-placement candidate.

    Wraps a :class:`~repro.net.NodeRuntime` and the name of a connected
    peer; :meth:`spawn` lands a kernel declaration in the peer's actor
    system via ``spawn_remote`` and returns the network-transparent
    handle, so a remotely placed graph node needs no data-path changes —
    requests auto-spill at the wire and replies unspill onto the driver's
    device like any other remote interaction."""

    def __init__(self, node, peer: str, *, load_s: float = 0.0):
        self.node = node
        self.peer = peer
        #: static load hint in seconds, superseded by live replica
        #: snapshots the service has for this peer
        self.static_load_s = float(load_s)

    @property
    def name(self) -> str:
        return f"node:{self.peer}"

    @property
    def allows_compress(self) -> bool:
        """May the hop use the int8 wire format? True when the wrapped
        node compresses (``compress=True``) or lets the cost model decide
        per payload (``compress="auto"``)."""
        return bool(getattr(self.node, "compress", False))

    def spawn(self, decl, **kwargs):
        return self.node.spawn_remote(self.peer, decl, spawn_kwargs=kwargs)

    def __repr__(self):
        return f"NodeTarget({self.peer!r})"


@dataclasses.dataclass
class GraphSite:
    """What :meth:`Graph.build` tells the service about one placeable
    node: identity, any pinned device, which upstream nodes feed it, and
    the typed edge sizes a wire-cost estimate needs. ``in_bytes`` /
    ``out_bytes`` are None when a port is untyped — an unknown edge is
    never routed over the wire."""

    idx: int
    path: str
    pinned: Any = None
    #: pinned-only nodes (existing actor refs) never fall through to
    #: cost-ranked placement — they already live somewhere
    fixed: bool = False
    producers: Tuple[int, ...] = ()
    in_bytes: Optional[int] = None
    out_bytes: Optional[int] = None
    remote_ok: bool = False


# ----------------------------------------------------------------------------
# the service
# ----------------------------------------------------------------------------
class PlacementService:
    """Process-wide placement authority; see module doc.

    Cost knobs (all injectable for tests):

    * ``dispatch_s`` — estimated seconds a queued dispatch ahead of us
      costs (seeded from BENCH_PR5's ~300 µs local hop).
    * ``mem_s_per_byte`` — pressure penalty per live byte on a device: a
      loaded device keeps winning until its watermark, not forever.
    * ``host_bytes_per_s`` — intra-host device-to-device copy throughput,
      charged when a node lands off its producer's device.
    * ``wire`` — the :class:`WireCostModel` for cross-node hops.
    """

    def __init__(self, *, wire: Optional[WireCostModel] = None,
                 dispatch_s: float = 3e-4,
                 mem_s_per_byte: float = 1e-12,
                 host_bytes_per_s: float = 10e9,
                 audit: int = 256):
        self.wire = wire if wire is not None else WireCostModel()
        self.dispatch_s = float(dispatch_s)
        self.mem_s_per_byte = float(mem_s_per_byte)
        self.host_bytes_per_s = float(host_bytes_per_s)
        self._lock = make_lock("PlacementService")
        self._decisions: deque = deque(maxlen=max(1, int(audit)))
        #: replica key -> latest load snapshot (a mesh cost source)
        self._replica_load: Dict[str, Dict[str, Any]] = {}
        #: peer name -> expected queue wait seconds, from replica feeds
        self._peer_load_s: Dict[str, float] = {}

    # -- audit -------------------------------------------------------------
    def _record(self, decision: PlacementDecision) -> PlacementDecision:
        self._decisions.append(decision)
        return decision

    def decisions(self, context: Optional[str] = None
                  ) -> List[PlacementDecision]:
        """Recent decisions, newest last; ``context`` filters by prefix
        (e.g. ``"graph"``, ``"pool"``, ``"mesh"``)."""
        with self._lock:
            snap = list(self._decisions)
        if context is None:
            return snap
        return [d for d in snap if d.context.startswith(context)]

    def clear_decisions(self) -> None:
        with self._lock:
            self._decisions.clear()

    # -- shared device scoring --------------------------------------------
    @staticmethod
    def _device_terms(dev) -> Dict[str, Any]:
        return {"live_bytes": dev.live_bytes(),
                "queue_depth": dev.queue_depth()}

    def _device_seconds(self, terms: Dict[str, Any]) -> float:
        return (terms["queue_depth"] * self.dispatch_s
                + terms["live_bytes"] * self.mem_s_per_byte)

    # -- pool / worker ranking --------------------------------------------
    def rank(self, candidates: Sequence[Tuple[Any, Any]],
             payload: tuple = (), *,
             outstanding: Optional[Dict[Any, int]] = None,
             policy: str = "least_loaded",
             rr_tick: Optional[Callable[[], int]] = None,
             context: str = "pool") -> PlacementDecision:
        """Rank worker ``(key, device)`` candidates for one payload —
        the query :class:`~repro.core.api.ActorPool` routes through.

        Residency first: when the payload carries a resident
        :class:`~repro.core.memref.DeviceRef`, workers on that device are
        preferred (zero-copy dispatch) and load-ranked among themselves.
        ``least_loaded`` then orders by (outstanding, queue depth, live
        bytes); ``round_robin`` with no residency match cycles via
        ``rr_tick`` (called only when actually cycling, preserving the
        pool's rotation semantics). Ties keep candidate order, so equal
        workers behave exactly as the pre-service pools did."""
        if not candidates:
            raise ValueError("rank() needs at least one candidate")
        outstanding = outstanding or {}
        pref = payload_device(payload)
        idx = list(range(len(candidates)))
        matched = False
        if pref is not None:
            local = [i for i in idx
                     if (d := candidates[i][1]) is not None
                     and d.jax_device == pref]
            if local:
                idx, matched = local, True

        def terms_of(i: int) -> Dict[str, Any]:
            key, dev = candidates[i]
            t = {"outstanding": outstanding.get(key, 0),
                 "queue_depth": dev.queue_depth() if dev is not None else 0,
                 "live_bytes": dev.live_bytes() if dev is not None else 0,
                 "resident": matched}
            return t

        with self._lock:
            if policy == "round_robin" and not matched:
                tick = rr_tick() if rr_tick is not None else 0
                pick = idx[tick % len(idx)]
                key, _ = candidates[pick]
                alts = tuple(
                    ScoredAlternative(str(candidates[i][0]), i == pick,
                                      {"round_robin": True}) for i in idx)
                return self._record(PlacementDecision(
                    context=context, target=str(key), chosen=key,
                    cost=tick % len(idx), terms={"round_robin": True},
                    alternatives=alts, reason="round-robin"))
            scored = [(terms_of(i), i) for i in idx]
            best_terms, best = min(
                scored, key=lambda ti: (ti[0]["outstanding"],
                                        ti[0]["queue_depth"],
                                        ti[0]["live_bytes"], ti[1]))
            key, _ = candidates[best]
            alts = tuple(ScoredAlternative(
                str(candidates[i][0]),
                (t["outstanding"], t["queue_depth"], t["live_bytes"]), t)
                for t, i in scored)
            return self._record(PlacementDecision(
                context=context, target=str(key), chosen=key,
                cost=(best_terms["outstanding"], best_terms["queue_depth"],
                      best_terms["live_bytes"]),
                terms=best_terms, alternatives=alts,
                reason="residency" if matched else "least-loaded"))

    # -- bare device ranking ----------------------------------------------
    def pick_device(self, devices: Sequence[Any], *,
                    context: str = "device") -> PlacementDecision:
        """Least-loaded device by (live bytes, queue depth), tie-broken
        deterministically by device name — the fallback
        :meth:`Graph.build` and the serve engine use."""
        if not devices:
            raise LookupError("no devices to place on")
        with self._lock:
            scored = [(self._device_terms(d), d) for d in devices]
            terms, dev = min(scored, key=lambda td: (
                td[0]["live_bytes"], td[0]["queue_depth"], td[1].name))
            alts = tuple(ScoredAlternative(
                d.name, (t["live_bytes"], t["queue_depth"]), t)
                for t, d in scored)
            return self._record(PlacementDecision(
                context=context, target=dev.name, chosen=dev,
                cost=(terms["live_bytes"], terms["queue_depth"]),
                terms=terms, alternatives=alts, reason="least-loaded"))

    # -- chunk-scheduler candidate classes --------------------------------
    def classify_chunks(self, payloads: Sequence[tuple], jax_device
                        ) -> Tuple[List[int], List[int]]:
        """Partition pending chunk indices for a worker on ``jax_device``
        into (resident-local, no-affinity) — the candidate classes
        :class:`~repro.core.scheduler.ChunkScheduler` pops from, in
        preference order; everything else stays a last resort."""
        local: List[int] = []
        neutral: List[int] = []
        for i, payload in enumerate(payloads):
            pd = payload_device(payload)
            if pd is None:
                neutral.append(i)
            elif jax_device is not None and pd == jax_device:
                local.append(i)
        return local, neutral

    # -- mesh replica ranking ---------------------------------------------
    def rank_replicas(self, snapshots: Sequence[Tuple[str, float, int]], *,
                      context: str = "mesh") -> PlacementDecision:
        """Least expected wait over ``(key, wait_s, inflight)`` replica
        snapshots: the polled EWMA queue wait scaled by the router's own
        outstanding fan-in (EWMA alone is stale between polls; inflight
        is always current). Ties keep snapshot order."""
        if not snapshots:
            raise ValueError("rank_replicas() needs at least one snapshot")

        def score(s: Tuple[str, float, int]) -> float:
            _, wait_s, inflight = s
            return (wait_s + 1e-3) * (1 + inflight)

        with self._lock:
            best_i = min(range(len(snapshots)),
                         key=lambda i: (score(snapshots[i]), i))
            key, wait_s, inflight = snapshots[best_i]
            alts = tuple(ScoredAlternative(
                k, score((k, w, f)), {"wait_s": w, "inflight": f})
                for k, w, f in snapshots)
            return self._record(PlacementDecision(
                context=context, target=key, chosen=key,
                cost=score(snapshots[best_i]),
                terms={"wait_s": wait_s, "inflight": inflight},
                alternatives=alts, reason="least-expected-wait"))

    # -- cost-source feeds -------------------------------------------------
    def observe_replica(self, key: str, wait_s: float, inflight: int, *,
                        peer: Optional[str] = None,
                        load: Optional[Dict[str, Any]] = None) -> None:
        """Mesh routers feed replica load snapshots here; per-peer
        expected waits become the remote load term in
        :meth:`place_graph`."""
        with self._lock:
            self._replica_load[key] = {"wait_s": wait_s,
                                       "inflight": inflight, "peer": peer,
                                       **(load or {})}
            if peer is not None:
                self._peer_load_s[peer] = (wait_s + 1e-3) * (1 + inflight)

    def observe_hop(self, peer: Optional[str], nbytes: int,
                    seconds: float, *, compressed: bool = False) -> None:
        """``repro.net`` reports observed request round-trips here; the
        wire model refines its latency/throughput estimates from them."""
        with self._lock:
            self.wire.observe(nbytes, seconds, compressed=compressed,
                              peer=peer)

    def choose_compress(self, nbytes: int,
                        peer: Optional[str] = None) -> bool:
        """Per-payload wire-format decision for ``compress="auto"``."""
        with self._lock:
            return self.wire.choose_compress(nbytes, peer)

    def peer_load_s(self, peer: str) -> float:
        with self._lock:
            return self._peer_load_s.get(peer, 0.0)

    def replica_load(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            return dict(self._replica_load)

    # -- whole-DAG placement ----------------------------------------------
    def place_graph(self, sites: Sequence[GraphSite],
                    devices: Sequence[Any],
                    remotes: Sequence[NodeTarget] = (), *,
                    context: str = "graph"
                    ) -> Tuple[Dict[int, Any], List[PlacementDecision]]:
        """Place a topologically ordered DAG over local devices and
        remote nodes.

        Per site, in order: an explicitly pinned device wins outright;
        otherwise the local candidate is the first placed upstream
        producer's device (inheritance: zero-move) or the least-loaded
        device, and every :class:`NodeTarget` is scored as estimated
        seconds — peer load plus the request/reply wire round trip at the
        site's typed edge sizes, using the cheaper of raw or int8 when
        the target's node allows compression. A cross-node edge is chosen
        only when that total undercuts the local candidate — i.e. only
        where compression (or a genuinely idle peer) amortizes the hop.
        Sites with untyped edges never go remote."""
        placements: Dict[int, Any] = {}
        out: List[PlacementDecision] = []
        with self._lock:
            for site in sites:
                d = self._place_site(site, placements, devices, remotes,
                                     context)
                if d is None:
                    continue
                out.append(self._record(d))
                if d.chosen is not None:
                    placements[site.idx] = d.chosen
        return placements, out

    def _place_site(self, site: GraphSite, placements: Dict[int, Any],
                    devices: Sequence[Any], remotes: Sequence[NodeTarget],
                    context: str) -> Optional[PlacementDecision]:
        ctx = f"{context}:{site.path}"
        if site.pinned is not None or site.fixed:
            if site.pinned is None:
                return None     # an unplaced existing actor: leave it be
            name = getattr(site.pinned, "name", str(site.pinned))
            return PlacementDecision(
                context=ctx, target=name, chosen=site.pinned, cost=0.0,
                terms={"pinned": True}, alternatives=(), reason="explicit")

        alts: List[ScoredAlternative] = []
        local_dev = None
        local_cost = None
        local_reason = ""
        for pidx in site.producers:
            up = placements.get(pidx)
            if up is not None and not isinstance(up, NodeTarget):
                local_dev, local_reason = up, "inherit-upstream"
                break
        if local_dev is None and devices:
            scored = [(self._device_terms(d), d) for d in devices]
            # deterministic fallback: live bytes, queue depth, then the
            # device *name* — never the manager's enumeration order
            _, local_dev = min(scored, key=lambda td: (
                td[0]["live_bytes"], td[0]["queue_depth"], td[1].name))
            local_reason = "least-loaded"
            for t, d in scored:
                if d is not local_dev:
                    alts.append(ScoredAlternative(
                        d.name, self._device_seconds(t), t))
        if local_dev is not None:
            terms = self._device_terms(local_dev)
            terms["reason"] = local_reason
            local_cost = self._device_seconds(terms)
            alts.insert(0, ScoredAlternative(local_dev.name, local_cost,
                                             terms))

        best = local_dev
        best_cost = local_cost
        best_terms: Dict[str, Any] = alts[0].terms if alts else {}
        best_reason = local_reason
        if site.remote_ok and site.in_bytes is not None \
                and site.out_bytes is not None:
            for target in remotes:
                wire_s, encoding = self.wire.round_trip_seconds(
                    site.in_bytes, site.out_bytes,
                    allow_compress=target.allows_compress,
                    peer=target.peer)
                load_s = self._peer_load_s.get(target.peer,
                                               target.static_load_s)
                cost = load_s + wire_s
                terms = {"wire_s": wire_s, "encoding": encoding,
                         "load_s": load_s, "in_bytes": site.in_bytes,
                         "out_bytes": site.out_bytes}
                alts.append(ScoredAlternative(target.name, cost, terms))
                # strict <: on a tie the local device wins — never pay a
                # hop for nothing
                if best_cost is None or cost < best_cost:
                    best, best_cost, best_terms = target, cost, terms
                    best_reason = f"wire-amortized:{encoding}"
        if best is None:
            return None
        return PlacementDecision(
            context=ctx, target=getattr(best, "name", str(best)),
            chosen=best, cost=best_cost, terms=best_terms,
            alternatives=tuple(alts), reason=best_reason)


# ----------------------------------------------------------------------------
# the process-wide instance
# ----------------------------------------------------------------------------
_service: PlacementService = PlacementService()


def service() -> PlacementService:
    """The process-wide :class:`PlacementService` every subsystem
    delegates to."""
    return _service


def set_service(svc: PlacementService) -> PlacementService:
    """Swap the process-wide service (tests inject fake cost tables this
    way); returns the previous one so callers can restore it."""
    global _service
    prev, _service = _service, svc
    return prev
