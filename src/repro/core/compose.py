"""Actor composition — multi-stage kernel pipelines (paper §3.5).

Two levels, exactly as the paper's design discussion (§3.6) lays out:

* :func:`compose` — **staged** composition. ``C = B ⊙ A`` spawns a new
  actor that forwards any message to ``A`` and delegates ``A``'s response
  to ``B`` via a response *promise*. When stages exchange
  :class:`~repro.core.memref.DeviceRef` payloads, intermediate data stays
  device-resident; because JAX dispatch is asynchronous, stage *n+1* is
  enqueued while stage *n* still runs on the device — the paper's
  OpenCL-event chaining.

* :func:`fuse` — **fused** composition ("an alternative level of
  composition uses kernels as building blocks to compose a single OpenCL
  actor", §3.6 — the nested-parallelism direction). The stage callables are
  traced into one jit program, eliminating per-stage dispatch *and*
  letting XLA fuse across stage boundaries. This is the beyond-paper
  optimization measured in ``benchmarks/bench_iterated.py``.
"""
from __future__ import annotations

from concurrent.futures import Future
from typing import Any, Callable, Optional, Sequence, Union

from .actor import Actor, ActorRef, ActorSystem
from .facade import KernelActor
from .signature import NDRange

__all__ = ["compose", "fuse", "ComposedActor"]


class ComposedActor(Actor):
    """Forwards messages through ``stages`` left→right, responding with the
    final stage's result (promise delegation, paper §3.5)."""

    def __init__(self, stages: Sequence[ActorRef]):
        super().__init__()
        if not stages:
            raise ValueError("need at least one stage")
        self.stages = list(stages)

    def receive(self, *payload: Any) -> Future:
        out: Future = Future()
        self._run_stage(0, payload, out)
        return out  # promise: the runtime delegates the response

    def _run_stage(self, idx: int, payload, out: Future) -> None:
        fut = self.stages[idx].request(*payload)

        def _done(f: Future):
            exc = f.exception()
            if exc is not None:
                out.set_exception(exc)
                return
            result = f.result()
            nxt = result if isinstance(result, tuple) else (result,)
            if idx + 1 == len(self.stages):
                out.set_result(result)
            else:
                self._run_stage(idx + 1, nxt, out)

        fut.add_done_callback(_done)


def compose(system: ActorSystem, *stages: ActorRef) -> ActorRef:
    """``compose(sys, A, B, C)`` builds C⊙B⊙A (A applied first).

    ``ActorRef.__mul__`` provides the paper's infix form:
    ``fuse = move_elems * count_elems * prepare`` (Listing 5).
    """
    flat: list[ActorRef] = []
    for s in stages:
        inner = _stages_of(system, s)
        flat.extend(inner if inner else [s])
    return system.spawn(ComposedActor(flat))


def _stages_of(system: ActorSystem, ref: ActorRef) -> Optional[list]:
    st = system._actors.get(ref.actor_id)
    if st is not None and isinstance(st.actor, ComposedActor):
        return st.actor.stages
    return None


def fuse(system: ActorSystem, *stages: Union[ActorRef, Callable],
         nd_range: Optional[NDRange] = None, name: str = "fused",
         device=None) -> ActorRef:
    """Fuse kernel stages into a **single** jitted actor.

    ``stages`` are kernel-actor refs (their traceable ``fn`` is extracted)
    or plain callables acting as adapters between stages. The fused actor
    takes the first stage's input signature and produces the last stage's
    output signature; intermediates never materialize as messages.
    """
    fns: list[Callable] = []
    first_ka: Optional[KernelActor] = None
    last_ka: Optional[KernelActor] = None
    for s in stages:
        if isinstance(s, ActorRef):
            st = system._actors.get(s.actor_id)
            actor = st.actor if st else None
            if not isinstance(actor, KernelActor):
                raise TypeError(f"{s} is not a kernel actor; cannot fuse")
            if first_ka is None:
                first_ka = actor
            last_ka = actor
            fns.append(_plain_fn(actor))
        elif callable(s):
            fns.append(s)
        else:
            raise TypeError(f"cannot fuse {s!r}")
    if first_ka is None:
        raise ValueError("fuse needs at least one kernel actor stage")

    def fused_fn(*inputs):
        vals = inputs
        for f in fns:
            out = f(*vals)
            vals = out if isinstance(out, tuple) else (out,)
        return vals

    specs = tuple(first_ka.signature.input_specs) + tuple(last_ka.signature.output_specs)
    mngr = system.opencl_manager()
    return mngr.spawn(fused_fn, name,
                      nd_range or first_ka.nd_range, *specs,
                      device=device or first_ka.device)


def _plain_fn(actor: KernelActor) -> Callable:
    """The stage's traceable callable with its static kwargs bound."""
    kwargs = {}
    if "nd_range" in actor._fn_kwargs:
        kwargs["nd_range"] = actor.nd_range
    if "local_shapes" in actor._fn_kwargs:
        kwargs["local_shapes"] = tuple(
            s.resolved_shape() for s in actor.signature.local_specs)
    if not kwargs:
        return actor.fn
    fn = actor.fn

    def bound(*inputs):
        return fn(*inputs, **kwargs)

    return bound
