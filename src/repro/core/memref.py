"""Device-resident memory references — the paper's ``mem_ref<T>`` (§3.5).

A :class:`DeviceRef` represents data living on an accelerator device. It is
what OpenCL actors forward between pipeline stages so that intermediate
results never round-trip through host memory.

JAX adaptation (DESIGN.md §2): a dispatched computation returns a
``jax.Array`` immediately — the array *is* the completion event. Wrapping
it in a ``DeviceRef`` and forwarding it to the next stage therefore
reproduces the paper's OpenCL-event chaining (Listing 4) with zero extra
machinery: stage *n+1* may enqueue against the ref before stage *n* has
finished executing on the device; XLA's runtime resolves the dependency.

Like the paper's reference type, a ``DeviceRef`` carries element type,
length, and access rights, and it is bound to the local process — we take
the paper's option (a) for distribution: serialization raises, making
expensive cross-node copies explicit (``to_value()``).
"""
from __future__ import annotations

import threading
from typing import Optional, Tuple

import jax
import numpy as np

__all__ = ["DeviceRef", "as_device_array", "live_ref_count"]

_live = 0
_live_lock = threading.Lock()


def live_ref_count() -> int:
    """Number of un-released DeviceRefs (used by tests/leak checks)."""
    return _live


class DeviceRef:
    """A typed handle to device-resident data (``mem_ref<T>``).

    Attributes mirror the paper's description: "a reference type includes
    type information about the data it references in addition to the amount
    of bytes it refers to and memory access rights."
    """

    __slots__ = ("_array", "dtype", "shape", "access", "_released", "__weakref__")

    def __init__(self, array: jax.Array, access: str = "rw"):
        if access not in ("r", "w", "rw"):
            raise ValueError("access must be 'r', 'w' or 'rw'")
        self._array = array
        self.dtype = array.dtype
        self.shape = tuple(array.shape)
        self.access = access
        self._released = False
        global _live
        with _live_lock:
            _live += 1

    # -- properties ---------------------------------------------------------
    @property
    def nbytes(self) -> int:
        return int(np.dtype(self.dtype).itemsize * np.prod(self.shape, dtype=np.int64))

    @property
    def array(self) -> jax.Array:
        """The underlying (possibly still-executing) device array."""
        if self._released:
            raise RuntimeError("DeviceRef used after release")
        return self._array

    @property
    def sharding(self):
        return self._array.sharding

    def is_ready(self) -> bool:
        """True once the producing computation has completed on device."""
        try:
            return bool(self._array.is_ready())
        except AttributeError:  # pragma: no cover - older jax
            return True

    # -- data movement ------------------------------------------------------
    def to_value(self) -> np.ndarray:
        """Explicit device→host copy (the paper's read-back at pipeline end)."""
        return np.asarray(jax.device_get(self.array))

    def block_until_ready(self) -> "DeviceRef":
        self.array.block_until_ready()
        return self

    def release(self) -> None:
        """Drop the device buffer (paper: "dropping a reference argument
        simply releases its memory on the device")."""
        if not self._released:
            self._released = True
            self._array = None
            global _live
            with _live_lock:
                _live -= 1

    def __del__(self):  # pragma: no cover - GC timing dependent
        try:
            self.release()
        except Exception:
            pass

    # -- distribution policy -------------------------------------------------
    def __reduce__(self):
        # Paper §3.5 option (a): prohibit serialization of reference types so
        # sending one over the network raises instead of silently copying.
        raise TypeError(
            "DeviceRef is bound to local device memory and cannot be "
            "serialized; call .to_value() for an explicit host copy"
        )

    def __repr__(self):
        state = "released" if self._released else ("ready" if self.is_ready() else "pending")
        return f"DeviceRef<{np.dtype(self.dtype).name}>{list(self.shape)}[{self.access}, {state}]"


def as_device_array(value, device=None, dtype=None) -> jax.Array:
    """Normalize message payloads (host arrays, scalars, or DeviceRefs) to a
    device array, transferring host data if needed (paper: the first actor in
    a chain transfers input data to the device)."""
    if isinstance(value, DeviceRef):
        arr = value.array
    else:
        arr = value
    if not isinstance(arr, jax.Array):
        arr = np.asarray(arr, dtype=dtype)
        arr = jax.device_put(arr, device)
    elif device is not None and getattr(arr, "sharding", None) is not None:
        arr = jax.device_put(arr, device)
    return arr
