"""Device-resident memory references — the paper's ``mem_ref<T>`` (§3.5).

A :class:`DeviceRef` represents data living on an accelerator device. It is
the *currency* of the runtime: kernel actors accept and emit refs natively,
pipeline stages forward them so intermediate results never round-trip
through host memory, and pools/schedulers route work toward the device a
ref already lives on.

JAX adaptation (DESIGN.md §2): a dispatched computation returns a
``jax.Array`` immediately — the array *is* the completion event. Wrapping
it in a ``DeviceRef`` and forwarding it to the next stage therefore
reproduces the paper's OpenCL-event chaining (Listing 4) with zero extra
machinery: stage *n+1* may enqueue against the ref before stage *n* has
finished executing on the device; XLA's runtime resolves the dependency.

Like the paper's reference type, a ``DeviceRef`` carries element type,
length, and **access rights** ("r", "w", "rw") which are enforced: reading
a write-only ref or donating a read-only ref raises
:class:`~repro.core.errors.AccessViolation`. For distribution the paper
offers two options — (a) prohibit serialization, (b) serialize through an
explicit host copy. We implement both: a device-resident ref refuses to
pickle, while :meth:`DeviceRef.spill` moves the payload to host memory at
an explicit boundary, after which the ref pickles and can be
:meth:`~DeviceRef.unspill`\\ ed on the receiving side.

Every ref is accounted in the process-wide :class:`RefRegistry`: per-device
live bytes (with a high watermark feeding placement policies) plus the
host-transfer counters the zero-copy tests assert on.
"""
from __future__ import annotations

import weakref
from typing import Any, Dict, Optional

import jax
import numpy as np

from ..analysis.runtime import make_rlock
from .errors import AccessViolation

__all__ = [
    "DeviceRef",
    "RefRegistry",
    "registry",
    "as_device_array",
    "live_ref_count",
    "transfer_count",
    "reset_transfer_stats",
    "memory_stats",
    "payload_device",
    "payload_nbytes",
    "tree_wrap",
    "tree_unwrap",
    "tree_release",
]

_ACCESS_MODES = ("r", "w", "rw")


def _device_of(arr) -> Optional[jax.Device]:
    """The ``jax.Device`` holding ``arr`` (single-device arrays)."""
    try:
        devs = arr.devices()
        if len(devs) == 1:
            return next(iter(devs))
    except Exception:  # pragma: no cover - tracers / older jax
        pass  # lint: device probe; tracers and older jax lack .devices()
    dev = getattr(arr, "device", None)
    return dev if isinstance(dev, jax.Device) else None


class RefRegistry:
    """Process-wide accounting of live :class:`DeviceRef`\\ s.

    Tracks the live-ref count (leak checks), per-device live bytes with a
    high watermark (``DeviceManager`` exposes these to the pool's
    least-loaded placement), and the device↔host traffic counters:

    * ``transfers``  — explicit ``to_value()`` read-backs
    * ``readbacks``  — kernel-actor value-semantics outputs
    * ``spills`` / ``unspills`` — explicit serialization boundaries
    """

    def __init__(self):
        # reentrant: DeviceRef.__del__ releases through the registry, so
        # a GC pass triggered inside a locked registry method re-enters
        # this lock on the same thread (see analysis/ORDER.md, rank 20)
        self._lock = make_rlock("RefRegistry")
        self._count = 0
        self._bytes: Dict[Any, int] = {}
        self._peak: Dict[Any, int] = {}
        self._pool_refs: list = []      # weakrefs to live PagePools
        self.transfers = 0
        self.readbacks = 0
        self.spills = 0
        self.unspills = 0

    # -- ref lifecycle (called by DeviceRef) ---------------------------------
    def on_create(self, device, nbytes: int, resident: bool) -> None:
        with self._lock:
            self._count += 1
            if resident:
                self._add_bytes(device, nbytes)

    def on_resident(self, device, nbytes: int) -> None:
        with self._lock:
            self._add_bytes(device, nbytes)

    def on_evict(self, device, nbytes: int) -> None:
        with self._lock:
            self._bytes[device] = self._bytes.get(device, 0) - nbytes

    def on_retire(self, device, nbytes: int, resident: bool) -> None:
        with self._lock:
            self._count -= 1
            if resident:
                self._bytes[device] = self._bytes.get(device, 0) - nbytes

    def _add_bytes(self, device, nbytes: int) -> None:
        b = self._bytes.get(device, 0) + nbytes
        self._bytes[device] = b
        if b > self._peak.get(device, 0):
            self._peak[device] = b

    # -- traffic counters -----------------------------------------------------
    def count_transfer(self) -> None:
        with self._lock:
            self.transfers += 1

    def count_readback(self) -> None:
        with self._lock:
            self.readbacks += 1

    def count_spill(self) -> None:
        with self._lock:
            self.spills += 1

    def count_unspill(self) -> None:
        with self._lock:
            self.unspills += 1

    # -- page pools (repro.serve.kvpool) --------------------------------
    def register_pool(self, pool) -> None:
        """Track a page pool (weakly) so page pressure is reported next
        to the byte watermarks in :func:`memory_stats`."""
        with self._lock:
            self._pool_refs.append(weakref.ref(pool))
            self._pool_refs = [r for r in self._pool_refs
                               if r() is not None]

    def _live_pools(self, device=None) -> list:
        with self._lock:
            pools = [r() for r in self._pool_refs]
        pools = [p for p in pools if p is not None]
        if device is None:
            return pools
        out = []
        for p in pools:
            pdev = getattr(p, "device", None)
            pdev = getattr(pdev, "jax_device", pdev)  # unwrap manager.Device
            if pdev is None:
                # a device-less pool places its refs on the JAX default
                # device; attribute its pressure there
                pdev = jax.devices()[0]
            if pdev == device:
                out.append(p)
        return out

    def page_stats(self, device=None) -> dict:
        """Aggregated page-pool pressure (optionally one device's):
        capacity, live/free/shared pages, peak, and the internal
        fragmentation ratio (unused slots inside allocated pages)."""
        agg = {"pages_total": 0, "pages_live": 0, "pages_free": 0,
               "pages_shared": 0, "peak_pages": 0}
        used = slots = 0
        for pool in self._live_pools(device):
            s = pool.stats()          # pool lock only; never ours
            for k in agg:
                agg[k] += s[k]
            used += s["used_slots"]
            slots += s["page_slots"]
        agg["fragmentation"] = (1.0 - used / slots) if slots else 0.0
        return agg

    # -- queries ------------------------------------------------------
    def live_count(self) -> int:
        return self._count

    def live_bytes(self, device=None) -> int:
        with self._lock:
            if device is None:
                return sum(self._bytes.values())
            return self._bytes.get(device, 0)

    def peak_bytes(self, device=None) -> int:
        with self._lock:
            if device is None:
                return sum(self._peak.values())
            return self._peak.get(device, 0)

    def stats(self) -> dict:
        with self._lock:
            base = {
                "live_refs": self._count,
                "live_bytes": sum(self._bytes.values()),
                "peak_bytes": sum(self._peak.values()),
                "transfers": self.transfers,
                "readbacks": self.readbacks,
                "spills": self.spills,
                "unspills": self.unspills,
            }
        pages = self.page_stats()       # own locking (pool locks)
        base["pages_total"] = pages["pages_total"]
        base["pages_free"] = pages["pages_free"]
        base["pages_shared"] = pages["pages_shared"]
        base["fragmentation"] = pages["fragmentation"]
        return base

    def reset_traffic(self) -> None:
        """Zero the host-traffic counters (not the live accounting)."""
        with self._lock:
            self.transfers = 0
            self.readbacks = 0
            self.spills = 0
            self.unspills = 0


#: the process-wide registry every DeviceRef reports to
registry = RefRegistry()


def live_ref_count() -> int:
    """Number of un-released DeviceRefs (used by tests/leak checks)."""
    return registry.live_count()


def transfer_count() -> int:
    """Explicit ``DeviceRef.to_value()`` device→host copies so far."""
    return registry.transfers


def reset_transfer_stats() -> None:
    """Zero the host-traffic counters (transfers/readbacks/spills)."""
    registry.reset_traffic()


def memory_stats() -> dict:
    """Registry snapshot: live refs/bytes, watermark, traffic counters."""
    return registry.stats()


def payload_device(payload) -> Optional[jax.Device]:
    """The device the first :class:`DeviceRef` in ``payload`` lives on, or
    ``None`` — the placement hint pools and schedulers route by."""
    for v in payload:
        if isinstance(v, DeviceRef) and v.device is not None and not v.is_spilled:
            return v.device
    return None


def payload_nbytes(payload) -> int:
    """Total array bytes a payload would move — the size term
    :mod:`repro.core.placement`'s wire-cost model prices hops by. Walks
    the same container shapes the wire codec freezes (tuples, lists,
    dicts) and counts DeviceRefs, jax arrays, and numpy arrays; opaque
    Python objects count zero (their pickled size is envelope noise next
    to array payloads)."""
    total = 0
    stack = [payload]
    while stack:
        v = stack.pop()
        if isinstance(v, DeviceRef):
            total += v.nbytes
        elif isinstance(v, (tuple, list)):
            stack.extend(v)
        elif isinstance(v, dict):
            stack.extend(v.values())
        elif isinstance(v, (jax.Array, np.ndarray)):
            total += int(v.nbytes)
    return total


class DeviceRef:
    """A typed handle to device-resident data (``mem_ref<T>``).

    Attributes mirror the paper's description: "a reference type includes
    type information about the data it references in addition to the amount
    of bytes it refers to and memory access rights."

    Lifecycle states: ``live`` (device-resident) → ``spilled`` (host copy,
    device buffer dropped; picklable) ↔ ``live``; terminal states are
    ``donated`` (buffer ownership transferred into a kernel) and
    ``released``.
    """

    __slots__ = ("_array", "_host", "dtype", "shape", "access", "device",
                 "_state", "__weakref__")

    def __init__(self, array: jax.Array, access: str = "rw"):
        if access not in _ACCESS_MODES:
            raise ValueError("access must be 'r', 'w' or 'rw'")
        self._array = array
        self._host = None
        self.dtype = array.dtype
        self.shape = tuple(array.shape)
        self.access = access
        self.device = _device_of(array)
        self._state = "live"
        registry.on_create(self.device, self.nbytes, resident=True)

    @classmethod
    def put(cls, value, device=None, dtype=None, access: str = "rw") -> "DeviceRef":
        """Transfer a host value to ``device`` and wrap it (the paper's
        first-actor-in-the-chain input transfer, made explicit)."""
        arr = jax.device_put(np.asarray(value, dtype=dtype), device)
        return cls(arr, access=access)

    # -- properties ---------------------------------------------------------
    @property
    def nbytes(self) -> int:
        return int(np.dtype(self.dtype).itemsize * np.prod(self.shape, dtype=np.int64))

    @property
    def readable(self) -> bool:
        return "r" in self.access

    @property
    def writable(self) -> bool:
        return "w" in self.access

    @property
    def is_spilled(self) -> bool:
        return self._state == "spilled"

    def _check_usable(self) -> None:
        if self._state == "released":
            raise RuntimeError("DeviceRef used after release")
        if self._state == "donated":
            raise RuntimeError(
                "DeviceRef used after donation: the buffer was donated to a "
                "kernel and its ownership transferred (donate-after-use)")

    @property
    def array(self) -> jax.Array:
        """The underlying (possibly still-executing) device array."""
        self._check_usable()
        if self._state == "spilled":
            raise RuntimeError(
                "DeviceRef is spilled to host memory; call unspill() first")
        if not self.readable:
            raise AccessViolation(
                f"DeviceRef has access rights {self.access!r}; reading "
                "requires 'r'")
        return self._array

    @property
    def sharding(self):
        return self.array.sharding

    def is_ready(self) -> bool:
        """True once the producing computation has completed on device."""
        if self._state != "live":
            return True
        try:
            return bool(self._array.is_ready())
        except AttributeError:  # pragma: no cover - older jax
            return True

    # -- access rights ------------------------------------------------------
    def restrict(self, access: str) -> "DeviceRef":
        """A narrowed-rights view of the same device buffer (paper §3.5).

        Rights may only shrink (``rw`` → ``r``); widening raises
        :class:`AccessViolation`. The view is an independent ref — release
        it like any other (accounting counts its bytes separately).
        """
        if access not in _ACCESS_MODES:
            raise ValueError("access must be 'r', 'w' or 'rw'")
        if not set(access) <= set(self.access):
            raise AccessViolation(
                f"cannot widen access rights {self.access!r} -> {access!r}")
        self._check_usable()
        if self._state == "spilled":
            raise RuntimeError("cannot derive a view of a spilled DeviceRef")
        return DeviceRef(self._array, access=access)

    # -- data movement ------------------------------------------------------
    def to_value(self) -> np.ndarray:
        """Explicit device→host copy (the paper's read-back at pipeline end).

        Counted in :func:`transfer_count` — the zero-copy pipeline tests
        assert this stays flat across stage hops.
        """
        self._check_usable()
        if not self.readable:
            raise AccessViolation(
                f"DeviceRef has access rights {self.access!r}; to_value() "
                "requires 'r'")
        if self._state == "spilled":
            return np.array(self._host)
        registry.count_transfer()
        return np.asarray(jax.device_get(self._array))

    def block_until_ready(self) -> "DeviceRef":
        self.array.block_until_ready()
        return self

    # -- spill / unspill (paper §3.5 distribution option (b)) ----------------
    def spill(self) -> "DeviceRef":
        """Serialize to host memory and drop the device buffer.

        This is the *explicit* stage boundary for distribution: a spilled
        ref pickles (see ``__reduce__``) and stops counting against the
        device's live bytes. Inverse of :meth:`unspill`. Requires read
        rights — spilling serializes the contents, so a write-only view
        must not be able to exfiltrate data its rights forbid reading.
        """
        self._check_usable()
        if self._state == "spilled":
            return self
        if not self.readable:
            raise AccessViolation(
                f"DeviceRef has access rights {self.access!r}; spill() "
                "serializes the contents and requires 'r'")
        self._host = np.asarray(jax.device_get(self._array))
        self._array = None
        self._state = "spilled"
        registry.count_spill()
        registry.on_evict(self.device, self.nbytes)
        return self

    def spill_copy(self) -> "DeviceRef":
        """A spilled **clone** for the wire: serializes the contents into a
        new picklable host-side ref, leaving this ref device-resident.

        This is the request-payload wire boundary (``repro.net``): the
        sender keeps its live ref so an exactly-once retry (a chunk
        re-issued after the receiving *node* died) can replay the same
        payload locally. Replies use in-place :meth:`spill` instead —
        there the ref's ownership transfers to the remote caller. Counts
        one spill either way, so "one spill/unspill pair per wire hop"
        holds for both directions. Requires read rights, like
        :meth:`spill`.
        """
        self._check_usable()
        if not self.readable:
            raise AccessViolation(
                f"DeviceRef has access rights {self.access!r}; spill_copy() "
                "serializes the contents and requires 'r'")
        if self._state == "spilled":
            host = np.array(self._host)
        else:
            host = np.asarray(jax.device_get(self._array))
        registry.count_spill()
        return _rebuild_spilled(host, np.dtype(self.dtype).str, self.shape,
                                self.access)

    def unspill(self, device=None) -> "DeviceRef":
        """Move a spilled payload back onto ``device`` (default: where it
        lived before, or the process default device). Accepts a bare
        ``jax.Device`` or the runtime's ``Device`` wrapper — the receiving
        node of a wire transfer passes whichever it routes by."""
        if self._state != "spilled":
            self._check_usable()
            return self
        device = getattr(device, "jax_device", device)
        self._array = jax.device_put(self._host, device or self.device)
        self._host = None
        self.device = _device_of(self._array)
        self._state = "live"
        registry.count_unspill()
        registry.on_resident(self.device, self.nbytes)
        return self

    # -- consumption ------------------------------------------------------
    def donate(self) -> jax.Array:
        """Consume the ref for buffer donation: returns the array and marks
        the ref dead so XLA may reuse the buffer in place (the TPU analogue
        of handing a read-write ``cl_mem`` to a kernel). Requires write
        rights; any later use raises a donate-after-use error."""
        self._check_usable()
        if self._state == "spilled":
            raise RuntimeError(
                "cannot donate a spilled DeviceRef; unspill() first")
        if not self.writable:
            raise AccessViolation(
                f"DeviceRef has access rights {self.access!r}; donation "
                "requires 'w'")
        arr = self._array
        self._array = None
        self._state = "donated"
        registry.on_retire(self.device, self.nbytes, resident=True)
        return arr

    def release(self) -> None:
        """Drop the buffer (paper: "dropping a reference argument simply
        releases its memory on the device"). Idempotent."""
        if self._state in ("released", "donated"):
            return
        resident = self._state == "live"
        registry.on_retire(self.device, self.nbytes, resident=resident)
        self._array = None
        self._host = None
        self._state = "released"

    def __del__(self):  # pragma: no cover - GC timing dependent
        try:
            self.release()
        except Exception:
            pass  # lint: finalizers must never raise

    # -- distribution policy -------------------------------------------------
    def __reduce__(self):
        # Paper §3.5: option (a) — a device-resident ref refuses to
        # serialize, so sending one over the network raises instead of
        # silently copying; option (b) — after an *explicit* spill() the
        # host payload travels and unspill() restores device residency on
        # the receiving node.
        if self._state == "spilled":
            return (_rebuild_spilled,
                    (self._host, np.dtype(self.dtype).str, self.shape,
                     self.access))
        raise TypeError(
            "DeviceRef is bound to local device memory and cannot be "
            "serialized; call .spill() for explicit host serialization or "
            ".to_value() for an explicit host copy")

    def __repr__(self):
        """Diagnostic form: dtype/shape, access rights, lifecycle state,
        byte size, and where the payload lives — enough to read a
        graph-edge error without a debugger. Examples::

            DeviceRef<float32>[16][rw, live/ready, 64B @ TFRT_CPU_0]
            DeviceRef<float32>[16][r, spilled, 64B @ host]
            DeviceRef<float32>[16][rw, released]
        """
        head = f"DeviceRef<{np.dtype(self.dtype).name}>{list(self.shape)}"
        if self._state == "live":
            phase = "ready" if self.is_ready() else "pending"
            loc = str(self.device) if self.device is not None else "?"
            return f"{head}[{self.access}, live/{phase}, {self.nbytes}B @ {loc}]"
        if self._state == "spilled":
            return f"{head}[{self.access}, spilled, {self.nbytes}B @ host]"
        return f"{head}[{self.access}, {self._state}]"


def _rebuild_spilled(host, dtype_str, shape, access) -> DeviceRef:
    """Unpickle target: reconstruct a spilled ref (host payload only)."""
    ref = DeviceRef.__new__(DeviceRef)
    ref._array = None
    ref._host = np.asarray(host)
    ref.dtype = np.dtype(dtype_str)
    ref.shape = tuple(shape)
    ref.access = access
    ref.device = None
    ref._state = "spilled"
    registry.on_create(None, ref.nbytes, resident=False)
    return ref


# ----------------------------------------------------------------------------
# pytree helpers — per-request cache refs (serve engine)
# ----------------------------------------------------------------------------
def tree_wrap(tree, device=None, access: str = "rw", created=None):
    """Wrap every array leaf of a pytree as a :class:`DeviceRef`.

    This is how the serve engine represents per-request decode state: a
    model cache pytree becomes a pytree of refs, each leaf accounted in the
    registry and kept device-resident between decode steps. Leaves that are
    already refs pass through unchanged; host values are transferred to
    ``device`` first.

    ``created`` (a list, optional) collects every ref this call creates
    *as it is created* — callers that must release on a mid-tree wrapping
    failure (one bad leaf after several good ones) release the partial
    set instead of leaking it; the serve engine's shed path depends on
    this.
    """

    # accept the runtime's Device wrapper as well as a bare jax.Device
    device = getattr(device, "jax_device", device)

    def wrap(leaf):
        if isinstance(leaf, DeviceRef):
            return leaf
        ref = DeviceRef(as_device_array(leaf, device=device), access=access)
        if created is not None:
            created.append(ref)
        return ref

    return jax.tree.map(wrap, tree)


def tree_unwrap(tree):
    """The inverse view: every :class:`DeviceRef` leaf replaced by its
    (possibly still-executing) device array; non-ref leaves pass through."""
    return jax.tree.map(
        lambda l: l.array if isinstance(l, DeviceRef) else l, tree,
        is_leaf=lambda l: isinstance(l, DeviceRef))


def tree_release(tree) -> int:
    """Release every ref leaf in ``tree`` (idempotent); returns how many
    refs/pages were visited — the serve engine drops a request's whole
    cache with one call when the request leaves the batch.

    Besides bare :class:`DeviceRef` leaves this also recognizes objects
    exposing ``release_pages()`` (a ``repro.serve.kvpool.PageTable``), so
    the ChunkScheduler's duplicate-success path reclaims a speculative
    race loser's *paged* cache the same way it reclaims loose refs.
    """
    n = 0
    is_leaf = lambda l: isinstance(l, DeviceRef) or hasattr(l, "release_pages")
    for leaf in jax.tree.leaves(tree, is_leaf=is_leaf):
        if isinstance(leaf, DeviceRef):
            leaf.release()
            n += 1
        elif hasattr(leaf, "release_pages"):
            n += leaf.release_pages()
    return n


def as_device_array(value, device=None, dtype=None) -> jax.Array:
    """Normalize message payloads (host arrays, scalars, or DeviceRefs) to a
    device array, transferring host data if needed (paper: the first actor in
    a chain transfers input data to the device)."""
    if isinstance(value, DeviceRef):
        arr = value.array
    else:
        arr = value
    if not isinstance(arr, jax.Array):
        arr = np.asarray(arr, dtype=dtype)
        arr = jax.device_put(arr, device)
    elif device is not None and getattr(arr, "sharding", None) is not None:
        arr = jax.device_put(arr, device)
    return arr
