"""Typed dataflow-graph composition — device-resident DAGs (paper §3.5).

The paper promises that "OpenCL kernels can be composed while encapsulated
in C++ actors, hence operate in a multi-stage fashion on data resident at
the GPU" (§3.5), and that CAF's *typed* actor interfaces make such
compositions statically checkable. :class:`Pipeline` realized the linear
case; this module generalizes composition to a declarative **DAG**:

* **Nodes** are kernel declarations (:class:`~repro.core.api.KernelDecl`),
  existing actor refs (kernel or opaque), plain Python callables, or the
  structural combinators below.
* **Edges** are named, *typed ports*: each :class:`Port` carries a
  :class:`PortType` (shape/dtype) derived from the producer's
  :class:`~repro.core.signature.KernelSignature` via ``jax.eval_shape``
  (see :func:`repro.core.facade.eval_output_structs`).
* **Combinators**: :meth:`Graph.broadcast` (fan-out one value to N
  consumers), :meth:`Graph.zip_join` (fan-in barrier), :meth:`Graph.select`
  (predicate routing, with :meth:`Graph.merge` as its first-wins dual for
  speculative branches), and :meth:`Graph.map_over` (per-chunk fan-out
  through :class:`~repro.core.scheduler.ChunkScheduler`).

``Graph.build()`` validates the topology **at build time** — cycle
detection, dangling/arity/dtype-mismatch errors, each raised as a distinct
:class:`~repro.core.errors.GraphError` subclass naming the offending node
path — then delegates whole-DAG placement to the process-wide
:class:`~repro.core.placement.PlacementService` (explicit ``device=``
wins, else inherit the upstream producer's device, else the cost-ranked
local device — or a remote :class:`~repro.core.placement.NodeTarget`
when the wire cost model says the hop amortizes) and lowers every interior
edge to **ref-emitting** actors: a kernel whose consumers can all unwrap
:class:`~repro.core.memref.DeviceRef`\\ s is spawned (or cloned) with
``emit="ref"``, so interior edges move zero bytes through the host — the
``RefRegistry`` transfer counters stay flat across the whole graph run.

The result of ``build()`` is a :class:`GraphRef` — an ordinary
:class:`~repro.core.actor.ActorRef` pointing at a spawned orchestrator
actor, so a built graph composes everywhere an actor does: as a
``Pipeline`` stage, behind an :class:`~repro.core.api.ActorPool`, as a
:class:`~repro.dist.pipeline.PipelineRunner` chain, or as a
``ServeEngine`` model step.
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FuturesTimeout
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis.runtime import make_lock, make_rlock
from .actor import _UNSET, Actor, ActorRef, ActorSystem
from .api import KernelDecl, _bound_fn
from .errors import (ArityMismatchError, DanglingPortError, GraphCycleError,
                     GraphError, PortTypeMismatchError)
from .memref import DeviceRef, as_device_array, registry
from .placement import GraphSite, NodeTarget
from .placement import service as placement_service

__all__ = ["Graph", "GraphNode", "GraphPlan", "GraphRef", "Port", "PortType"]


# ----------------------------------------------------------------------------
# typed ports
# ----------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PortType:
    """Shape/dtype of the value crossing an edge; ``None`` = unknown
    (Python stages and splat chain edges are untyped wildcards)."""

    dtype: Optional[np.dtype] = None
    shape: Optional[Tuple[int, ...]] = None

    @classmethod
    def of(cls, dtype=None, shape=None) -> "PortType":
        return cls(None if dtype is None else np.dtype(dtype),
                   None if shape is None else tuple(int(s) for s in shape))

    def __repr__(self):
        d = self.dtype.name if self.dtype is not None else "?"
        s = list(self.shape) if self.shape is not None else "?"
        return f"PortType<{d}>{s}"


class Port:
    """One named output of a graph node; the handle edges are wired with."""

    __slots__ = ("node", "index")

    def __init__(self, node: "GraphNode", index: int):
        self.node = node
        self.index = index

    @property
    def type(self) -> PortType:
        return self.node.out_types[self.index]

    @property
    def key(self) -> Tuple[int, int]:
        return (self.node.idx, self.index)

    @property
    def path(self) -> str:
        return f"{self.node.path}[{self.index}]"

    def __repr__(self):
        return f"Port({self.path}: {self.type})"


#: structural node kinds are routed by the orchestrator itself — they never
#: spawn an actor, so fan-out/fan-in adds no per-message hop
_STRUCTURAL = ("broadcast", "zip_join", "select", "merge")
#: node kinds backed by a spawned actor at runtime
_ACTOR_KINDS = ("kernel", "actor", "func", "map_over")


def _edge_bytes(types) -> Optional[int]:
    """Total payload bytes crossing a set of typed edges, or None when
    any edge is untyped — an unknown edge size means the wire-cost model
    cannot price the hop, so such nodes are never placed remotely."""
    total = 0
    for t in types:
        if t is None or t.dtype is None or t.shape is None:
            return None
        total += int(np.prod(t.shape, dtype=np.int64)) * t.dtype.itemsize
    return total


class GraphNode:
    """A node plus its input wiring; created via :meth:`Graph.node` /
    :meth:`Graph.apply` / the combinators."""

    def __init__(self, graph: "Graph", idx: int, kind: str, target: Any,
                 name: str, n_in: int, n_out: int, *, device=None,
                 splat: bool = False, options: Optional[dict] = None):
        self.graph = graph
        self.idx = idx
        self.kind = kind
        self.target = target
        self.name = name
        self.device = device
        self.splat = splat          # single input delivered as *payload
        self.options = dict(options or {})
        self.inputs: List[Optional[Port]] = [None] * n_in
        self.out_types: List[PortType] = [PortType()] * n_out

    @property
    def n_in(self) -> int:
        return len(self.inputs)

    @property
    def n_out(self) -> int:
        return len(self.out_types)

    @property
    def path(self) -> str:
        """Node path used in every Graph diagnostic: ``<graph>/<node>``."""
        return f"{self.graph.name}/{self.name}"

    def out(self, index: int = 0) -> Port:
        if not 0 <= index < self.n_out:
            raise GraphError(f"{self.path} has {self.n_out} output ports, "
                             f"no port {index}")
        return Port(self, index)

    def outs(self) -> Tuple[Port, ...]:
        return tuple(Port(self, i) for i in range(self.n_out))

    def __repr__(self):
        return (f"GraphNode({self.path}, kind={self.kind!r}, "
                f"in={self.n_in}, out={self.n_out})")


# ----------------------------------------------------------------------------
# the builder
# ----------------------------------------------------------------------------
class Graph:
    """Declarative DAG builder (see module docstring for the model).

    Functional surface — each call returns the new node's port(s)::

        g = Graph(system, name="diamond")
        x = g.source("x", jnp.float32, shape=(N,))
        h = g.apply(prepare, x)
        l, r = g.broadcast(h, 2)
        j1, j2 = g.zip_join(g.apply(left, l), g.apply(right, r))
        g.output(g.apply(merge_k, j1, j2))
        diamond = g.build()                 # validate + place + spawn
        out = diamond.ask(np.arange(N, dtype=np.float32))

    Low-level surface — :meth:`node` creates a node with unbound input
    slots and :meth:`bind` wires them afterwards (this is the only way to
    construct a cyclic topology, which :meth:`build` then rejects).
    """

    def __init__(self, system: ActorSystem, *, name: str = "graph"):
        self.system = system
        self.name = name
        self.nodes: List[GraphNode] = []
        self.outputs: List[Port] = []
        self._used_names: Dict[str, int] = {}

    # -- construction ------------------------------------------------------
    def _unique_name(self, base: str) -> str:
        n = self._used_names.get(base, 0)
        self._used_names[base] = n + 1
        return base if n == 0 else f"{base}.{n}"

    def _add(self, kind: str, target, name: str, n_in: int, n_out: int,
             *, device=None, splat: bool = False,
             options: Optional[dict] = None) -> GraphNode:
        node = GraphNode(self, len(self.nodes), kind, target,
                         self._unique_name(name), n_in, n_out,
                         device=device, splat=splat, options=options)
        self.nodes.append(node)
        return node

    def source(self, name: str = "in", dtype=None, shape=None) -> Port:
        """Declare a graph input; payload values bind to sources in
        declaration order at :meth:`GraphRef.request` time."""
        node = self._add("source", None, name, 0, 1)
        node.out_types[0] = PortType.of(dtype, shape)
        return node.out(0)

    def chain_source(self, name: str = "in") -> Port:
        """A *splat* source: the whole request payload tuple flows as one
        value and is splatted into its consumer — the untyped chain edge
        the linear :class:`~repro.core.api.Pipeline` wrapper is built on."""
        node = self._add("source", None, name, 0, 1, splat=True)
        return node.out(0)

    def node(self, target, *, name: Optional[str] = None, device=None,
             n_in: Optional[int] = None, n_out: Optional[int] = None
             ) -> GraphNode:
        """Add an **unbound** node (wire inputs later with :meth:`bind`).

        Arity defaults come from the target's kernel signature when it has
        one; plain callables default to one input / one output.
        """
        kind, sig = self._classify(target)
        if sig is not None:
            d_in, d_out = len(sig.input_specs), len(sig.output_specs)
        else:
            d_in, d_out = 1, 1
        node = self._add(kind, target, name or _target_name(target),
                         n_in if n_in is not None else d_in,
                         n_out if n_out is not None else d_out,
                         device=device)
        return node

    def bind(self, node: GraphNode, slot: int, port: Port) -> None:
        """Wire ``port`` into ``node``'s input ``slot``."""
        if node.graph is not self or port.node.graph is not self:
            raise GraphError(f"{node.path}: cannot bind across graphs")
        if not 0 <= slot < node.n_in:
            raise GraphError(f"{node.path} has {node.n_in} input slots, "
                             f"no slot {slot}")
        node.inputs[slot] = port

    def apply(self, target, *ports: Port, name: Optional[str] = None,
              device=None, n_out: Optional[int] = None
              ) -> Union[Port, Tuple[Port, ...]]:
        """Add a node for ``target`` wired to ``ports``; returns its output
        port (or a tuple of ports for multi-output kernels)."""
        kind, sig = self._classify(target)
        if sig is not None and n_out is None:
            n_out = len(sig.output_specs)
        node = self._add(kind, target, name or _target_name(target),
                         len(ports), n_out if n_out is not None else 1,
                         device=device)
        for i, p in enumerate(ports):
            self.bind(node, i, p)
        return node.out(0) if node.n_out == 1 else node.outs()

    def chain(self, target, port: Port, *, name: Optional[str] = None,
              device=None, traceable: bool = False) -> Port:
        """Append a splat-edged stage: the upstream value (a whole payload
        tuple) is splatted into ``target`` — ``Pipeline``'s linear hop.

        ``traceable=True`` marks a bare-callable stage as jax-traceable
        (a pure array adapter), which lets :meth:`build` with ``fuse=True``
        pull it *inside* a fused region instead of treating it as a
        Python-stage boundary. Kernel declarations are traceable by
        definition and ignore the flag.
        """
        kind, _sig = self._classify(target)
        node = self._add(kind, target, name or _target_name(target),
                         1, 1, device=device, splat=True,
                         options={"traceable": True} if traceable else None)
        self.bind(node, 0, port)
        return node.out(0)

    # -- combinators -------------------------------------------------------
    def broadcast(self, port: Port, n: int, *, name: str = "broadcast"
                  ) -> Tuple[Port, ...]:
        """Fan-out: the same value (for a :class:`DeviceRef`, the same
        device buffer — no copy) is delivered to ``n`` consumers. Ref
        fan-out is *read-sharing*: each branch receives a read-only view,
        so a donating ``InOut`` consumer raises ``AccessViolation``
        instead of pulling the buffer out from under its siblings."""
        if n < 2:
            raise GraphError(f"{self.name}/{name}: broadcast needs n >= 2")
        node = self._add("broadcast", None, name, 1, n)
        self.bind(node, 0, port)
        return node.outs()

    def zip_join(self, *ports: Port, name: str = "zip_join"
                 ) -> Tuple[Port, ...]:
        """Fan-in barrier: output ``i`` forwards input ``i``, but no output
        is delivered until **every** input has arrived (the paper's
        multi-producer join before a dependent kernel)."""
        if len(ports) < 2:
            raise GraphError(f"{self.name}/{name}: zip_join needs >= 2 ports")
        node = self._add("zip_join", None, name, len(ports), len(ports))
        for i, p in enumerate(ports):
            self.bind(node, i, p)
        return node.outs()

    def select(self, port: Port, pred: Callable[[Any], int], n: int = 2,
               *, name: str = "select") -> Tuple[Port, ...]:
        """Predicate routing: ``pred(value)`` picks which of the ``n``
        branches receives the value; the others are marked *dead* and
        deadness propagates (a :meth:`merge` downstream resolves it).

        ``pred`` sees the raw edge value — a :class:`DeviceRef` when the
        producer emits refs. Routing on data *content* then requires an
        explicit ``.to_value()`` read-back (counted in the registry);
        routing on metadata (``shape``/``dtype``/``nbytes``) stays free.
        """
        if n < 2:
            raise GraphError(f"{self.name}/{name}: select needs n >= 2")
        if not callable(pred):
            raise GraphError(f"{self.name}/{name}: pred must be callable")
        node = self._add("select", None, name, 1, n, options={"pred": pred})
        self.bind(node, 0, port)
        return node.outs()

    def merge(self, *ports: Port, name: str = "merge") -> Port:
        """First-arrival-wins fan-in: forwards the first live value among
        its inputs (losers are released); dead only if *all* inputs are
        dead. The dual of :meth:`select` — together they express
        conditional and speculative branches."""
        if len(ports) < 2:
            raise GraphError(f"{self.name}/{name}: merge needs >= 2 ports")
        node = self._add("merge", None, name, len(ports), 1)
        for i, p in enumerate(ports):
            self.bind(node, i, p)
        return node.out(0)

    def map_over(self, target: KernelDecl, port: Port, *, chunks: int = 4,
                 replicas: int = 2, policy: str = "least_loaded",
                 devices: Optional[Sequence] = None,
                 timeout: Optional[float] = 300.0,
                 name: Optional[str] = None,
                 min_chunk_bytes: int = 1 << 20,
                 **scheduler_kwargs) -> Port:
        """Per-chunk fan-out: split the value along axis 0 into ``chunks``
        device-resident slices, dispatch them through a
        :class:`~repro.core.scheduler.ChunkScheduler` over a pool of
        ``replicas`` kernel actors (placement-aware, straggler re-issuing),
        and concatenate the results on device.

        Each chunk pays a fixed dispatch constant (a mailbox hop, a
        device-side slice, a scheduler round-trip — BENCH_PR5 puts the hop
        alone near 300 µs), so chunking only wins once per-chunk compute
        dwarfs it. ``min_chunk_bytes`` (default 1 MiB) caps the effective
        chunk count so no slice drops below that size: small inputs
        degrade gracefully to a single whole-array dispatch instead of
        paying ``chunks`` dispatch constants for sub-millisecond kernels
        (the BENCH_PR4 ``diamond_graph_mapped`` regression). Pass
        ``min_chunk_bytes=0`` to force the requested chunk count."""
        if not isinstance(target, KernelDecl):
            raise GraphError(
                f"{self.name}/{name or _target_name(target)}: map_over "
                f"needs a @kernel declaration, got {target!r}")
        if len(target.signature.input_specs) != 1 or \
                len(target.signature.output_specs) != 1:
            raise GraphError(
                f"{self.name}/{name or _target_name(target)}: map_over "
                "kernels must take exactly one input and one output")
        if target.preprocess is not None:
            raise GraphError(
                f"{self.name}/{name or _target_name(target)}: map_over "
                "dispatches device-resident chunk refs, which a kernel "
                "preprocess (running before ref unwrapping) cannot see; "
                "apply the preprocess as a separate stage instead")
        node = self._add(
            "map_over", target, name or f"map_{_target_name(target)}", 1, 1,
            options={"chunks": int(chunks), "replicas": int(replicas),
                     "policy": policy, "devices": devices, "timeout": timeout,
                     "min_chunk_bytes": int(min_chunk_bytes),
                     "scheduler": dict(scheduler_kwargs)})
        self.bind(node, 0, port)
        return node.out(0)

    def output(self, *ports: Port) -> "Graph":
        """Declare the graph's result port(s); a single output resolves to
        its bare value, several to a tuple."""
        for p in ports:
            if p.node.graph is not self:
                raise GraphError(f"{p.path}: port belongs to another graph")
            self.outputs.append(p)
        return self

    # -- introspection -----------------------------------------------------
    def _classify(self, target):
        """(kind, kernel_signature_or_None) for an apply/node target."""
        if isinstance(target, KernelDecl):
            return "kernel", target.signature
        if isinstance(target, ActorRef):
            ka = self._kernel_actor_of(target)
            return "actor", (ka.signature if ka is not None else None)
        if callable(target):
            return "func", None
        raise GraphError(f"{self.name}: cannot add node for {target!r}")

    def _kernel_actor_of(self, ref: ActorRef):
        from .facade import KernelActor
        st = self.system._actors.get(ref.actor_id)
        actor = st.actor if st else None
        return actor if isinstance(actor, KernelActor) else None

    # -- validation --------------------------------------------------------
    def validate(self) -> List[GraphNode]:
        """Check the topology and propagate port types; returns the nodes
        in topological order. All errors are
        :class:`~repro.core.errors.GraphError` subclasses naming the
        offending node path."""
        if not self.nodes:
            raise GraphError(f"graph {self.name!r} has no nodes")
        if not self.outputs:
            raise GraphError(f"graph {self.name!r} declares no outputs; "
                             "call Graph.output(port) before build()")
        for node in self.nodes:
            for slot, p in enumerate(node.inputs):
                if p is None:
                    raise DanglingPortError(
                        f"{node.path}: input slot {slot} was never bound "
                        f"(wire it with Graph.bind or Graph.apply)")
        topo = self._toposort()
        consumers = self._consumers()
        outset = {p.key for p in self.outputs}
        for node in self.nodes:
            for oi in range(node.n_out):
                if not consumers.get((node.idx, oi)) and \
                        (node.idx, oi) not in outset:
                    raise DanglingPortError(
                        f"{node.path}: output port {oi} has no consumer and "
                        "is not a graph output — device-resident data would "
                        "be produced and leaked")
        for node in topo:
            self._type_node(node)
        return topo

    def _consumers(self) -> Dict[Tuple[int, int], List[Tuple[int, int]]]:
        consumers: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        for node in self.nodes:
            for slot, p in enumerate(node.inputs):
                consumers.setdefault(p.key, []).append((node.idx, slot))
        return consumers

    def _toposort(self) -> List[GraphNode]:
        """Kahn's algorithm; a leftover node set means a cycle — report it
        by walking the cycle's node paths."""
        indeg = {n.idx: 0 for n in self.nodes}
        succ: Dict[int, List[int]] = {n.idx: [] for n in self.nodes}
        for node in self.nodes:
            for p in node.inputs:
                indeg[node.idx] += 1
                succ[p.node.idx].append(node.idx)
        ready = [n.idx for n in self.nodes if indeg[n.idx] == 0]
        order: List[int] = []
        while ready:
            i = ready.pop()
            order.append(i)
            for j in succ[i]:
                indeg[j] -= 1
                if indeg[j] == 0:
                    ready.append(j)
        if len(order) != len(self.nodes):
            stuck = {i for i, d in indeg.items() if d > 0}
            # walk one cycle for the diagnostic
            start = min(stuck)
            cycle, cur = [start], start
            while True:
                cur = next(p.node.idx for p in self.nodes[cur].inputs
                           if p.node.idx in stuck)
                if cur in cycle:
                    cycle.append(cur)
                    break
                cycle.append(cur)
            path = " -> ".join(self.nodes[i].path for i in reversed(cycle))
            raise GraphCycleError(
                f"graph {self.name!r} contains a cycle: {path}")
        return [self.nodes[i] for i in order]

    def _type_node(self, node: GraphNode) -> None:
        """Propagate/validate port types for one node (topo order)."""
        in_types = [p.type for p in node.inputs]
        if node.kind in ("kernel", "actor"):
            sig, pre = self._sig_of(node)
            if sig is None or node.splat:
                return
            if node.n_in != len(sig.input_specs):
                raise ArityMismatchError(
                    f"{node.path}: kernel signature declares "
                    f"{len(sig.input_specs)} inputs, wired with {node.n_in}")
            structs: Optional[List] = []
            for slot, (spec, t) in enumerate(zip(sig.input_specs, in_types)):
                self._check_edge(node, slot, spec, t)
                if structs is not None and t.shape is not None:
                    structs.append(jax.ShapeDtypeStruct(t.shape, spec.np_dtype))
                else:
                    structs = None      # some shape unknown: cannot eval
            self._type_kernel_outputs(node, sig, structs)
        elif node.kind == "map_over":
            sig = node.target.signature
            self._check_edge(node, 0, sig.input_specs[0], in_types[0])
            node.out_types[0] = PortType.of(sig.output_specs[0].np_dtype)
        elif node.kind == "broadcast":
            node.out_types = [in_types[0]] * node.n_out
        elif node.kind in ("zip_join",):
            node.out_types = list(in_types)
        elif node.kind == "select":
            node.out_types = [in_types[0]] * node.n_out
        elif node.kind == "merge":
            node.out_types = [in_types[0] if len(set(in_types)) == 1
                              else PortType()]
        # func/source: declared or unknown — nothing to derive

    def _sig_of(self, node: GraphNode):
        """(signature, preprocess) of a kernel-backed node, else (None, _)."""
        if node.kind == "kernel":
            return node.target.signature, node.target.preprocess
        ka = self._kernel_actor_of(node.target)
        if ka is None:
            return None, None
        return ka.signature, ka.preprocess

    def _check_edge(self, node: GraphNode, slot: int, spec, t: PortType
                    ) -> None:
        producer = node.inputs[slot].node
        if t.dtype is not None and t.dtype != spec.np_dtype:
            raise PortTypeMismatchError(
                f"{node.path}: input {slot} expects dtype "
                f"{spec.np_dtype.name}, but upstream port {producer.path} "
                f"carries {t.dtype.name}")
        if t.shape is not None and spec.shape is not None and \
                t.shape != tuple(spec.shape):
            raise PortTypeMismatchError(
                f"{node.path}: input {slot} expects shape "
                f"{tuple(spec.shape)}, but upstream port {producer.path} "
                f"carries {t.shape}")

    def _type_kernel_outputs(self, node: GraphNode, sig, structs) -> None:
        """Derive output port types, preferring ``jax.eval_shape`` over the
        declared specs; an eval'd dtype contradicting the declared Out spec
        is a build-time type error (it would die at runtime anyway)."""
        evaled = None
        if structs is not None and len(structs) == len(sig.input_specs):
            try:
                evaled = self._out_structs_of(node, structs)
            except Exception:
                evaled = None       # untraceable: fall back to declared specs
        types = []
        for oi, spec in enumerate(sig.output_specs):
            if evaled is not None and oi < len(evaled):
                st = evaled[oi]
                if np.dtype(st.dtype) != spec.np_dtype:
                    raise PortTypeMismatchError(
                        f"{node.path}: output {oi} declared as "
                        f"{spec.np_dtype.name} but the kernel computes "
                        f"{np.dtype(st.dtype).name}")
                types.append(PortType.of(st.dtype, st.shape))
            else:
                types.append(PortType.of(spec.np_dtype, spec.shape))
        if len(types) == node.n_out:
            node.out_types = types

    def _out_structs_of(self, node: GraphNode, structs):
        if node.kind == "kernel":
            return node.target.out_structs(structs)
        return self._kernel_actor_of(node.target).out_structs(structs)

    # -- lowering ----------------------------------------------------------
    def build(self, fuse: bool = False,
              remotes: Sequence[NodeTarget] = ()) -> "GraphRef":
        """Validate, place, lower, and spawn; returns a :class:`GraphRef`.

        Placement is delegated to the process-wide
        :class:`~repro.core.placement.PlacementService`: explicit
        ``device=`` pins win, then upstream inheritance, then the
        least-loaded local device — and with ``remotes=`` (a sequence of
        :class:`~repro.core.placement.NodeTarget`\\ s wrapping connected
        peers) kernel nodes may land *cross-node*, but only where the
        wire cost model says the hop is cheaper than staying local (e.g.
        because int8 compression amortizes it, or the peer is idle while
        every local device is saturated). The per-node
        :class:`~repro.core.placement.PlacementDecision` audit records are
        exposed as ``GraphRef.placement_decisions``.

        Interior kernel edges are lowered to ``emit="ref"`` actors (zero
        host transfers between nodes); terminal kernels — those feeding a
        graph output or a non-ref-capable consumer — keep their declared
        value/reference semantics.

        With ``fuse=True`` the placed DAG first runs through a
        **trace-time fusion pass**: maximal linear regions of kernel nodes
        (plus ``traceable`` adapter callables) on one device — containing
        no fan-out/fan-in/``select``/``merge`` boundary, no opaque actor
        node, and no port escaping as a graph output — collapse into a
        *single* jitted callable behind one
        :class:`~repro.core.facade.KernelActor` (the paper's §3.6 kernel
        composition done once at build time instead of per-message at
        actor-hop time). Region boundaries keep exactly the emission
        semantics the unfused graph would have had, and the grouping is
        reported via ``GraphRef.plan.fused_regions``.
        """
        topo = self.validate()
        consumers = self._consumers()
        outset = {p.key for p in self.outputs}
        mngr = self.system.opencl_manager()

        refcap = {n.idx: self._ref_capable(n) for n in self.nodes}
        # placement runs over the whole DAG before anything is spawned:
        # the fusion pass and the inline-dispatch table both need every
        # node's device up front. The cost-model service decides; this
        # module only describes the sites (pins, edges, typed byte sizes)
        sites = [self._placement_site(n) for n in topo
                 if n.kind in _ACTOR_KINDS]
        placements, decisions = placement_service().place_graph(
            sites, mngr.devices(), remotes=list(remotes),
            context=f"graph:{id(self):x}")

        regions = (self._fuse_regions(topo, consumers, outset, placements)
                   if fuse else [])
        member_of: Dict[int, int] = {}
        tail_of: Dict[int, int] = {}
        by_head: Dict[int, List[GraphNode]] = {}
        for region in regions:
            head = region[0].idx
            by_head[head] = region
            tail_of[head] = region[-1].idx
            for n in region:
                member_of[n.idx] = head

        refs: Dict[int, Optional[ActorRef]] = {}
        private: set = set()        # node idxs whose ref this build spawned
        for node in topo:
            if node.kind not in _ACTOR_KINDS:
                refs[node.idx] = None
                continue
            head = member_of.get(node.idx)
            if head is not None and head != node.idx:
                refs[node.idx] = None   # interior member of a fused region
                continue
            device = placements.get(node.idx)
            if head is not None:
                region = by_head[head]
                want = self._wants_ref(region[-1], consumers, outset, refcap)
                refs[node.idx] = self._spawn_fused(region, device, want)
                private.add(node.idx)
            else:
                want = self._wants_ref(node, consumers, outset, refcap)
                refs[node.idx] = self._spawn_node(node, device, want, mngr)
                if node.kind != "actor" or refs[node.idx] is not node.target:
                    private.add(node.idx)

        inline_ok = {
            n.idx: self._inline_eligible(n, refs[n.idx], consumers, outset,
                                         placements, private)
            for n in self.nodes if refs.get(n.idx) is not None}
        plan = GraphPlan(self, topo, consumers, refs, placements,
                         regions=regions, member_of=member_of,
                         tail_of=tail_of, inline_ok=inline_ok)
        plan.decisions = decisions
        ref = self.system.spawn(_GraphActor(plan))
        gref = GraphRef(ref.actor_id, self.system)
        gref.plan = plan
        gref.placements = {self.nodes[i].path: d
                           for i, d in placements.items()}
        gref.node_refs = {self.nodes[i].path: r
                          for i, r in refs.items() if r is not None}
        gref.placement_decisions = decisions
        return gref

    # -- fusion pass -------------------------------------------------------
    def _fusible_node(self, node: GraphNode) -> bool:
        """May this node live *inside* a fused region? Kernel declarations
        always; bare callables only when marked ``traceable`` (an opaque
        Python stage may block, perform I/O, or inspect concrete values —
        none of which survives a jit trace). Existing actor refs never
        fuse: their behavior is not a traceable function."""
        if node.kind == "kernel":
            return True
        return node.kind == "func" and bool(node.options.get("traceable"))

    def _fuse_successor(self, u: GraphNode, consumers, outset, placements
                        ) -> Optional[GraphNode]:
        """The unique node a region ending in ``u`` may extend into, or
        ``None`` at a fusion boundary: fan-out (several consumers), an
        escaping output port, external fan-in into the successor, a
        postprocess on ``u`` (must stay a region tail — it runs on the
        emitted representation), a preprocess on the successor (must stay
        a region head — it runs on the raw payload), or a device change."""
        if u.kind == "kernel" and u.target.postprocess is not None:
            return None
        v: Optional[GraphNode] = None
        for oi in range(u.n_out):
            key = (u.idx, oi)
            if key in outset:
                return None
            for dst, _slot in consumers.get(key, ()):
                cand = self.nodes[dst]
                if v is None:
                    v = cand
                elif cand is not v:
                    return None
        if v is None:
            return None
        if any(p.node is not u for p in v.inputs):
            return None
        if v.kind == "kernel" and v.target.preprocess is not None:
            return None
        du, dv = placements.get(u.idx), placements.get(v.idx)
        if isinstance(du, NodeTarget) or isinstance(dv, NodeTarget):
            # a remotely placed node runs inside another process; its
            # traceable cannot join a locally jitted region
            return None
        if du is None and dv is None:
            return v
        if du is None or dv is None:
            return None
        if du is not dv and getattr(du, "jax_device", du) != \
                getattr(dv, "jax_device", dv):
            return None
        return v

    def _fuse_regions(self, topo, consumers, outset, placements
                      ) -> List[List[GraphNode]]:
        """Greedy maximal linear regions over the placed DAG (topo order
        guarantees a chain's earliest node is visited first, so every
        region starts at its true head). Single-node regions are dropped —
        nothing to fuse — as are all-adapter regions (no kernel signature
        to anchor the fused actor's specs on)."""
        regions: List[List[GraphNode]] = []
        assigned: set = set()
        for node in topo:
            if node.idx in assigned or not self._fusible_node(node) or \
                    isinstance(placements.get(node.idx), NodeTarget):
                continue
            region = [node]
            while True:
                nxt = self._fuse_successor(region[-1], consumers, outset,
                                           placements)
                if nxt is None or nxt.idx in assigned or \
                        not self._fusible_node(nxt):
                    break
                region.append(nxt)
            if len(region) >= 2 and any(n.kind == "kernel" for n in region):
                regions.append(region)
                assigned.update(n.idx for n in region)
        return regions

    def _spawn_fused(self, region: List[GraphNode], device, want_ref: bool
                     ) -> ActorRef:
        """One :class:`~repro.core.facade.KernelActor` for a fused region:
        the members' traceables are chained inside a single jit, so the
        whole region costs one actor hop and one XLA dispatch. Specs are
        the first kernel member's inputs plus the last kernel member's
        outputs (the fused-``Pipeline`` contract); the head's preprocess
        and the tail's postprocess — the only ones a region may contain —
        carry over to the fused actor."""
        from .facade import KernelActor
        steps: List[Tuple[GraphNode, Callable]] = []
        first_sig = last_sig = None
        first_nd = None
        donate = True
        for node in region:
            if node.kind == "kernel":
                decl: KernelDecl = node.target
                steps.append((node, _bound_fn(decl.fn, decl.nd_range,
                                              decl.signature.local_specs)))
                if first_sig is None:
                    first_sig, first_nd = decl.signature, decl.nd_range
                    donate = decl.donate
                last_sig = decl.signature
            else:               # traceable adapter callable
                steps.append((node, node.target))

        def fused_fn(*inputs):
            outs: Any = ()
            for pos, (node, f) in enumerate(steps):
                if pos == 0:
                    args = inputs
                elif node.splat:
                    args = outs if isinstance(outs, tuple) else (outs,)
                else:
                    norm = outs if isinstance(outs, tuple) else (outs,)
                    args = tuple(norm[p.index] for p in node.inputs)
                outs = f(*args)
            return outs

        head, tail = region[0], region[-1]
        specs = tuple(first_sig.input_specs) + tuple(last_sig.output_specs)
        mngr = self.system.opencl_manager()
        actor = KernelActor(
            fn=fused_fn,
            name="fused[" + "+".join(n.name for n in region) + "]",
            nd_range=first_nd, specs=specs,
            device=device if device is not None else mngr.find_device(),
            program=None,
            preprocess=(head.target.preprocess if head.kind == "kernel"
                        else None),
            postprocess=(tail.target.postprocess if tail.kind == "kernel"
                         else None),
            donate=donate,
            emit="ref" if want_ref else "declared",
            fused_from=tuple(n.path for n in region))
        return self.system.spawn(actor)

    # -- inline-dispatch eligibility ---------------------------------------
    def _effective_producer(self, port: Port) -> Optional[GraphNode]:
        """The actor/source node whose value actually flows through
        ``port``, walking back through structural nodes; ``None`` when the
        path crosses a value-sharing node (``broadcast`` — inlining one
        arm would serialize its siblings on the producer's thread) or a
        racy fan-in (``merge`` — the loser's speculative work must keep
        its own mailbox)."""
        node = port.node
        while node.kind in _STRUCTURAL:
            if node.kind in ("broadcast", "merge"):
                return None
            port = (node.inputs[0] if node.kind == "select"
                    else node.inputs[port.index])
            node = port.node
        return node

    def _inline_eligible(self, node: GraphNode, ref, consumers, outset,
                         placements, private) -> bool:
        """May the orchestrator dispatch this node by calling its behavior
        directly instead of enqueueing (the hot-path bypass)? Only when
        the ref is private to this build (nobody else can observe its
        mailbox ordering) and local, and every in-edge is single-consumer
        from a same-device unshared producer. Monitors/links are a runtime
        condition and are re-checked per call in
        :meth:`~repro.core.actor.ActorSystem.try_call_inline`."""
        if node.idx not in private or getattr(ref, "is_remote", False):
            return False
        vd = placements.get(node.idx)
        for p in node.inputs:
            if p.key in outset or len(consumers.get(p.key, ())) != 1:
                return False
            prod = self._effective_producer(p)
            if prod is None:
                return False
            if prod.kind == "source":
                continue        # payload arrives host-side anyway
            pd = placements.get(prod.idx)
            if pd is not None and vd is not None and pd is not vd and \
                    getattr(pd, "jax_device", pd) != \
                    getattr(vd, "jax_device", vd):
                return False
        return True

    def _ref_capable(self, node: GraphNode) -> bool:
        """Can this node consume DeviceRef payloads? Kernel-backed nodes
        without a preprocess can (the preprocess runs on the raw payload
        *before* ref unwrapping); map_over splits refs device-side."""
        if node.kind == "kernel":
            return node.target.preprocess is None
        if node.kind == "actor":
            ka = self._kernel_actor_of(node.target)
            return ka is not None and ka.preprocess is None
        return node.kind == "map_over"

    def _terminals(self, key: Tuple[int, int], consumers, outset,
                   acc: set, seen: set) -> None:
        """Terminal consumers of a port, walking *through* structural
        nodes; graph outputs contribute the sentinel ``-1`` (host)."""
        if key in seen:
            return
        seen.add(key)
        if key in outset:
            acc.add(-1)
        for dst, slot in consumers.get(key, ()):
            node = self.nodes[dst]
            if node.kind == "broadcast" or node.kind == "select":
                for oi in range(node.n_out):
                    self._terminals((dst, oi), consumers, outset, acc, seen)
            elif node.kind == "zip_join":
                self._terminals((dst, slot), consumers, outset, acc, seen)
            elif node.kind == "merge":
                self._terminals((dst, 0), consumers, outset, acc, seen)
            else:
                acc.add(dst)

    def _wants_ref(self, node: GraphNode, consumers, outset, refcap) -> bool:
        """Should this producer emit DeviceRefs? Only when every terminal
        consumer of every output port can unwrap them, none of its ports
        escapes as a graph output, and it has no postprocess (which runs on
        the emitted representation)."""
        if node.kind == "kernel":
            if node.target.postprocess is not None:
                return False
        elif node.kind == "actor":
            ka = self._kernel_actor_of(node.target)
            if ka is None or ka.postprocess is not None:
                return False
        elif node.kind != "map_over":
            return False
        for oi in range(node.n_out):
            acc: set = set()
            self._terminals((node.idx, oi), consumers, outset, acc, set())
            if not acc or -1 in acc or not all(refcap[t] for t in acc):
                return False
        return True

    def _placement_site(self, node: GraphNode) -> GraphSite:
        """Describe one node to the placement service: explicit pins,
        upstream producers (inheritance candidates), and the typed edge
        byte sizes the wire-cost model prices a cross-node hop by.
        Existing actor refs are *fixed* — they already live somewhere —
        and only kernel declarations may be spawned remotely (their
        declarations pickle; opaque Python stages and map_over pools stay
        on the driver)."""
        pinned, fixed = node.device, False
        if node.kind == "actor":
            ka = self._kernel_actor_of(node.target)
            pinned = ka.device if ka is not None else None
            fixed = True
        return GraphSite(
            idx=node.idx, path=node.path, pinned=pinned, fixed=fixed,
            producers=tuple(p.node.idx for p in node.inputs
                            if p is not None),
            in_bytes=_edge_bytes(p.type for p in node.inputs
                                 if p is not None),
            out_bytes=_edge_bytes(node.out_types),
            remote_ok=node.kind == "kernel" and node.device is None)

    def _spawn_node(self, node: GraphNode, device, want_ref: bool, mngr
                    ) -> ActorRef:
        if node.kind == "kernel":
            if isinstance(device, NodeTarget):
                # cross-node placement: the declaration pickles over the
                # wire and spawns in the peer's actor system; data routing
                # is unchanged (requests auto-spill at the wire, replies
                # unspill onto the driver's device)
                return device.spawn(node.target,
                                    emit="ref" if want_ref else "declared")
            return mngr.spawn(node.target, device=device,
                              emit="ref" if want_ref else "declared")
        if node.kind == "actor":
            ka = self._kernel_actor_of(node.target)
            if want_ref and ka is not None and ka.emit != "ref":
                # clone, never mutate: the original actor keeps its
                # declared semantics for direct callers
                return self.system.spawn(ka.clone(emit="ref"))
            return node.target
        if node.kind == "func":
            return self.system.spawn(node.target)
        return self._spawn_map(node, device, want_ref, mngr)

    def _spawn_map(self, node: GraphNode, device, want_ref: bool, mngr
                   ) -> ActorRef:
        from .scheduler import ChunkScheduler
        opts = node.options
        decl: KernelDecl = node.target
        devices = opts["devices"]
        if devices is None and device is not None:
            devices = [device]
        pool = mngr.spawn_pool(
            decl, opts["replicas"], policy=opts["policy"], devices=devices,
            emit="ref" if decl.postprocess is None else "declared")
        chunks, timeout = opts["chunks"], opts["timeout"]
        min_bytes = opts.get("min_chunk_bytes", 0)
        sched_kwargs = opts["scheduler"]

        def run_map(x):
            arr = x.array if isinstance(x, DeviceRef) else as_device_array(x)
            n = int(arr.shape[0])
            k = max(1, min(chunks, n))
            if min_bytes and arr.nbytes and arr.nbytes // k < min_bytes:
                # sub-threshold slices can't amortize the per-chunk
                # dispatch constant; shrink the chunk count (down to a
                # single whole-array dispatch) instead of paying it k times
                k = max(1, min(k, int(arr.nbytes) // min_bytes))
            bounds = np.linspace(0, n, k + 1).astype(int)
            owned, payloads = [], []
            for a, b in zip(bounds[:-1], bounds[1:]):
                if a == b:
                    continue
                c = DeviceRef(arr[a:b], access="r")   # device-side slice
                owned.append(c)
                payloads.append((c,))
            if not payloads:
                # empty leading axis: run one empty chunk through the
                # kernel so the result has the kernel's output dtype/shape
                c = DeviceRef(arr[:0], access="r")
                owned.append(c)
                payloads.append((c,))
            results: list = []
            try:
                results = ChunkScheduler(pool, **sched_kwargs).run(
                    payloads, timeout=timeout)
                parts = [r.array if isinstance(r, DeviceRef)
                         else jnp.asarray(r) for r in results]
                out = jnp.concatenate(parts, axis=0)
            finally:
                for c in owned:
                    c.release()
                # chunk result refs too — on success their arrays are
                # already captured by the concat, on failure nobody else
                # will release them
                for r in results:
                    if isinstance(r, DeviceRef):
                        r.release()
            if want_ref:
                return DeviceRef(out)
            registry.count_readback()
            return np.asarray(jax.device_get(out))

        return self.system.spawn(run_map)


def _target_name(target) -> str:
    return getattr(target, "name", None) or \
        getattr(target, "__name__", None) or type(target).__name__


# ----------------------------------------------------------------------------
# runtime plan + orchestrator
# ----------------------------------------------------------------------------
class GraphPlan:
    """Everything the orchestrator needs at runtime, frozen at build.

    The fusion pass and the dispatch fast path surface here:
    ``fused_regions`` (node-path groups, one list per fused
    :class:`~repro.core.facade.KernelActor`), ``member_of``/``produce_as``
    (member idx → region head / head idx → region tail — how a fused
    actor's single reply is attributed to the tail's output ports),
    ``inline_ok`` (per-node verdict of the build-time inline-dispatch
    analysis), and ``counters`` (``inline`` vs ``mailbox`` dispatch
    counts, served by :attr:`GraphRef.dispatch_stats`)."""

    __slots__ = ("name", "nodes", "order", "sources", "outputs", "outset",
                 "consumers", "refs", "placements", "chain_refs",
                 "fused_regions", "member_of", "produce_as", "inline_ok",
                 "counters", "_counters_lock", "decisions")

    def __init__(self, graph: Graph, topo, consumers, refs, placements, *,
                 regions=(), member_of=None, tail_of=None, inline_ok=None):
        self.name = graph.name
        self.nodes = list(graph.nodes)
        self.order = [n.idx for n in topo]
        self.sources = [n.idx for n in graph.nodes if n.kind == "source"]
        self.outputs = [p.key for p in graph.outputs]
        self.outset = set(self.outputs)
        self.consumers = consumers
        self.refs = refs
        self.placements = placements
        #: per-node PlacementDecision audit records (set by build())
        self.decisions: list = []
        self.fused_regions = [[n.path for n in r] for r in regions]
        self.member_of = dict(member_of or {})
        self.produce_as = dict(tail_of or {})
        self.inline_ok = dict(inline_ok or {})
        self.counters = {"inline": 0, "mailbox": 0}
        self._counters_lock = make_lock("GraphCounters")
        self.chain_refs = self._linear_chain()

    def count_dispatch(self, kind: str) -> None:
        with self._counters_lock:
            self.counters[kind] += 1

    def _linear_chain(self) -> Optional[List[ActorRef]]:
        """The underlying stage refs when this graph is a pure linear
        chain — lets an outer ``Pipeline`` inline a built pipe's stages
        (the pre-composed-chain flattening the v1 builder did for
        :class:`~repro.core.compose.ComposedActor`). Fused interiors carry
        no ref of their own; the region's single fused actor stands in as
        one chain stage."""
        if len(self.sources) != 1 or len(self.outputs) != 1:
            return None
        if any(n.kind not in ("source",) + _ACTOR_KINDS or n.n_out != 1
               or n.n_in > 1 for n in self.nodes):
            return None
        prev, chain = self.sources[0], []
        for idx in self.order:
            node = self.nodes[idx]
            if node.kind == "source":
                continue
            p = node.inputs[0]
            if p.node.idx != prev or p.index != 0:
                return None
            r = self.refs[idx]
            if r is not None:
                chain.append(r)
            prev = idx
        if self.outputs[0] != (prev, 0) or not chain:
            return None
        return chain


#: backward-compat alias (pre-PR7 internal name)
_Plan = GraphPlan


class _GraphActor(Actor):
    """The spawned orchestrator: each message starts one :class:`_GraphRun`
    and responds with its promise (paper §3.5 response delegation).

    Runs entered through the mailbox keep ``allow_inline=False``: pools
    and chunk schedulers issue ``request``\\ s while holding their own
    locks, and running whole graph traversals synchronously under those
    locks would serialize their dispatch. The inline fast path belongs to
    :meth:`GraphRef.ask`, whose caller blocks on the result anyway."""

    def __init__(self, plan: GraphPlan):
        super().__init__()
        self.plan = plan

    def receive(self, *payload: Any) -> Future:
        out: Future = Future()
        _GraphRun(self.plan, payload, out).start()
        return out


class GraphRef(ActorRef):
    """An :class:`ActorRef` to a built graph, plus build artifacts:
    ``placements`` (node path → Device or
    :class:`~repro.core.placement.NodeTarget`), ``node_refs`` (node path →
    ActorRef), ``placement_decisions`` (the cost-model service's auditable
    per-node records), and the plan used by Pipeline inlining (which also
    carries ``plan.fused_regions`` and the dispatch counters behind
    :attr:`dispatch_stats`).

    :meth:`ask` runs the plan **directly on the calling thread** instead
    of hopping through the orchestrator's mailbox, with the
    inline-dispatch fast path enabled: on a fused linear chain a request
    costs one jit call plus plain function dispatch — the paper's
    "negligible overhead" claim. ``send``/``request`` keep the ordinary
    mailbox path (and with it PR 5's supervision semantics end to end).
    """

    __slots__ = ("plan", "placements", "node_refs", "placement_decisions")

    @property
    def dispatch_stats(self) -> dict:
        """Cumulative ``{"inline": n, "mailbox": m}`` dispatch counts
        across every run of this graph since build."""
        with self.plan._counters_lock:
            return dict(self.plan.counters)

    def ask(self, *payload: Any, timeout: Any = _UNSET) -> Any:
        st = self._system._actors.get(self.actor_id)
        if st is None or not st.alive:
            # dead/killed orchestrator: fall through to the mailbox path
            # so the caller sees the same ActorFailed it always did
            return super().ask(*payload, timeout=timeout)
        if timeout is _UNSET:
            timeout = getattr(self._system, "default_ask_timeout", 120.0)
        out: Future = Future()
        _GraphRun(self.plan, payload, out, allow_inline=True).start()
        try:
            return out.result(timeout=timeout)
        except FuturesTimeout:
            if out.done():
                raise       # the graph itself raised a TimeoutError
            raise FuturesTimeout(
                f"ask() timed out after {timeout}s waiting on graph "
                f"{self.plan.name!r}") from None

    def __repr__(self):
        return (f"GraphRef#{self.actor_id}({self.plan.name!r}, "
                f"{len(self.plan.nodes)} nodes)")


#: sentinel flowing down unselected select() branches
_DEAD = object()


def _iter_refs(value):
    if isinstance(value, DeviceRef):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _iter_refs(v)


class _GraphRun:
    """One request's traversal of the plan.

    Values are routed node-to-node as they become available; structural
    nodes (broadcast / zip_join / select / merge) are resolved inline,
    actor-backed nodes get an asynchronous ``request`` whose completion
    continues the traversal. Every :class:`DeviceRef` produced inside the
    run is registered and — once the run has settled (result delivered and
    all in-flight node futures done) — released, unless it escaped into
    the final result or came in with the caller's payload. This is the DAG
    generalization of ``ComposedActor``'s chain ownership: a graph run
    leaves no live intermediate refs behind, on success *or* failure.
    """

    def __init__(self, plan: GraphPlan, payload: tuple, out: Future,
                 allow_inline: bool = False):
        self.plan = plan
        self.payload = payload
        self.out = out
        #: GraphRef.ask sets this: dispatch inline-eligible nodes by
        #: calling their behavior on this thread (caller blocks on the
        #: result anyway); mailbox-entered runs never do
        self.allow_inline = allow_inline
        # request() may complete synchronously in the issuing thread, so
        # the callback can re-enter while we still hold the lock
        self.lock = make_rlock("GraphRun")
        n = len(plan.nodes)
        self.slot_vals: List[List[Any]] = [[None] * node.n_in
                                           for node in plan.nodes]
        self.got = [0] * n
        self.fired = [False] * n
        self.merge_dead = [0] * n
        self.inflight = 0
        self.refs: Dict[int, DeviceRef] = {}
        self.protected: set = set()
        self.out_vals: Dict[Tuple[int, int], Any] = {}
        self.failed: Optional[BaseException] = None
        self.resolved = False
        self.finished = False

    # -- entry ----------------------------------------------------------
    def start(self) -> None:
        plan = self.plan
        requests: List[Tuple[int, tuple]] = []
        with self.lock:
            for r in _iter_refs(self.payload):
                self.protected.add(id(r))   # caller owns its input refs
            srcs = plan.sources
            if len(srcs) == 1 and plan.nodes[srcs[0]].splat:
                vals = [self.payload]
            elif len(self.payload) == len(srcs):
                vals = list(self.payload)
            else:
                self._record_failure(GraphError(
                    f"graph {plan.name!r} has {len(srcs)} source(s), "
                    f"request carried {len(self.payload)} value(s)"))
                self._settle()
                return
            # zero-input non-source nodes (constant producers) have no
            # delivery to trigger them — they are ready immediately
            stack: List[int] = [n.idx for n in plan.nodes
                                if n.kind != "source" and n.n_in == 0]
            for idx, v in zip(srcs, vals):
                self.fired[idx] = True
                self._produce(idx, [v], stack)
            self._drain(stack, requests)
        self._issue(requests)
        self._settle()

    # -- routing (lock held) --------------------------------------------
    def _produce(self, idx: int, outs: List[Any], stack: List[int]) -> None:
        for oi, v in enumerate(outs):
            for r in _iter_refs(v):
                self.refs[id(r)] = r
            key = (idx, oi)
            if key in self.plan.outset:
                self.out_vals[key] = v
            for dst, slot in self.plan.consumers.get(key, ()):
                self._deliver(dst, slot, v, stack)

    def _deliver(self, dst: int, slot: int, v: Any, stack: List[int]) -> None:
        node = self.plan.nodes[dst]
        if node.kind == "merge":
            if v is _DEAD:
                self.merge_dead[dst] += 1
                if self.merge_dead[dst] == node.n_in and not self.fired[dst]:
                    self.fired[dst] = True
                    self._produce(dst, [_DEAD], stack)
            elif not self.fired[dst]:
                self.fired[dst] = True          # first live value wins
                self._produce(dst, [v], stack)
            return                              # losers: swept at settle
        self.slot_vals[dst][slot] = v
        self.got[dst] += 1
        if self.got[dst] == node.n_in and not self.fired[dst]:
            stack.append(dst)

    def _drain(self, stack: List[int],
               requests: List[Tuple[int, tuple]]) -> None:
        """Fire ready nodes: structural ones inline, actor-backed ones by
        queueing a request to issue once the lock is released."""
        while stack:
            idx = stack.pop()
            if self.fired[idx] or self.failed is not None:
                continue
            self.fired[idx] = True
            node = self.plan.nodes[idx]
            vals = self.slot_vals[idx]
            if node.kind == "broadcast":
                v = vals[0]
                if isinstance(v, DeviceRef) and not v.is_spilled \
                        and v.readable and v.writable:
                    # fan-out is read-sharing: hand each branch a
                    # read-only view so a donating (InOut) consumer in
                    # one branch gets a deterministic AccessViolation
                    # instead of invalidating the buffer under siblings
                    outs = [v.restrict("r") for _ in range(node.n_out)]
                else:
                    outs = [v] * node.n_out
                self._produce(idx, outs, stack)
            elif node.kind == "zip_join":
                outs = ([_DEAD] * node.n_out if any(v is _DEAD for v in vals)
                        else list(vals))
                self._produce(idx, outs, stack)
            elif node.kind == "select":
                self._fire_select(idx, node, vals[0], stack)
            else:  # actor-backed
                if any(v is _DEAD for v in vals):
                    # deadness skips the whole fused region: attribute the
                    # dead outputs to the region tail, as a reply would be
                    out_idx = self.plan.produce_as.get(idx, idx)
                    self._produce(out_idx,
                                  [_DEAD] * self.plan.nodes[out_idx].n_out,
                                  stack)
                    continue
                if node.splat:
                    v = vals[0]
                    args = tuple(v) if isinstance(v, tuple) else (v,)
                else:
                    args = tuple(vals)
                self.inflight += 1
                requests.append((idx, args))

    def _fire_select(self, idx: int, node: GraphNode, v: Any,
                     stack: List[int]) -> None:
        if v is _DEAD:
            self._produce(idx, [_DEAD] * node.n_out, stack)
            return
        try:
            branch = int(node.options["pred"](v))
            if not 0 <= branch < node.n_out:
                raise GraphError(
                    f"{node.path}: predicate picked branch {branch}, node "
                    f"has {node.n_out}")
        except Exception as exc:
            self._record_failure(exc)
            return
        outs: List[Any] = [_DEAD] * node.n_out
        outs[branch] = v
        self._produce(idx, outs, stack)

    # -- async continuation ---------------------------------------------
    def _issue(self, requests: List[Tuple[int, tuple]]) -> None:
        plan = self.plan
        for idx, args in requests:
            ref = plan.refs[idx]
            if self.allow_inline and plan.inline_ok.get(idx):
                try:
                    ok, result = ref._system.try_call_inline(
                        ref.actor_id, args)
                except Exception as exc:
                    # the behavior raised: the actor is already terminated
                    # (monitors notified) — identical to the mailbox path
                    plan.count_dispatch("inline")
                    self._finish_node(idx, None, exc)
                    continue
                if ok:
                    plan.count_dispatch("inline")
                    if isinstance(result, Future):
                        # behavior delegated to a promise: continue async
                        result.add_done_callback(
                            lambda f, idx=idx: self._on_node_done(idx, f))
                    else:
                        self._finish_node(idx, result, None)
                    continue
                # miss (queued messages / concurrent drain / monitors
                # attached since build): fall back to the mailbox
            plan.count_dispatch("mailbox")
            fut = ref.request(*args)
            fut.add_done_callback(
                lambda f, idx=idx: self._on_node_done(idx, f))

    def _on_node_done(self, idx: int, fut: Future) -> None:
        exc = fut.exception()
        self._finish_node(idx, None if exc is not None else fut.result(), exc)

    def _finish_node(self, idx: int, result: Any,
                     exc: Optional[BaseException]) -> None:
        requests: List[Tuple[int, tuple]] = []
        with self.lock:
            self.inflight -= 1
            if exc is not None:
                self._record_failure(exc)
            else:
                for r in _iter_refs(result):
                    if self.finished:
                        # a straggler (merge loser) finished after the run
                        # settled: release immediately, nobody will
                        if id(r) not in self.protected:
                            r.release()
                    else:
                        self.refs[id(r)] = r
                if self.failed is None and not self.finished:
                    # a fused head replies for its whole region: outputs
                    # belong to the region *tail*'s ports
                    out_idx = self.plan.produce_as.get(idx, idx)
                    node = self.plan.nodes[out_idx]
                    if node.n_out > 1:
                        if not isinstance(result, tuple) or \
                                len(result) != node.n_out:
                            self._record_failure(GraphError(
                                f"{node.path}: expected {node.n_out} "
                                f"outputs, actor returned {result!r}"))
                        else:
                            stack: List[int] = []
                            self._produce(out_idx, list(result), stack)
                            self._drain(stack, requests)
                    else:
                        stack = []
                        self._produce(out_idx, [result], stack)
                        self._drain(stack, requests)
        self._issue(requests)
        self._settle()

    # -- completion ------------------------------------------------------
    def _record_failure(self, exc: BaseException) -> None:
        # lock held; first failure wins the response
        if self.failed is None:
            self.failed = exc

    def _settle(self) -> None:
        """Resolve the response as soon as it is determined; sweep
        intermediate refs once everything in flight has landed."""
        do_set = False
        set_exc: Optional[BaseException] = None
        set_val: Any = None
        cleanup: List[DeviceRef] = []
        with self.lock:
            if not self.resolved:
                if self.failed is not None:
                    self.resolved = do_set = True
                    set_exc = self.failed
                elif len(self.out_vals) == len(self.plan.outset):
                    self.resolved = do_set = True
                    vals = [self.out_vals[k] for k in self.plan.outputs]
                    vals = [None if v is _DEAD else v for v in vals]
                    for v in vals:
                        for r in _iter_refs(v):
                            self.protected.add(id(r))
                    set_val = vals[0] if len(vals) == 1 else tuple(vals)
            if self.resolved and self.inflight == 0 and not self.finished:
                self.finished = True
                cleanup = [r for rid, r in self.refs.items()
                           if rid not in self.protected]
        if do_set:          # exactly one caller flips resolved
            if set_exc is not None:
                self.out.set_exception(set_exc)
            else:
                self.out.set_result(set_val)
        for r in cleanup:
            try:
                r.release()
            except Exception:       # pragma: no cover - defensive
                pass  # lint: reclaiming a failed run's refs is best-effort
