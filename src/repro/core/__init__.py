"""The paper's contribution: OpenCL-style kernel actors for JAX/TPU.

The v2 surface is declarative — signature and index space are captured at
definition site, composition is a builder, pooling is one call:

    from repro.core import ActorSystem, NDRange, In, Out, dim_vec, kernel

    @kernel(In(jnp.float32), In(jnp.float32),
            Out(jnp.float32, shape=(n, n)),
            nd_range=NDRange(dim_vec(n, n)))
    def m_mult(a, b):
        return a @ b

    sys_ = ActorSystem()
    worker = sys_.spawn(m_mult)
    result = worker.ask(a, b)

    pipe = Pipeline(sys_, mode="auto").stage(m_mult).stage(scale).build()
    pool = sys_.opencl_manager().spawn_pool(m_mult, 4, policy="least_loaded")

Non-linear compositions use the typed DAG builder (``repro.core.Graph``):
nodes are kernels/actors/Python stages, edges are shape/dtype-checked
ports, and ``build()`` validates the topology before spawning — see the
README "Dataflow graphs" section and ``examples/graph_diamond.py``.

The v1 positional surface (``mngr.spawn(fn, name, nd_range, *specs)``,
``compose``, ``fuse``) remains available as deprecated shims.
"""
from .actor import Actor, ActorRef, ActorSystem, Message
from .api import ActorPool, KernelDecl, Pipeline, kernel
from .compose import ComposedActor, compose, fuse
from .errors import (AccessViolation, ActorError, ActorFailed,
                     ArityMismatchError, DanglingPortError, DeadlineExceeded,
                     DownMessage, ExitMessage, GraphCycleError, GraphError,
                     MailboxClosed, PortTypeMismatchError, SignatureMismatch)
from .facade import KernelActor
from .graph import Graph, GraphNode, GraphPlan, GraphRef, Port, PortType
from .manager import Device, DeviceManager, Platform, Program
from .memref import (DeviceRef, RefRegistry, as_device_array, live_ref_count,
                     memory_stats, payload_nbytes, reset_transfer_stats,
                     transfer_count, tree_release, tree_unwrap, tree_wrap)
from .placement import (NodeTarget, PlacementDecision, PlacementService,
                        WireCostModel)
from .placement import service as placement_service
from .placement import set_service as set_placement_service
from .scheduler import ChunkScheduler, split_offload
from .signature import In, InOut, KernelSignature, Local, NDRange, Out, Priv, dim_vec

__all__ = [
    "Actor", "ActorRef", "ActorSystem", "Message",
    "ActorPool", "KernelDecl", "Pipeline", "kernel",
    "ComposedActor", "compose", "fuse",
    "AccessViolation", "ActorError", "ActorFailed", "ArityMismatchError",
    "DanglingPortError", "DeadlineExceeded", "DownMessage", "ExitMessage",
    "GraphCycleError", "GraphError", "MailboxClosed",
    "PortTypeMismatchError", "SignatureMismatch",
    "KernelActor",
    "Graph", "GraphNode", "GraphPlan", "GraphRef", "Port", "PortType",
    "Device", "DeviceManager", "Platform", "Program",
    "DeviceRef", "RefRegistry", "as_device_array", "live_ref_count",
    "memory_stats", "reset_transfer_stats", "transfer_count",
    "tree_release", "tree_unwrap", "tree_wrap",
    "ChunkScheduler", "split_offload",
    "NodeTarget", "PlacementDecision", "PlacementService", "WireCostModel",
    "placement_service", "set_placement_service", "payload_nbytes",
    "In", "InOut", "KernelSignature", "Local", "NDRange", "Out", "Priv", "dim_vec",
]
