"""The paper's contribution: OpenCL-style kernel actors for JAX/TPU.

Public API mirrors the paper's CAF additions:

    from repro.core import ActorSystem, NDRange, dim_vec, In, Out, InOut

    sys_ = ActorSystem()
    mngr = sys_.opencl_manager()
    worker = mngr.spawn(m_mult, "m_mult", NDRange(dim_vec(n, n)),
                        In(jnp.float32), In(jnp.float32), Out(jnp.float32))
    result = worker.ask(a, b)
"""
from .actor import Actor, ActorRef, ActorSystem, Message
from .compose import ComposedActor, compose, fuse
from .errors import (ActorError, ActorFailed, DownMessage, ExitMessage,
                     MailboxClosed, SignatureMismatch)
from .facade import KernelActor
from .manager import Device, DeviceManager, Platform, Program
from .memref import DeviceRef, as_device_array, live_ref_count
from .scheduler import ChunkScheduler, split_offload
from .signature import In, InOut, KernelSignature, Local, NDRange, Out, Priv, dim_vec

__all__ = [
    "Actor", "ActorRef", "ActorSystem", "Message",
    "ComposedActor", "compose", "fuse",
    "ActorError", "ActorFailed", "DownMessage", "ExitMessage",
    "MailboxClosed", "SignatureMismatch",
    "KernelActor",
    "Device", "DeviceManager", "Platform", "Program",
    "DeviceRef", "as_device_array", "live_ref_count",
    "ChunkScheduler", "split_offload",
    "In", "InOut", "KernelSignature", "Local", "NDRange", "Out", "Priv", "dim_vec",
]
