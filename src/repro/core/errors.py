"""Failure/exit message types for actor supervision (paper §2.1).

The actor model addresses fault-tolerance by letting actors monitor each
other: when an actor dies, the runtime sends a ``DownMessage`` to every
monitor and an ``ExitMessage`` to every link (bidirectional monitor).
"""
from __future__ import annotations

import dataclasses
from typing import Any


class ActorError(Exception):
    """Base class for actor-runtime errors."""


class ActorFailed(ActorError):
    """Raised when requesting from an actor that terminated abnormally."""


class MailboxClosed(ActorError):
    """Message sent to an actor that already terminated."""


class SignatureMismatch(ActorError):
    """Message payload does not match the kernel signature (paper §3.4)."""


class AccessViolation(ActorError):
    """Operation not permitted by a DeviceRef's access rights (paper §3.5:
    "a reference type includes ... memory access rights")."""


class DeadlineExceeded(ActorError):
    """A deadline-carrying request or chunk missed its deadline before (or
    while) being served; the serve engine surfaces this per request."""


@dataclasses.dataclass(frozen=True)
class DownMessage:
    """Sent to monitors when a watched actor terminates (paper §2.1)."""

    actor_id: int
    reason: Any  # None for normal termination, the exception otherwise


@dataclasses.dataclass(frozen=True)
class ExitMessage:
    """Sent over links; by default kills the receiver unless it traps exits."""

    actor_id: int
    reason: Any
