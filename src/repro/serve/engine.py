"""Continuous-batching serve engine on the actor data plane.

The paper's evaluation argues sub-second duties live or die on offload
efficiency: keep multi-stage work device-resident while messages arrive
asynchronously. :class:`ServeEngine` applies that discipline to request
serving:

* per-request decode state is a pytree of :class:`DeviceRef`\\ s
  (``repro.core.memref.tree_wrap``) that stays device-resident between
  decode steps — the demo test asserts ``RefRegistry.transfer_count``
  stays flat across an entire 32-request run;
* each decode step is one actor message through an
  :class:`~repro.core.api.ActorPool` — placement-aware routing hands the
  batch to a worker whose device already holds the caches;
* the batch composition changes step to step: finished requests **leave**
  immediately (their future resolves) and queued requests **join** free
  slots without stalling the running batch (continuous batching);
* a failed step is re-queued through the
  :class:`~repro.core.scheduler.ChunkScheduler` re-issue machinery — the
  crashed worker is dead to the pool, the retry replays the *unmutated*
  cache refs on another replica (exactly-once results), and permanent
  failures surface as per-request errors, never a crashed engine.

Workers never donate or mutate incoming cache refs; the engine releases a
request's previous-step refs only after the step that superseded them
succeeded. That invariant is what makes mid-batch worker failure
recoverable by replay.

**Disaggregated paged mode** (``cache_pool=``): instead of a monolithic
``init_fn`` cache built inline in the decode loop, per-request state
lives in a :class:`~repro.serve.kvpool.PagePool` and serving splits into
phases. A prefill worker :class:`~repro.core.api.ActorPool` consumes
admitted prompts off the batcher, writes their KV pages (reusing shared
prompt prefixes copy-free), and hands each request's
:class:`~repro.serve.kvpool.PageTable` to the decode loop by plain ref
handoff — zero host transfers, and a crashed prefill worker is replayed
exactly-once through the same ChunkScheduler machinery the decode step
uses. The decode loop joins prefilled requests into free batch slots the
moment they are ready, so decode batches stay full while long prefills
run on the prefill pool instead of stalling the step loop.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.runtime import make_lock
from repro.core.actor import ActorSystem
from repro.core.api import ActorPool
from repro.core.errors import DeadlineExceeded
from repro.core.memref import DeviceRef, tree_release, tree_wrap
from repro.core.placement import service as placement_service
from repro.core.scheduler import ChunkScheduler

from .batcher import Batcher
from .kvpool import (PagePool, PageTable, make_paged_decode_worker,
                     make_prefill_worker)
from .request import Request, RequestQueue, ServeResult
from .stats import LatencyStats

__all__ = ["ServeEngine", "make_decode_worker", "make_graph_decode_worker",
           "EngineStopped"]


class EngineStopped(RuntimeError):
    """Set on requests abandoned by a non-draining shutdown."""


# ----------------------------------------------------------------------------
# decode worker — the actor behavior a pool replica runs
# ----------------------------------------------------------------------------
def make_decode_worker(step_fn: Callable, *, combine: Optional[Callable] = None,
                       split: Optional[Callable] = None,
                       jit: bool = True) -> Callable:
    """An actor behavior running one batched decode step.

    ``step_fn(cache, tokens[B]) → (next_tokens[B], new_cache)`` where
    ``cache`` is any pytree batched on the leading axis. The worker
    combines the per-request cache leaves (DeviceRefs) on device, runs the
    jitted step, and splits the updated cache back into per-request
    DeviceRefs.

    ``combine(leaves, i) → batched leaf`` / ``split(leaf, b, i) → request
    leaf`` override the default stack/index pair (``i`` is the flattened
    leaf index) — model caches whose leaves batch on different axes, or
    carry batch-uniform leaves like a scalar decode position, supply their
    own pair (see ``repro.launch.serve`` for an axis-detecting example).

    Input refs are **not** donated or mutated: a step that fails on this
    replica can be replayed verbatim on another (exactly-once results).
    """
    fn = jax.jit(step_fn) if jit else step_fn
    if combine is None:
        combine = lambda leaves, i: jnp.stack(leaves)
    if split is None:
        split = lambda leaf, b, i: leaf[b]

    def decode(tag: str, tokens: tuple, caches: tuple, treedef):
        if tag != "step":
            raise ValueError(f"decode worker got unknown message {tag!r}")
        nreq = len(caches)
        nleaves = len(caches[0])
        cols = [combine([caches[b][i].array for b in range(nreq)], i)
                for i in range(nleaves)]
        cache = jax.tree_util.tree_unflatten(treedef, cols)
        new_tokens, new_cache = fn(cache, jnp.asarray(tokens))
        leaves = jax.tree_util.tree_leaves(new_cache)
        if len(leaves) != nleaves:
            raise ValueError("step_fn changed the cache pytree structure")
        created = []
        try:
            out = []
            for b in range(nreq):
                row = []
                for i, leaf in enumerate(leaves):
                    ref = DeviceRef(split(leaf, b, i))
                    created.append(ref)
                    row.append(ref)
                out.append(tuple(row))
            return np.asarray(jax.device_get(new_tokens)), tuple(out)
        except BaseException:
            # a failing split/read-back must not leak the per-request
            # refs already carved out — the step will be retried
            for r in created:
                r.release()
            raise

    return decode


def make_graph_decode_worker(step_graph, *, combine: Optional[Callable] = None,
                             split: Optional[Callable] = None,
                             timeout: float = 120.0) -> Callable:
    """An actor behavior whose decode step is a **built dataflow graph**
    (:meth:`repro.core.graph.Graph.build`), instead of a jitted
    ``step_fn`` — multi-kernel decode steps (fan-out heads, gather/merge
    stages) plug straight into continuous batching.

    Graph contract: sources are ``(tokens[B], *cache_leaves)`` and outputs
    are ``(next_tokens[B], *new_cache_leaves)``, leaves batched on the
    leading axis (override with ``combine``/``split`` as in
    :func:`make_decode_worker`). Cache-leaf outputs declared with
    ``as_ref=True`` stay device-resident across steps; the batched inputs
    are handed to the graph as read-only :class:`DeviceRef`\\ s so interior
    edges dispatch zero-copy. Like the jitted worker, nothing is donated
    or mutated: a failed step replays verbatim on another replica.
    """
    if combine is None:
        combine = lambda leaves, i: jnp.stack(leaves)
    if split is None:
        split = lambda leaf, b, i: leaf[b]

    def decode(tag: str, tokens: tuple, caches: tuple, treedef):
        if tag != "step":
            raise ValueError(f"decode worker got unknown message {tag!r}")
        nreq = len(caches)
        nleaves = len(caches[0])
        cols = [DeviceRef(combine([caches[b][i].array for b in range(nreq)],
                                  i), access="r")
                for i in range(nleaves)]
        try:
            res = step_graph.ask(jnp.asarray(tokens), *cols, timeout=timeout)
            # a single-output graph resolves to its bare value (the
            # cache-less nleaves == 0 case); normalize before the check
            if not isinstance(res, tuple):
                res = (res,)
            created: List[DeviceRef] = []
            try:
                if len(res) != 1 + nleaves:
                    raise ValueError(
                        "graph step must return (next_tokens, "
                        f"*cache_leaves); got {len(res)} outputs for "
                        f"{nleaves} cache leaves")
                new_tokens, new_cols = res[0], res[1:]
                leaves = [c.array if isinstance(c, DeviceRef)
                          else jnp.asarray(c) for c in new_cols]
                out = []
                for b in range(nreq):
                    row = []
                    for i, leaf in enumerate(leaves):
                        ref = DeviceRef(split(leaf, b, i))
                        created.append(ref)
                        row.append(ref)
                    out.append(tuple(row))
                for c in new_cols:
                    if isinstance(c, DeviceRef):
                        c.release()
                if isinstance(new_tokens, DeviceRef):
                    toks = new_tokens.to_value()
                    new_tokens.release()
                else:
                    toks = np.asarray(jax.device_get(new_tokens))
                return toks, tuple(out)
            except BaseException:
                # the graph handed us ownership of its output refs; a
                # failed split/read-back must not leak them (or the
                # per-request refs already carved out) on every retry
                for r in created:
                    r.release()
                tree_release(res)
                raise
        finally:
            # released last: a graph may pass an input leaf through
            # unchanged, so its array must stay readable until the split
            # above has consumed it (release is idempotent for that case)
            for c in cols:
                c.release()

    return decode


class _Active:
    """A request resident in the running batch: its queue entry plus the
    flattened DeviceRef leaves of its device-resident cache."""

    __slots__ = ("req", "leaves", "treedef")

    def __init__(self, req: Request, leaves: List[DeviceRef], treedef):
        self.req = req
        self.leaves = leaves
        self.treedef = treedef

    prefix_hit = False

    def release(self) -> None:
        for ref in self.leaves:
            ref.release()
        self.leaves = []


class _ActivePaged:
    """A request resident in the running batch of a paged engine: its
    queue entry plus its page table (the pages live in the engine's
    :class:`~repro.serve.kvpool.PagePool`)."""

    __slots__ = ("req", "table", "prefix_hit")

    def __init__(self, req: Request, table: PageTable, prefix_hit: bool):
        self.req = req
        self.table = table
        self.prefix_hit = prefix_hit

    def release(self) -> None:
        self.table.release_pages()


# ----------------------------------------------------------------------------
# the engine
# ----------------------------------------------------------------------------
class ServeEngine:
    """Asynchronous continuous-batching request engine.

    **Monolithic mode** (default): ``init_fn(prompt) → (cache_pytree,
    first_token)`` builds one request's decode state inline in the decode
    loop; ``step_fn(cache, tokens[B]) → (next_tokens[B], new_cache)``
    advances a whole batch one token. The engine owns a worker pool (or
    adopts one via ``pool=``), an admission :class:`RequestQueue`, and a
    :class:`Batcher`; ``submit()`` is the client surface, ``stats()`` the
    observability surface.

    **Paged mode** (``cache_pool=`` a
    :class:`~repro.serve.kvpool.PagePool`): serving disaggregates into a
    prefill phase and a decode phase. ``prefill_fn(prompt) → (entries,
    first_token)`` (entry leaves ``[T, *per_token]``) runs on a dedicated
    prefill worker pool driven by ``prefill_workers`` threads, each
    dispatching through its own ChunkScheduler chunk so a crashed prefill
    worker replays exactly-once; ``step_fn(kv, lengths, tokens) →
    (next_tokens, entries)`` is the paged decode contract
    (:func:`~repro.serve.kvpool.make_paged_decode_worker`). Prefilled
    requests hand their page tables to the decode loop by in-process ref
    handoff (zero host transfers) and join the running batch immediately,
    so long prefills never stall the decode step; identical prompts map
    the same read-sealed pages through the pool's prefix cache.

    ``allow_join=False`` degrades to gang scheduling — a batch runs to
    completion before the next forms. Models whose cache carries
    batch-uniform leaves (e.g. a scalar decode position) need this, since
    a mid-batch joiner would be at a different position.
    """

    def __init__(self, system: ActorSystem, step_fn: Optional[Callable] = None,
                 init_fn: Optional[Callable] = None, *,
                 step_graph=None,
                 cache_pool: Optional[PagePool] = None,
                 prefill_fn: Optional[Callable] = None,
                 prefill_workers: int = 2,
                 share_prefixes: bool = True,
                 pool: Optional[ActorPool] = None, n_workers: int = 2,
                 max_batch: int = 8, max_wait_ms: float = 2.0,
                 allow_join: bool = True, max_attempts: int = 3,
                 step_timeout: float = 120.0,
                 queue: Optional[RequestQueue] = None, device=None,
                 combine: Optional[Callable] = None,
                 split: Optional[Callable] = None,
                 jit_step: bool = True):
        self._paged = cache_pool is not None
        if self._paged:
            if prefill_fn is None:
                raise ValueError(
                    "cache_pool mode needs prefill_fn (prompt → (entries, "
                    "first_token)); init_fn is the monolithic path")
            if init_fn is not None:
                raise ValueError(
                    "pass init_fn (monolithic) or cache_pool+prefill_fn "
                    "(paged), not both")
            if step_fn is None or step_graph is not None:
                raise ValueError(
                    "cache_pool mode needs a paged step_fn "
                    "(kv, lengths, tokens) → (next_tokens, entries)")
            if pool is not None:
                raise ValueError(
                    "cache_pool mode builds its own prefill/decode pools; "
                    "adopted pools are a monolithic-mode feature")
        else:
            if init_fn is None:
                raise ValueError(
                    "init_fn is required (per-request cache setup)")
            if step_fn is not None and step_graph is not None:
                raise ValueError("pass step_fn or step_graph, not both")
            if pool is not None and (step_fn is not None
                                     or step_graph is not None):
                raise ValueError(
                    "an adopted pool brings its own decode behavior; "
                    "step_fn/step_graph would be silently ignored — pass "
                    "one or the other")
        behavior = None
        self._prefill_behavior = None
        self._prefill_workers = 0
        self.prefill_pool: Optional[ActorPool] = None
        self._prefill_scheduler: Optional[ChunkScheduler] = None
        if pool is None:
            if device is None:
                # worker placement goes through the cost-model service:
                # least live bytes, then queue depth, deterministic
                # name tie-break (one device on CPU CI, but a multi-GPU
                # host steers new engines away from loaded devices)
                device = placement_service().pick_device(
                    system.opencl_manager().devices(),
                    context="serve-engine").chosen
            if self._paged:
                behavior = make_paged_decode_worker(step_fn, cache_pool)
                self._prefill_behavior = make_prefill_worker(
                    prefill_fn, cache_pool, share_prefixes=share_prefixes)
                self._prefill_workers = max(1, int(prefill_workers))
                prefill_refs = [system.spawn(self._prefill_behavior)
                                for _ in range(self._prefill_workers)]
                self.prefill_pool = ActorPool(
                    system, prefill_refs, policy="round_robin",
                    devices=[device] * len(prefill_refs))
                # straggler speculation stays off: a duplicated prefill
                # would burn compute and allocate a second page set (the
                # scheduler reclaims the loser via tree_release, but the
                # work is wasted); crash *replay* — the exactly-once path
                # this scheduler exists for — does not need it
                self._prefill_scheduler = ChunkScheduler(
                    self.prefill_pool, max_attempts=max_attempts,
                    straggler_factor=float("inf"))
            elif step_graph is not None:
                # the model step is a built dataflow graph (multi-kernel
                # DAG); replicas share the graph's node actors, so the
                # pool here buys step pipelining + crash replay, not
                # extra device parallelism. An *unbuilt* Graph is accepted
                # and built with the trace-time fusion pass — contiguous
                # kernel runs in the decode step collapse into single
                # jitted dispatches, and the worker's step_graph.ask()
                # rides the inline-dispatch fast path
                from repro.core.graph import Graph as _Graph
                if isinstance(step_graph, _Graph):
                    step_graph = step_graph.build(fuse=True)
                behavior = make_graph_decode_worker(
                    step_graph, combine=combine, split=split,
                    timeout=step_timeout)
            else:
                behavior = make_decode_worker(step_fn, combine=combine,
                                              split=split, jit=jit_step)
            workers = [system.spawn(behavior) for _ in range(n_workers)]
            pool = ActorPool(system, workers, policy="least_loaded",
                             devices=[device] * len(workers))
        elif device is None:
            device = next((d for d in pool.placements.values()
                           if d is not None), None)
        #: engine-owned pools self-heal: a crashed replica (any exception
        #: terminates its actor) is replaced before the next step so
        #: transient faults never permanently shrink capacity; adopted
        #: pools (pool=...) are the caller's to manage
        self._behavior = behavior
        self._n_workers = n_workers if behavior is not None else 0
        self.system = system
        self.pool = pool
        self.device = device
        self.init_fn = init_fn
        self.cache_pool = cache_pool
        self.queue = queue if queue is not None else RequestQueue()
        self.batcher = Batcher(self.queue, max_batch=max_batch,
                               max_wait_ms=max_wait_ms)
        self.max_batch = max_batch
        self.allow_join = allow_join
        self.step_timeout = step_timeout
        self._scheduler = ChunkScheduler(pool, max_attempts=max_attempts)
        self.latency = LatencyStats()
        self.ttft = LatencyStats()
        self._counters: Dict[str, int] = {
            "steps": 0, "tokens": 0, "joined": 0, "left": 0,
            "completed": 0, "failed": 0, "expired": 0, "requeues": 0,
            "respawned": 0, "peak_batch": 0, "batch_slots": 0,
            "prefills": 0, "prefix_hits": 0, "respawned_prefill": 0,
        }
        # prefill threads and the decode loop both bump shared counters
        self._ct_lock = make_lock("ServeEngine")
        self._max_step_gap = 0.0
        self._last_step_end: Optional[float] = None
        self._clock = time.monotonic
        self._stop = threading.Event()
        self._drain = True
        self._thread: Optional[threading.Thread] = None
        # paged handoff: prefill threads publish (req, table, first_token,
        # prefix_hit) here; the decode loop joins them into free slots
        self._ready: deque = deque()
        self._ready_cv = threading.Condition()
        self._prefill_inflight = 0
        self._prefill_threads: List[threading.Thread] = []

    def _bump(self, key: str, n: int = 1) -> None:
        with self._ct_lock:
            self._counters[key] += n

    # -- client surface ----------------------------------------------------
    def submit(self, prompt, *, max_new_tokens: int = 8, priority: int = 0,
               slo_ms: Optional[float] = None, block: bool = False,
               timeout: Optional[float] = None) -> Future:
        """Admit one request; returns a future resolving to a
        :class:`ServeResult` (or raising the per-request error). Raises an
        :class:`~repro.serve.request.AdmissionError` when shed."""
        deadline = None if slo_ms is None else self._clock() + slo_ms / 1e3
        req = Request(prompt, max_new_tokens=max_new_tokens,
                      priority=priority, deadline=deadline)
        self.queue.submit(req, block=block, timeout=timeout)
        return req.future

    def start(self) -> "ServeEngine":
        if self._thread is not None:
            raise RuntimeError("engine already started")
        if self._paged:
            self._prefill_threads = [
                threading.Thread(target=self._prefill_loop,
                                 name=f"serve-prefill-{i}", daemon=True)
                for i in range(self._prefill_workers)]
            for t in self._prefill_threads:
                t.start()
        self._thread = threading.Thread(target=self._loop,
                                        name="serve-engine", daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True, timeout: Optional[float] = 120.0
             ) -> None:
        """Close admissions and stop the engine thread. ``drain=True``
        (default) serves everything already queued first; ``drain=False``
        fails queued requests with :class:`EngineStopped` (the running
        batch still finishes — its results are already paid for)."""
        self.queue.close()
        self._drain = drain
        self._stop.set()
        with self._ready_cv:
            self._ready_cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
        for t in self._prefill_threads:
            t.join(timeout)

    def __enter__(self) -> "ServeEngine":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False

    def stats(self) -> Dict[str, Any]:
        with self._ct_lock:
            s: Dict[str, Any] = dict(self._counters)
        s["shed"] = self.queue.shed
        s["admitted"] = self.queue.admitted
        s["queue_depth"] = len(self.queue)
        s["latency"] = self.latency.summary()
        s["ttft"] = self.ttft.summary()
        s["dispatch"] = dict(self._scheduler.stats)
        s["max_step_gap_ms"] = self._max_step_gap * 1e3
        #: fraction of decode-batch slots filled, over every step taken —
        #: the disaggregation win is this staying high under mixed load
        s["occupancy"] = (s["batch_slots"] / (s["steps"] * self.max_batch)
                          if s["steps"] else 0.0)
        if self._paged:
            s["prefill_dispatch"] = dict(self._prefill_scheduler.stats)
            s["pool"] = self.cache_pool.stats()
        return s

    def load_snapshot(self) -> Dict[str, Any]:
        """A small, cheap load summary for a mesh router's scheduling
        tick: queue depth, the queue's EWMA-derived wait estimate, batch
        occupancy, and the lifetime completed/failed counts. Unlike
        :meth:`stats` this touches no latency reservoirs and builds no
        nested dicts — it is polled per tick per replica."""
        with self._ct_lock:
            joined = self._counters["joined"]
            left = self._counters["left"]
            steps = self._counters["steps"]
            slots = self._counters["batch_slots"]
            completed = self._counters["completed"]
            failed = self._counters["failed"]
        return {
            "queue_depth": len(self.queue),
            "queue_wait_s": self.queue.estimated_wait(),
            "active": joined - left,
            "occupancy": (slots / (steps * self.max_batch)
                          if steps else 0.0),
            "max_batch": self.max_batch,
            "steps": steps,
            "completed": completed,
            "failed": failed,
        }

    def drain_async(self) -> Future:
        """Close admissions and drain in the background; the returned
        future resolves (to the final :meth:`stats`) once everything
        already queued has been served and the engine thread has exited.
        This is the mesh scale-in entrypoint: the router stops routing to
        the replica, calls this, and releases the node only after the
        future resolves — so scale-in never sheds admitted work."""
        fut: Future = Future()

        def _drain() -> None:
            try:
                self.stop(drain=True)
                fut.set_result(self.stats())
            except BaseException as exc:  # pragma: no cover - defensive
                if not fut.done():
                    fut.set_exception(exc)

        threading.Thread(target=_drain, name="serve-drain",
                         daemon=True).start()
        return fut

    # -- engine loop -------------------------------------------------------
    def _loop(self) -> None:
        active: list = []
        try:
            if self._paged:
                self._serve_paged(active)
            else:
                self._serve(active)
        except BaseException as exc:  # defensive: never die silently
            for a in list(active):
                self._leave(a, active, error=exc)
            raise

    def _serve(self, active: List[_Active]) -> None:
        while True:
            if self._stop.is_set() and not self._drain:
                self._abandon_queue()
            free = self.max_batch - len(active)
            if free > 0 and (self.allow_join or not active):
                bucket = active[0].req.bucket if active else None
                if active:
                    # join path: grab whatever is ready, never stall the
                    # running batch waiting for company
                    newcomers = self.batcher.take(free, bucket=bucket,
                                                  wait_s=0.0, max_wait_s=0.0)
                else:
                    newcomers = self.batcher.take(free, wait_s=0.02)
                for req in newcomers:
                    self._admit(req, active)
            if not active:
                if self._stop.is_set() and len(self.queue) == 0:
                    return
                continue  # take() above already waited for work
            self._expire(active)
            if active:
                self._step(active)

    def _abandon_queue(self) -> None:
        while True:
            req = self.queue.pop(timeout=0)
            if req is None:
                return
            if not req.future.done():
                req.future.set_exception(
                    EngineStopped("engine stopped before serving request"))

    # -- batch membership --------------------------------------------------
    def _admit(self, req: Request, active: List[_Active]) -> None:
        now = self._clock()
        if req.deadline is not None and req.deadline <= now:
            self._bump("expired")
            if not req.future.done():
                req.future.set_exception(DeadlineExceeded(
                    f"request {req.id} expired while queued"))
            return
        created: List[DeviceRef] = []
        try:
            cache, first_token = self.init_fn(req.prompt)
            refs = tree_wrap(cache, device=self.device, created=created)
        except Exception as exc:
            # a bad prompt fails its own request, never the engine — and
            # a wrap that died mid-tree (one bad leaf after several good
            # ones) must not leak the refs already created (shed-path
            # leak regression)
            for ref in created:
                ref.release()
            self._bump("failed")
            if not req.future.done():
                req.future.set_exception(exc)
            return
        leaves, treedef = jax.tree_util.tree_flatten(refs)
        # init_fn may be a long prefill: re-check the deadline *after* it
        # ran and release the just-built cache on the shed path instead
        # of parking it in the batch for a doomed decode step
        now = self._clock()
        if req.deadline is not None and req.deadline <= now:
            for ref in leaves:
                ref.release()
            self._bump("expired")
            if not req.future.done():
                req.future.set_exception(DeadlineExceeded(
                    f"request {req.id} expired during cache init"))
            return
        if active:
            # the prompt-shape bucket is only a proxy for cache
            # compatibility; verify the real invariant so one malformed
            # joiner sheds itself instead of crashing the whole batch in
            # the worker's tree_unflatten/stack
            seed = active[0]
            if treedef != seed.treedef or \
                    [(l.shape, l.dtype) for l in leaves] != \
                    [(l.shape, l.dtype) for l in seed.leaves]:
                for ref in leaves:
                    ref.release()
                self._bump("failed")
                if not req.future.done():
                    req.future.set_exception(ValueError(
                        f"request {req.id}: cache structure does not match "
                        "the running batch (init_fn inconsistent with the "
                        "shape bucket)"))
                return
        req.last_token = first_token
        active.append(_Active(req, leaves, treedef))
        self._bump("joined")
        with self._ct_lock:
            self._counters["peak_batch"] = max(self._counters["peak_batch"],
                                               len(active))

    def _leave(self, a, active: list,
               error: Optional[BaseException] = None) -> None:
        a.release()
        active.remove(a)
        self._bump("left")
        req = a.req
        if error is not None:
            self._bump("failed")
            if not req.future.done():
                req.future.set_exception(error)
            return
        now = self._clock()
        lat = now - req.t_submit
        self.latency.record(lat)
        self._bump("completed")
        ttft = (req.t_first - req.t_submit
                if req.t_first is not None else lat)
        if not req.future.done():
            req.future.set_result(ServeResult(
                request_id=req.id, tokens=list(req.tokens), latency_s=lat,
                ttft_s=ttft, steps=len(req.tokens),
                prefix_hit=getattr(a, "prefix_hit", False)))

    def _expire(self, active: list) -> None:
        now = self._clock()
        for a in list(active):
            if a.req.deadline is not None and now > a.req.deadline:
                self._bump("expired")
                self._leave(a, active, error=DeadlineExceeded(
                    f"request {a.req.id} missed its deadline mid-decode "
                    f"after {len(a.req.tokens)} tokens"))

    def _heal_pool(self) -> None:
        """Replace crashed replicas in an engine-owned pool (no-op for
        adopted pools). New workers join both the pool and the scheduler's
        worker set, so the very next step can route to them.

        Adopted pools may contain :class:`repro.net.RemoteActorRef`
        replicas (decode steps then cross the wire as spill/unspill pairs;
        the request-side spill *copies*, so a node death mid-step replays
        the same cache refs on a surviving replica — the engine's
        exactly-once invariant holds across nodes). Healing such pools is
        the caller's job: this engine cannot respawn an actor into a
        process it does not own."""
        if self._behavior is None:
            return
        missing = self._n_workers - len(self.pool.live_workers())
        for _ in range(missing):
            ref = self.system.spawn(self._behavior)
            self.pool.add_worker(ref, self.device)
            self._scheduler.add_worker(ref)
            self._bump("respawned")

    def _heal_prefill(self) -> None:
        """Same self-healing for the engine-owned prefill pool: a prefill
        worker killed by a crash (or a poison prompt) is replaced before
        the next prefill dispatch."""
        if self._prefill_behavior is None:
            return
        missing = self._prefill_workers - len(self.prefill_pool.live_workers())
        for _ in range(missing):
            ref = self.system.spawn(self._prefill_behavior)
            self.prefill_pool.add_worker(ref, self.device)
            self._prefill_scheduler.add_worker(ref)
            self._bump("respawned_prefill")

    def _note_step_gap(self) -> None:
        now = self._clock()
        if self._last_step_end is not None:
            self._max_step_gap = max(self._max_step_gap,
                                     now - self._last_step_end)

    # -- one decode step ---------------------------------------------------
    def _step(self, active: List[_Active]) -> None:
        self._heal_pool()
        self._note_step_gap()
        payload = ("step",
                   tuple(a.req.last_token for a in active),
                   tuple(tuple(a.leaves) for a in active),
                   active[0].treedef)
        failed_before = self._scheduler.stats["failed"]
        t0 = self._clock()
        try:
            # one chunk through the ChunkScheduler: its re-issue machinery
            # retries a failed step on another live worker (the crashed
            # one is dead to the pool) up to max_attempts
            result = self._scheduler.run([payload],
                                         timeout=self.step_timeout)[0]
        except Exception as exc:
            # permanent failure: every member surfaces it per-request;
            # the engine itself keeps serving
            self._bump("requeues",
                       self._scheduler.stats["failed"] - failed_before)
            for a in list(active):
                self._leave(a, active, error=exc)
            self._last_step_end = self._clock()
            return
        self._bump("requeues",
                   self._scheduler.stats["failed"] - failed_before)
        self.queue.note_service_time(self._clock() - t0)
        self._bump("steps")
        self._bump("batch_slots", len(active))
        tokens, new_caches = result
        now = self._clock()
        self._last_step_end = now
        for a, tok, new_leaves in zip(list(active), tokens, new_caches):
            for old in a.leaves:
                old.release()
            a.leaves = list(new_leaves)
            token = tok.item() if hasattr(tok, "item") else tok
            a.req.tokens.append(token)
            a.req.last_token = token
            self._bump("tokens")
            if a.req.t_first is None:
                a.req.t_first = now
                self.ttft.record(now - a.req.t_submit)
            if len(a.req.tokens) >= a.req.max_new_tokens:
                self._leave(a, active)

    # ------------------------------------------------------------------
    # paged mode: prefill threads + the paged decode loop
    # ------------------------------------------------------------------
    def _prefill_loop(self) -> None:
        """One prefill thread: pull a prompt off the batcher, prefill it
        through the ChunkScheduler (exactly-once replay of a crashed
        prefill worker), and publish the page table to the decode loop.
        ``prefill_workers`` of these run concurrently, so several long
        prefills overlap each other *and* the decode steps."""
        while True:
            if self._stop.is_set() and not self._drain:
                return
            with self._ready_cv:
                self._prefill_inflight += 1
            try:
                req = self.batcher.take_one(wait_s=0.05)
                if req is None:
                    if self.queue.closed and len(self.queue) == 0:
                        return
                    continue
                self._do_prefill(req)
            finally:
                with self._ready_cv:
                    self._prefill_inflight -= 1
                    self._ready_cv.notify_all()

    def _do_prefill(self, req: Request) -> None:
        now = self._clock()
        if req.deadline is not None and req.deadline <= now:
            self._bump("expired")
            if not req.future.done():
                req.future.set_exception(DeadlineExceeded(
                    f"request {req.id} expired while queued for prefill"))
            return
        self._heal_prefill()
        try:
            table, first, hit = self._prefill_scheduler.run(
                [("prefill", req.prompt)], timeout=self.step_timeout)[0]
        except Exception as exc:
            self._bump("failed")
            if not req.future.done():
                req.future.set_exception(exc)
            return
        self._bump("prefills")
        if hit:
            self._bump("prefix_hits")
        req.t_ready = self._clock()
        # shed-path page return: a request whose deadline passed *during*
        # prefill hands its pages straight back to the pool instead of
        # leaking them into a batch it can never finish in
        if req.deadline is not None and req.deadline <= req.t_ready:
            table.release_pages()
            self._bump("expired")
            if not req.future.done():
                req.future.set_exception(DeadlineExceeded(
                    f"request {req.id} expired during prefill"))
            return
        with self._ready_cv:
            self._ready.append((req, table, first, hit))
            self._ready_cv.notify_all()

    def _take_ready(self, n: int, wait: bool) -> list:
        with self._ready_cv:
            if wait and not self._ready and not self._stop.is_set():
                self._ready_cv.wait(timeout=0.02)
            out = []
            while self._ready and len(out) < n:
                out.append(self._ready.popleft())
            return out

    def _abandon_ready(self) -> None:
        with self._ready_cv:
            entries = list(self._ready)
            self._ready.clear()
        for req, table, _first, _hit in entries:
            table.release_pages()
            if not req.future.done():
                req.future.set_exception(
                    EngineStopped("engine stopped before serving request"))

    def _paged_idle(self) -> bool:
        with self._ready_cv:
            return (len(self.queue) == 0 and self._prefill_inflight == 0
                    and not self._ready)

    def _serve_paged(self, active: List[_ActivePaged]) -> None:
        while True:
            if self._stop.is_set() and not self._drain:
                self._abandon_queue()
                self._abandon_ready()
            free = self.max_batch - len(active)
            if free > 0:
                for req, table, first, hit in self._take_ready(
                        free, wait=not active):
                    self._admit_paged(req, table, first, hit, active)
            if not active:
                if self._stop.is_set() and self._paged_idle():
                    return
                if self._stop.is_set() and not self._drain:
                    return
                continue  # _take_ready waited for work above
            self._expire(active)
            if active:
                self._step_paged(active)

    def _admit_paged(self, req: Request, table: PageTable, first,
                     hit: bool, active: List[_ActivePaged]) -> None:
        now = self._clock()
        if req.deadline is not None and req.deadline <= now:
            table.release_pages()
            self._bump("expired")
            if not req.future.done():
                req.future.set_exception(DeadlineExceeded(
                    f"request {req.id} expired between prefill and join"))
            return
        req.last_token = first
        active.append(_ActivePaged(req, table, hit))
        self._bump("joined")
        with self._ct_lock:
            self._counters["peak_batch"] = max(self._counters["peak_batch"],
                                               len(active))

    def _step_paged(self, active: List[_ActivePaged]) -> None:
        self._heal_pool()
        self._note_step_gap()
        # reserve every request's append slot *before* dispatch: page
        # allocation at a boundary, copy-on-write when the tail is a
        # shared prefix page — so the worker only ever writes private
        # tails, and a replayed step re-reads unmodified pages
        for a in list(active):
            try:
                a.table.prepare_append()
            except Exception as exc:   # PoolExhausted: shed this request
                self._leave(a, active, error=exc)
        if not active:
            return
        payload = ("pstep",
                   tuple(a.req.last_token for a in active),
                   tuple((tuple(a.table.pages), a.table.length)
                         for a in active))
        failed_before = self._scheduler.stats["failed"]
        t0 = self._clock()
        try:
            result = self._scheduler.run([payload],
                                         timeout=self.step_timeout)[0]
        except Exception as exc:
            self._bump("requeues",
                       self._scheduler.stats["failed"] - failed_before)
            for a in list(active):
                self._leave(a, active, error=exc)
            self._last_step_end = self._clock()
            return
        self._bump("requeues",
                   self._scheduler.stats["failed"] - failed_before)
        self.queue.note_service_time(self._clock() - t0)
        self._bump("steps")
        self._bump("batch_slots", len(active))
        tokens, new_tails = result
        now = self._clock()
        self._last_step_end = now
        for a, tok, tail_arrays in zip(list(active), tokens, new_tails):
            a.table.commit_append(tail_arrays)
            token = tok.item() if hasattr(tok, "item") else tok
            a.req.tokens.append(token)
            a.req.last_token = token
            self._bump("tokens")
            if a.req.t_first is None:
                a.req.t_first = now
                self.ttft.record(now - a.req.t_submit)
            if len(a.req.tokens) >= a.req.max_new_tokens:
                self._leave(a, active)
