"""Elastic multi-node serve mesh (ROADMAP item 3).

The paper's composability claim — "transparent message passing in
distributed systems on heterogeneous hardware" — means the pieces built
so far should stack into a cluster-scale service without new primitives.
This module does exactly that: a :class:`MeshRouter` on the driver node
shards requests across :class:`EngineReplica` actors (each wrapping one
:class:`~repro.serve.engine.ServeEngine`) that may live in other
processes behind :class:`repro.net.RemoteActorRef` handles. Because a
remote replica is just an :class:`~repro.core.actor.ActorRef`, the
router's dispatch, supervision, and replay paths are identical for local
and remote replicas — the network transparency is inherited, not
re-implemented.

Three behaviors compose on top of existing machinery:

* **replica-aware routing** — requests carrying a ``session`` key (or a
  shared prompt prefix, when ``route_by_prefix`` is on) pick their
  replica by rendezvous (HRW) hashing, so a paged engine's prefix cache
  stays warm; keyless requests go to the replica with the least
  EWMA queue-wait (fed by each replica's
  :meth:`~repro.serve.engine.ServeEngine.load_snapshot`).
* **autoscaling** — when even the *least* loaded replica's EWMA
  queue-wait exceeds the SLO budget there is nowhere good to route, so
  the router spawns a new replica (``NodeRuntime.spawn_remote`` on the
  least-populated worker); when the *most* loaded replica undershoots,
  one replica is drained (``ServeEngine.drain_async``) and released only
  after everything it admitted has been served — scale-in never sheds
  work.
* **failure transparency** — every replica is monitored
  (``system.monitor``, which for remote refs rides the cross-node relay
  from PR 5). A worker SIGKILL becomes NodeDown → DownMessage; the
  router sweeps that replica's in-flight requests and replays each on a
  surviving replica. Exactly-once holds by construction: a request's
  in-flight entry is popped under the router lock by whichever of the
  two death signals (failed reply future vs. DownMessage sweep) arrives
  first, and client futures resolve first-wins
  (:func:`~repro.core.actor._safe_set_result`) — never lost, never
  double-completed. Engine workers never mutate their inputs (the PR 3
  ChunkScheduler invariant), so a replayed request recomputes from the
  prompt with no torn state.

Requests *shed* by a replica's admission control (queue overflow, SLO
budget) are **not** replayed — shedding is the overload policy answering
correctly, not a failure. The one admission error the router does retry
is :class:`~repro.serve.request.QueueClosed`: it means the pick raced a
drain, which is a replica lifecycle artifact, not the client's problem.
"""
from __future__ import annotations

import hashlib
import threading
import time
from concurrent.futures import Future
from functools import partial
from typing import Any, Callable, Dict, List, Optional

from repro.core.actor import (Actor, ActorRef, ActorSystem,
                              _safe_set_exception, _safe_set_result)
from repro.analysis.runtime import make_lock
from repro.core.errors import ActorError, ActorFailed, DownMessage
from repro.core.placement import service as placement_service

from .engine import EngineStopped, ServeEngine
from .request import AdmissionError, QueueClosed
from .stats import EWMA

__all__ = ["MeshRouter", "EngineReplica", "ReplicaSpec", "MeshDown",
           "local_replica_stats"]


class MeshDown(ActorError):
    """No live replica remains to route (or replay) a request to."""


# ----------------------------------------------------------------------------
# replica side
# ----------------------------------------------------------------------------
class ReplicaSpec:
    """Picklable recipe for building one engine replica.

    ``factory(system, **kwargs) → ServeEngine`` must be a module-level
    callable (pickled by reference — the worker process imports it, the
    same contract ``spawn_remote`` behaviors already follow) and
    ``kwargs`` must be picklable. The spec crosses the wire inside the
    ``spawn_remote`` payload; the engine itself is built *on the worker*,
    so device handles and actor pools never travel.
    """

    def __init__(self, factory: Callable[..., ServeEngine], **kwargs: Any):
        self.factory = factory
        self.kwargs = kwargs

    def build(self, system: ActorSystem) -> ServeEngine:
        return self.factory(system, **self.kwargs)

    def __repr__(self):
        return f"ReplicaSpec({getattr(self.factory, '__name__', '?')})"


#: engines hosted by this process's EngineReplica actors, keyed by the
#: replica actor id — read by ``local_replica_stats`` so a worker node
#: can expose per-replica load through ``peer_stats`` (see
#: ``NodeRuntime.add_stats_provider``)
_local_replicas: Dict[int, ServeEngine] = {}
_local_lock = make_lock("MeshLocalReplicas")


def local_replica_stats() -> Dict[str, Any]:
    """Load snapshots of every engine replica hosted in this process —
    a node stats provider (cheap by design: ``load_snapshot`` touches no
    latency reservoirs)."""
    with _local_lock:
        engines = dict(_local_replicas)
    return {str(aid): eng.load_snapshot() for aid, eng in engines.items()}


class EngineReplica(Actor):
    """One serve-engine replica behind an actor mailbox.

    Spawned locally (``system.spawn(EngineReplica(spec))``) or on a
    worker (``node.spawn_remote(peer, EngineReplica, spec)``); either way
    the router talks to the same four messages:

    ``("serve", prompt, max_new_tokens, priority, slo_ms)``
        admits the request and **delegates the reply** to the engine's
        per-request future — the actor answers when the request finishes,
        not when it is queued. A shed (:class:`AdmissionError`) comes
        back as a failed future rather than an exception raised from
        ``receive``: raising would terminate the replica actor, turning
        every load shed into a fake replica death.
    ``("stats",)`` → :meth:`ServeEngine.load_snapshot` (cheap, per-tick).
    ``("drain",)`` → delegates to :meth:`ServeEngine.drain_async`; the
        reply arrives once everything admitted has been served.
    ``("ping",)`` → ``"pong"`` (liveness probe).
    """

    def __init__(self, spec: ReplicaSpec):
        super().__init__()
        self.spec = spec
        self.engine: Optional[ServeEngine] = None

    def on_start(self) -> None:
        self.engine = self.spec.build(self.system).start()
        with _local_lock:
            _local_replicas[self.ref.actor_id] = self.engine

    def on_exit(self, reason: Any) -> None:
        with _local_lock:
            _local_replicas.pop(self.ref.actor_id, None)
        if self.engine is not None:
            # non-draining: a replica killed by its supervisor must not
            # block shutdown serving a backlog nobody is routing to —
            # queued requests fail with EngineStopped and the router (if
            # any survives) replays them elsewhere
            self.engine.stop(drain=False, timeout=5.0)

    def receive(self, tag: str, *rest: Any) -> Any:
        if tag == "serve":
            prompt, max_new_tokens, priority, slo_ms = rest
            try:
                return self.engine.submit(
                    prompt, max_new_tokens=max_new_tokens,
                    priority=priority, slo_ms=slo_ms)
            except AdmissionError as exc:
                fut: Future = Future()
                fut.set_exception(exc)
                return fut
        if tag == "stats":
            return self.engine.load_snapshot()
        if tag == "drain":
            return self.engine.drain_async()
        if tag == "ping":
            return "pong"
        raise ValueError(f"EngineReplica got unknown message {tag!r}")


# ----------------------------------------------------------------------------
# router side
# ----------------------------------------------------------------------------
class _MeshRequest:
    __slots__ = ("id", "prompt", "max_new_tokens", "priority", "slo_ms",
                 "key", "future", "attempts", "t_submit")

    def __init__(self, rid: int, prompt: Any, max_new_tokens: int,
                 priority: int, slo_ms: Optional[float], key: Optional[str]):
        self.id = rid
        self.prompt = prompt
        self.max_new_tokens = max_new_tokens
        self.priority = priority
        self.slo_ms = slo_ms
        self.key = key
        self.future: Future = Future()
        self.attempts = 0
        self.t_submit = time.monotonic()


class _Replica:
    __slots__ = ("key", "ref", "peer", "state", "inflight", "wait_ewma",
                 "load", "watcher")

    def __init__(self, ref: ActorRef, peer: Optional[str]):
        self.key = str(ref.actor_id)
        self.ref = ref
        self.peer = peer                       # None for local replicas
        self.state = "live"                    # live → draining → released
        self.inflight: Dict[int, _MeshRequest] = {}
        self.wait_ewma = EWMA(alpha=0.3)
        self.load: Dict[str, Any] = {}
        self.watcher: Optional[ActorRef] = None

    def wait_estimate(self) -> float:
        v = self.wait_ewma.value
        return 0.0 if v is None else v


class MeshRouter:
    """Front-end sharding requests across engine replicas (module doc).

    Parameters
    ----------
    system : the driver-side actor system (watchers and the optional
        front-end actor are spawned here).
    node : the driver's :class:`repro.net.NodeRuntime`, or None for a
        purely in-process mesh (autoscale then spawns local replicas).
    spec : the :class:`ReplicaSpec` autoscale uses to spawn replicas;
        optional when the replica set is managed by hand.
    slo_budget_s : the queue-wait the mesh is sized to keep; the
        autoscaler's reference point.
    scale_out_ratio / scale_in_ratio : scale out when the **least**
        loaded replica's EWMA wait exceeds ``slo_budget_s ×
        scale_out_ratio`` (nowhere good to route); scale in when the
        **most** loaded one undershoots ``slo_budget_s ×
        scale_in_ratio``.
    spawn_targets : peers eligible for scale-out (default: the node's
        live peers at decision time; ``[None]`` spawns locally).
    route_by_prefix / prefix_tokens : key session-less requests by their
        prompt prefix so paged prefix caches stay warm.
    """

    def __init__(self, system: ActorSystem, node=None, *,
                 spec: Optional[ReplicaSpec] = None,
                 slo_budget_s: float = 1.0,
                 scale_out_ratio: float = 1.0,
                 scale_in_ratio: float = 0.25,
                 min_replicas: int = 1, max_replicas: int = 4,
                 cooldown_s: float = 5.0,
                 control_interval: float = 0.2,
                 max_attempts: int = 3,
                 route_by_prefix: bool = False, prefix_tokens: int = 8,
                 spawn_targets: Optional[List[Optional[str]]] = None):
        self.system = system
        self.node = node
        self.spec = spec
        self.slo_budget_s = slo_budget_s
        self.scale_out_ratio = scale_out_ratio
        self.scale_in_ratio = scale_in_ratio
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.cooldown_s = cooldown_s
        self.control_interval = control_interval
        self.max_attempts = max_attempts
        self.route_by_prefix = route_by_prefix
        self.prefix_tokens = prefix_tokens
        self.spawn_targets = spawn_targets
        self._lock = make_lock("MeshRouter")
        self._replicas: Dict[str, _Replica] = {}
        self._req_ids = 0
        self._counters: Dict[str, int] = {
            "submitted": 0, "routed": 0, "completed": 0, "failed": 0,
            "shed": 0, "replayed": 0, "replicas_lost": 0,
            "scale_outs": 0, "scale_ins": 0, "prefix_routed": 0,
        }
        self._clock = time.monotonic
        self._last_scale = self._clock()
        self._last_scale_error: Optional[str] = None
        self._stop_evt = threading.Event()
        self._control: Optional[threading.Thread] = None
        self._front: Optional[ActorRef] = None

    # -- replica membership ------------------------------------------------
    def add_replica(self, ref: ActorRef,
                    peer: Optional[str] = None) -> _Replica:
        """Adopt ``ref`` (an :class:`EngineReplica`, local or remote) into
        the routing set and monitor it for death."""
        rep = _Replica(ref, peer)
        router = self

        def on_down(msg):
            if isinstance(msg, DownMessage):
                router._mark_dead(rep, msg.reason)

        rep.watcher = self.system.spawn(on_down)
        with self._lock:
            self._replicas[rep.key] = rep
        self.system.monitor(rep.watcher, ref)
        return rep

    def spawn_replica(self, peer: Optional[str] = None) -> _Replica:
        """Spawn a fresh replica from :attr:`spec` — on ``peer`` via
        ``spawn_remote``, or in-process when ``peer`` is None."""
        if self.spec is None:
            raise ValueError("MeshRouter needs spec= to spawn replicas")
        if peer is not None:
            if self.node is None:
                raise ValueError("remote spawn needs node=")
            ref = self.node.spawn_remote(peer, EngineReplica, self.spec)
        else:
            ref = self.system.spawn(EngineReplica(self.spec))
        return self.add_replica(ref, peer)

    # -- client surface ----------------------------------------------------
    def submit(self, prompt: Any, *, max_new_tokens: int = 8,
               priority: int = 0, slo_ms: Optional[float] = None,
               session: Optional[str] = None) -> Future:
        """Route one request; the returned future resolves to the serving
        replica's :class:`~repro.serve.request.ServeResult` (replays on
        replica death are invisible to the caller) or raises the
        per-request error (:class:`AdmissionError` when shed,
        :class:`MeshDown` when no replica survives)."""
        key = session if session is not None else self._prefix_key(prompt)
        with self._lock:
            self._req_ids += 1
            req = _MeshRequest(self._req_ids, prompt, max_new_tokens,
                               priority, slo_ms, key)
            self._counters["submitted"] += 1
        self._dispatch(req)
        return req.future

    def _prefix_key(self, prompt: Any) -> Optional[str]:
        if not self.route_by_prefix:
            return None
        try:
            if isinstance(prompt, (str, bytes)):
                return repr(prompt[:self.prefix_tokens])
            return repr(list(prompt[:self.prefix_tokens]))
        except Exception:
            return None

    # -- dispatch / replay -------------------------------------------------
    def _pick_locked(self, key: Optional[str],
                     exclude: Optional[_Replica] = None) -> Optional[_Replica]:
        live = [r for r in self._replicas.values()
                if r.state == "live" and r is not exclude]
        if not live:
            # replaying after the last healthy replica died: a draining
            # one that is still up beats losing the request
            live = [r for r in self._replicas.values()
                    if r.state == "draining" and r is not exclude]
        if not live:
            return None
        if key is not None:
            # rendezvous (HRW) hashing: each (key, replica) pair scores
            # independently, so replica churn only remaps the keys that
            # hashed to the lost replica — warm prefix caches elsewhere
            # stay warm
            self._counters["prefix_routed"] += 1
            return max(live, key=lambda r: hashlib.md5(
                f"{key}|{r.key}".encode()).digest())
        # keyless requests: least expected wait, ranked by the placement
        # service from (EWMA queue-wait, this router's own inflight
        # fan-in) snapshots — the same auditable cost source every other
        # dispatcher queries. EWMA alone is stale between polls; inflight
        # is always current, so it degrades a replica's score as requests
        # are routed to it
        decision = placement_service().rank_replicas(
            [(r.key, r.wait_estimate(), len(r.inflight)) for r in live],
            context="mesh")
        return next(r for r in live if r.key == decision.chosen)

    def _dispatch(self, req: _MeshRequest) -> None:
        with self._lock:
            rep = self._pick_locked(req.key)
            if rep is None:
                self._counters["failed"] += 1
                exhausted = True
            else:
                rep.inflight[req.id] = req
                self._counters["routed"] += 1
                exhausted = False
        if exhausted:
            _safe_set_exception(req.future, MeshDown(
                f"no live replica to serve request {req.id}"))
            return
        fut = rep.ref.request("serve", req.prompt, req.max_new_tokens,
                              req.priority, req.slo_ms)
        fut.add_done_callback(partial(self._on_serve_done, req, rep))

    def _on_serve_done(self, req: _MeshRequest, rep: _Replica,
                       fut: Future) -> None:
        with self._lock:
            owner = rep.inflight.pop(req.id, None)
        if owner is None:
            # the DownMessage sweep got here first and already replayed
            # (or this request was resolved by a replay) — exactly-once
            # means exactly one path owns the outcome
            return
        exc = fut.exception() if not fut.cancelled() else \
            ActorFailed("request cancelled")
        if exc is None:
            with self._lock:
                self._counters["completed"] += 1
            _safe_set_result(req.future, fut.result())
            return
        if isinstance(exc, QueueClosed) or \
                isinstance(exc, (ActorFailed, EngineStopped)):
            # replica death (NodeDown is an ActorFailed) or a drain race:
            # the request did not run to completion — replay it
            self._replay(req, rep, exc)
            return
        with self._lock:
            self._counters["shed" if isinstance(exc, AdmissionError)
                           else "failed"] += 1
        _safe_set_exception(req.future, exc)

    def _replay(self, req: _MeshRequest, failed: _Replica,
                reason: BaseException) -> None:
        req.attempts += 1
        if req.attempts >= self.max_attempts:
            with self._lock:
                self._counters["failed"] += 1
            _safe_set_exception(req.future, MeshDown(
                f"request {req.id} failed on {req.attempts} replicas; "
                f"last: {reason!r}"))
            return
        with self._lock:
            rep = self._pick_locked(req.key, exclude=failed)
            if rep is None:
                self._counters["failed"] += 1
            else:
                rep.inflight[req.id] = req
                self._counters["replayed"] += 1
        if rep is None:
            _safe_set_exception(req.future, MeshDown(
                f"request {req.id}: no surviving replica to replay on "
                f"(last failure: {reason!r})"))
            return
        fut = rep.ref.request("serve", req.prompt, req.max_new_tokens,
                              req.priority, req.slo_ms)
        fut.add_done_callback(partial(self._on_serve_done, req, rep))

    def _mark_dead(self, rep: _Replica, reason: Any) -> None:
        """A monitored replica terminated. Sweep its in-flight requests
        into replays — unless it was *released* (scale-in drained it and
        asked it to exit; its inflight is empty and its death is policy,
        not failure)."""
        with self._lock:
            if rep.state == "released":
                return
            was = rep.state
            rep.state = "dead"
            swept = list(rep.inflight.values())
            rep.inflight.clear()
            if was in ("live", "draining"):
                self._counters["replicas_lost"] += 1
        err = reason if isinstance(reason, BaseException) else \
            ActorFailed(f"replica {rep.key} terminated: {reason!r}")
        for req in swept:
            self._replay(req, rep, err)

    # -- control loop: load polling + autoscale ----------------------------
    def start(self) -> "MeshRouter":
        if self._control is not None:
            raise RuntimeError("router already started")
        self._control = threading.Thread(target=self._control_loop,
                                         name="mesh-control", daemon=True)
        self._control.start()
        return self

    def _control_loop(self) -> None:
        # Event.wait, not time.sleep: shutdown() must not linger a full
        # control interval (the node heartbeat had this exact bug)
        while not self._stop_evt.wait(self.control_interval):
            self._poll_replicas()
            try:
                self._autoscale()
            except Exception as exc:
                # a failed scale action retries next tick, but the fault
                # stays visible in stats() instead of vanishing
                with self._lock:
                    self._last_scale_error = repr(exc)

    def _poll_replicas(self) -> None:
        with self._lock:
            reps = [r for r in self._replicas.values() if r.state == "live"]
        for rep in reps:
            try:
                fut = rep.ref.request("stats")
            except Exception:  # lint: dead conn; the monitor path sweeps it
                continue
            fut.add_done_callback(partial(self._on_stats, rep))

    def _on_stats(self, rep: _Replica, fut: Future) -> None:
        if fut.cancelled() or fut.exception() is not None:
            return
        snap = fut.result()
        with self._lock:
            rep.load = snap
            rep.wait_ewma.update(float(snap.get("queue_wait_s", 0.0)))
            # feed the snapshot into the placement service: replica load
            # becomes just another cost source, and per-peer expected
            # waits inform cross-node graph placement
            placement_service().observe_replica(
                rep.key, rep.wait_estimate(), len(rep.inflight),
                peer=rep.peer, load={"queue_depth": snap.get("queue_depth")})

    def _autoscale(self) -> None:
        now = self._clock()
        with self._lock:
            if now - self._last_scale < self.cooldown_s:
                return
            live = [r for r in self._replicas.values() if r.state == "live"]
            if not live:
                return
            waits = [r.wait_estimate() for r in live]
            scale_out = (min(waits) > self.slo_budget_s * self.scale_out_ratio
                         and len(live) < self.max_replicas
                         and self.spec is not None)
            victim = None
            if not scale_out and len(live) > self.min_replicas and \
                    max(waits) < self.slo_budget_s * self.scale_in_ratio:
                victim = min(live, key=lambda r: (len(r.inflight),
                                                  r.wait_estimate()))
                victim.state = "draining"
                self._counters["scale_ins"] += 1
            if scale_out or victim is not None:
                self._last_scale = now
        if scale_out:
            self._scale_out()
        elif victim is not None:
            self._drain_release(victim)

    def _scale_out(self) -> None:
        targets = self.spawn_targets
        if targets is None:
            targets = (self.node.peers() or [None]) if self.node else [None]
        with self._lock:
            pop = {t: 0 for t in targets}
            for r in self._replicas.values():
                if r.state in ("live", "draining") and r.peer in pop:
                    pop[r.peer] += 1
        peer = min(targets, key=lambda t: pop[t])
        self.spawn_replica(peer)
        with self._lock:
            self._counters["scale_outs"] += 1

    def _drain_release(self, rep: _Replica) -> None:
        """Drain-then-release: ``rep`` is already out of the routing set
        (state ``draining``); ask it to serve out its backlog, and only
        on the drain *reply* mark it released and stop the actor."""
        def on_drained(fut: Future, rep=rep) -> None:
            with self._lock:
                # a node death mid-drain already swept it via _mark_dead
                if rep.state != "draining":
                    return
                rep.state = "released"
            try:
                rep.ref.exit(None)
            except Exception:  # lint: replica already dead; exit is best-effort
                pass

        try:
            rep.ref.request("drain").add_done_callback(on_drained)
        except Exception:  # lint: dead replica; the monitor path sweeps it
            pass

    # -- observability / lifecycle -----------------------------------------
    def stats(self) -> Dict[str, Any]:
        with self._lock:
            s: Dict[str, Any] = dict(self._counters)
            s["last_scale_error"] = self._last_scale_error
            s["replicas"] = {
                r.key: {"state": r.state, "peer": r.peer,
                        "inflight": len(r.inflight),
                        "ewma_wait_s": r.wait_estimate(),
                        "load": dict(r.load)}
                for r in self._replicas.values()}
            s["inflight"] = sum(len(r.inflight)
                                for r in self._replicas.values())
        return s

    def live_replicas(self) -> List[str]:
        with self._lock:
            return [r.key for r in self._replicas.values()
                    if r.state == "live"]

    def actor_ref(self) -> ActorRef:
        """The router as an actor: ``("serve", prompt, {kwargs})``
        delegates to :meth:`submit`'s future, ``("stats",)`` snapshots.
        Publish it on the driver's node and any process in the cluster
        can talk to the whole mesh through one network-transparent
        handle."""
        if self._front is not None:
            return self._front
        router = self

        def front(tag: str, *rest: Any) -> Any:
            if tag == "serve":
                prompt = rest[0]
                kwargs = dict(rest[1]) if len(rest) > 1 else {}
                return router.submit(prompt, **kwargs)
            if tag == "stats":
                return router.stats()
            raise ValueError(f"mesh front-end got unknown message {tag!r}")

        self._front = self.system.spawn(front)
        return self._front

    def shutdown(self, drain: bool = False,
                 timeout: Optional[float] = 120.0) -> None:
        """Stop the control loop; with ``drain=True`` also drain every
        live replica (waiting up to ``timeout`` each) and stop it."""
        self._stop_evt.set()
        if self._control is not None:
            self._control.join(timeout=5.0)
            self._control = None
        if not drain:
            return
        with self._lock:
            reps = [r for r in self._replicas.values() if r.state == "live"]
            for r in reps:
                r.state = "draining"
        for rep in reps:
            try:
                rep.ref.request("drain").result(timeout)
            except Exception:  # lint: shutdown drain is best-effort
                pass
            with self._lock:
                if rep.state == "draining":
                    rep.state = "released"
            try:
                rep.ref.exit(None)
            except Exception:  # lint: replica may already be gone at shutdown
                pass

    def __enter__(self) -> "MeshRouter":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.shutdown()
        return False
