"""``repro.serve`` — asynchronous continuous-batching request engine.

Layered on the actor data plane built in PRs 1–2: requests are admitted
with deadlines and priorities (:class:`RequestQueue`), formed into
shape-bucketed dynamic batches (:class:`Batcher`), and decoded
multi-step by the :class:`ServeEngine`, whose per-request caches stay
device-resident as :class:`~repro.core.memref.DeviceRef` pytrees between
steps. See the README's "Serving" section for the engine diagram and the
SLO/backpressure knobs.
"""
from .batcher import Batcher
from .engine import (EngineStopped, ServeEngine, make_decode_worker,
                     make_graph_decode_worker)
from .request import (AdmissionError, QueueClosed, QueueOverflow, Request,
                      RequestQueue, ServeResult, SLOExceeded)
from .stats import EWMA, LatencyStats

__all__ = [
    "Batcher",
    "EngineStopped", "ServeEngine", "make_decode_worker",
    "make_graph_decode_worker",
    "AdmissionError", "QueueClosed", "QueueOverflow", "Request",
    "RequestQueue", "ServeResult", "SLOExceeded",
    "EWMA", "LatencyStats",
]
