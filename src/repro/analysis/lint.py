"""AST lint framework for the actor runtime.

The runtime's concurrency and DeviceRef-lifecycle contracts (no
blocking calls inside actor behaviors, every ``emit="ref"`` result
released on every path, locks taken in the ``ORDER.md`` order, no
silently-swallowed exceptions in broker/reader threads) used to live in
reviewers' heads; PRs 2, 5, 6 and 8 each shipped a hand-found race,
leak or deadlock. This package machine-checks those contracts.

Architecture:

* :class:`Finding` — one diagnostic, with a *fingerprint* that is
  line-number-free (``relpath::rule::qualname::detail``) so baselines
  survive unrelated edits to the same file.
* :class:`ModuleInfo` — a parsed module handed to every rule: path,
  AST, raw source lines, and the set of ``# lint:``-suppressed lines.
* Rules are callables ``rule(module: ModuleInfo, ctx: ProjectContext)
  -> Iterable[Finding]`` registered in ``repro.analysis.rules``.
  ``ProjectContext`` carries cross-module facts (today: the lock-name
  table the lock-order rule builds in a first pass).
* Baseline files hold one fingerprint per line; a run fails (exit 1)
  only on findings *not* in the baseline. Stale baseline entries are a
  warning, not an error — deleting an entry after fixing its finding
  is the normal workflow (and deleting one whose finding still exists
  makes the run fail, which is what CI relies on).

Suppression: append ``# lint: <reason>`` to the offending line (or the
``except``/``with``/``def`` line introducing the construct). Reasons are
mandatory by convention — a bare tag reads as unexplained and reviewers
should push back.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Finding",
    "ModuleInfo",
    "ProjectContext",
    "collect_modules",
    "run_rules",
    "fingerprints",
    "load_baseline",
    "write_baseline",
    "compare",
]

SUPPRESS_TAG = "# lint:"


@dataclass
class Finding:
    path: str          # path as given on the command line
    relpath: str       # repo-relative, '/'-separated — the stable key
    rule: str          # rule slug, e.g. "silent-except"
    line: int          # 1-based, for humans; not part of the fingerprint
    qualname: str      # enclosing Class.func dotted path ("<module>" at top level)
    detail: str        # rule-specific stable discriminator
    message: str       # human-readable explanation

    def fingerprint(self) -> str:
        return f"{self.relpath}::{self.rule}::{self.qualname}::{self.detail}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] {self.message}"
                f"  ({self.qualname})")


@dataclass
class ModuleInfo:
    path: str
    relpath: str
    tree: ast.Module
    lines: List[str]                      # raw source, 0-indexed
    suppressed: frozenset                 # 1-based line numbers with a lint tag

    def is_suppressed(self, *linenos: int) -> bool:
        return any(n in self.suppressed for n in linenos)

    def qualname_of(self, node: ast.AST) -> str:
        """Dotted Class.func path enclosing ``node`` (computed once,
        cached on the module)."""
        parents = getattr(self, "_qualnames", None)
        if parents is None:
            parents = {}
            def walk(n, prefix):
                for child in ast.iter_child_nodes(n):
                    if isinstance(child, (ast.FunctionDef,
                                          ast.AsyncFunctionDef,
                                          ast.ClassDef)):
                        q = f"{prefix}.{child.name}" if prefix else child.name
                        parents[child] = q
                        walk(child, q)
                    else:
                        parents[child] = prefix
                        walk(child, prefix)
            walk(self.tree, "")
            self._qualnames = parents
        return parents.get(node) or "<module>"


@dataclass
class ProjectContext:
    """Cross-module facts shared by all rules over one run."""
    modules: List[ModuleInfo] = field(default_factory=list)
    # (relpath-agnostic) lock attribute name -> canonical lock name,
    # harvested from make_lock("Name") / make_rlock("Name") call sites
    # by the lock-order rule's prepass; e.g. "_lock@PagePool" -> "PagePool"
    lock_names: Dict[str, str] = field(default_factory=dict)


def _suppressed_lines(lines: Sequence[str]) -> frozenset:
    return frozenset(i + 1 for i, ln in enumerate(lines)
                     if SUPPRESS_TAG in ln)


def _iter_py_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                # build trees and egg-info hold stale copies of the
                # package — linting them would shadow real findings with
                # duplicates from snapshots nobody edits
                dirs[:] = sorted(
                    d for d in dirs
                    if d not in ("__pycache__", ".git", "build", "dist")
                    and not d.endswith(".egg-info"))
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)
        elif p.endswith(".py"):
            yield p


def _relpath(path: str, root: Optional[str]) -> str:
    ap = os.path.abspath(path)
    if root:
        try:
            rp = os.path.relpath(ap, root)
            if not rp.startswith(".."):
                return rp.replace(os.sep, "/")
        except ValueError:
            pass
    return os.path.basename(ap)


def _repo_root(start: str) -> Optional[str]:
    cur = os.path.abspath(start)
    while True:
        if os.path.isdir(os.path.join(cur, ".git")) or \
           os.path.isfile(os.path.join(cur, "pyproject.toml")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return None
        cur = parent


def collect_modules(paths: Sequence[str]) -> Tuple[List[ModuleInfo], List[str]]:
    """Parse every ``.py`` under ``paths``. Returns (modules, errors);
    unparseable files become error strings, not crashes."""
    modules: List[ModuleInfo] = []
    errors: List[str] = []
    root = _repo_root(paths[0]) if paths else None
    for path in _iter_py_files(paths):
        try:
            with open(path, "r", encoding="utf-8") as fh:
                src = fh.read()
            tree = ast.parse(src, filename=path)
        except (OSError, SyntaxError) as exc:
            errors.append(f"{path}: cannot analyze: {exc}")
            continue
        lines = src.splitlines()
        modules.append(ModuleInfo(
            path=path,
            relpath=_relpath(path, root),
            tree=tree,
            lines=lines,
            suppressed=_suppressed_lines(lines),
        ))
    return modules, errors


Rule = Callable[[ModuleInfo, ProjectContext], Iterable[Finding]]


def run_rules(paths: Sequence[str],
              rules: Optional[Dict[str, Rule]] = None,
              ) -> Tuple[List[Finding], List[str]]:
    """Run every registered rule over every module under ``paths``."""
    if rules is None:
        from .rules import ALL_RULES
        rules = ALL_RULES
    modules, errors = collect_modules(paths)
    ctx = ProjectContext(modules=modules)
    # prepass hooks (cross-module fact gathering) run before any rule
    from .rules import PREPASSES
    for prepass in PREPASSES:
        prepass(ctx)
    findings: List[Finding] = []
    for mod in modules:
        for name, rule in rules.items():
            try:
                findings.extend(rule(mod, ctx))
            except Exception as exc:
                errors.append(f"{mod.path}: rule {name} crashed: {exc!r}")
    findings.sort(key=lambda f: (f.relpath, f.line, f.rule, f.detail))
    return findings, errors


def fingerprints(findings: Iterable[Finding]) -> List[str]:
    """Stable, deduplicated fingerprints; repeats of the same print get
    ``#2``, ``#3``… suffixes so a baseline holds exactly one line per
    live finding."""
    seen: Dict[str, int] = {}
    out: List[str] = []
    for f in findings:
        fp = f.fingerprint()
        n = seen.get(fp, 0) + 1
        seen[fp] = n
        out.append(fp if n == 1 else f"{fp}#{n}")
    return out


def load_baseline(path: str) -> List[str]:
    if not os.path.exists(path):
        return []
    with open(path, "r", encoding="utf-8") as fh:
        return [ln.strip() for ln in fh
                if ln.strip() and not ln.lstrip().startswith("#")]


def write_baseline(path: str, findings: Iterable[Finding]) -> int:
    fps = fingerprints(findings)
    with open(path, "w", encoding="utf-8") as fh:
        fh.write("# repro.analysis baseline — one fingerprint per "
                 "accepted pre-existing finding.\n"
                 "# Fix the finding, then delete its line. Adding lines "
                 "to silence new findings defeats the gate;\n"
                 "# prefer a `# lint: <reason>` tag at the site so the "
                 "reason lives next to the code.\n")
        for fp in fps:
            fh.write(fp + "\n")
    return len(fps)


def compare(findings: Sequence[Finding], baseline: Sequence[str],
            ) -> Tuple[List[Finding], List[str]]:
    """(new findings not in baseline, stale baseline entries)."""
    fps = fingerprints(findings)
    base = set(baseline)
    new = [f for f, fp in zip(findings, fps) if fp not in base]
    live = set(fps)
    stale = [b for b in baseline if b not in live]
    return new, stale
