"""Spill-based wire format for cross-node actor messages (paper §3.5).

The paper offers two serialization policies for ``mem_ref``: (a) prohibit
it, (b) serialize through an explicit host copy. ``repro.core.memref``
implements both for the single process; this module is where option (b)
meets an actual wire. Every frame is a pickled Python object with its
:class:`~repro.core.memref.DeviceRef` leaves normalized at the boundary:

* **outgoing** — a live ref is spilled exactly once. Request/``send``
  payloads use :meth:`DeviceRef.spill_copy` (the sender keeps its
  device-resident ref, so an exactly-once chunk re-issue after the remote
  node dies can replay the same payload locally); reply values use
  in-place :meth:`DeviceRef.spill` (ownership transfers to the remote
  caller, so the sender's device buffer is dropped at the boundary).
  Already-spilled refs travel as-is — their spill was the caller's
  explicit stage boundary (``PipelineRunner.submit(emit="spill")``).
* **incoming** — every spilled ref is unspilled exactly once onto the
  *receiver-chosen* device, so the payload lands device-resident and the
  handling actor never sees a wire artifact.
* **compression (optional)** — float refs are re-expressed in the int8
  wire format of :func:`repro.dist.collectives.quantize_ref` before
  spilling: the wire carries the int8 payload plus one float scale (~4x
  fewer bytes), and the receiver dequantizes back to the original dtype
  on its device. Lossy (relative error ≤ 1/254) and therefore opt-in per
  node.

Raw ``jax.Array`` payload leaves are converted to NumPy (value semantics
— they were going to be copied anyway); refs nested inside arbitrary
user objects are *not* discovered — they hit ``DeviceRef.__reduce__``'s
refusal with its explicit-spill message, which is the intended failure
mode for undeclared device state crossing the wire.
"""
from __future__ import annotations

import io
import pickle
import struct
from typing import Any, Optional

import jax
import numpy as np

from repro.core.memref import DeviceRef

__all__ = ["encode", "decode", "encoded_size", "WireRef"]

#: frame header: 4-byte big-endian payload length
HEADER = struct.Struct(">I")

#: frames larger than this are refused (corrupt-stream guard)
MAX_FRAME_BYTES = 1 << 31


class WireRef:
    """An int8-compressed ref in flight: spilled int8 payload + absmax
    scale + the original dtype **and access rights** to restore on
    arrival (compression must not widen a restricted view back to
    ``rw``). Pickles through the inner spilled ref's ``__reduce__``."""

    __slots__ = ("ref", "scale", "dtype_str", "access")

    def __init__(self, ref: DeviceRef, scale: float, dtype_str: str,
                 access: str = "rw"):
        self.ref = ref
        self.scale = scale
        self.dtype_str = dtype_str
        self.access = access

    def __repr__(self):
        return (f"WireRef(int8->{self.dtype_str}, scale={self.scale:.3g}, "
                f"{self.access}, {self.ref!r})")


def _compressible(ref: DeviceRef) -> bool:
    return np.issubdtype(np.dtype(ref.dtype), np.floating)


def _freeze(obj: Any, compress: bool, consume: bool) -> Any:
    if isinstance(obj, DeviceRef):
        if obj.is_spilled:
            return obj
        if compress and _compressible(obj):
            from repro.dist.collectives import quantize_ref
            q, scale = quantize_ref(obj.array)
            q.spill()
            access = obj.access
            if consume:
                obj.release()
            return WireRef(q, scale, np.dtype(obj.dtype).str, access)
        if consume:
            return obj.spill()
        return obj.spill_copy()
    if isinstance(obj, tuple):
        vals = [_freeze(v, compress, consume) for v in obj]
        return type(obj)(*vals) if hasattr(obj, "_fields") else tuple(vals)
    if isinstance(obj, list):
        return [_freeze(v, compress, consume) for v in obj]
    if isinstance(obj, dict):
        return {k: _freeze(v, compress, consume) for k, v in obj.items()}
    if isinstance(obj, jax.Array):
        return np.asarray(jax.device_get(obj))
    return obj


def _thaw(obj: Any, device) -> Any:
    if isinstance(obj, WireRef):
        from repro.dist.collectives import dequantize_ref
        obj.ref.unspill(device)
        out = dequantize_ref(obj.ref.array, obj.scale,
                             dtype=np.dtype(obj.dtype_str),
                             access=obj.access)
        obj.ref.release()
        return out
    if isinstance(obj, DeviceRef):
        return obj.unspill(device)
    if isinstance(obj, tuple):
        vals = [_thaw(v, device) for v in obj]
        return type(obj)(*vals) if hasattr(obj, "_fields") else tuple(vals)
    if isinstance(obj, list):
        return [_thaw(v, device) for v in obj]
    if isinstance(obj, dict):
        return {k: _thaw(v, device) for k, v in obj.items()}
    return obj


def encode(obj: Any, *, compress: Any = False, consume: bool = False,
           peer: Optional[str] = None) -> bytes:
    """Serialize ``obj`` for the wire (see module doc for the ref policy).

    ``consume=True`` spills live refs in place (reply direction:
    ownership transfers); the default clones (request direction: sender
    retains residency for replay).

    ``compress`` may be a bool (the node's static setting) or ``"auto"``,
    in which case the spill-boundary choice is delegated per payload to
    the process-wide placement service's wire-cost model: int8 is used
    only when the payload is large enough that quantization amortizes the
    bytes it saves on this (optionally ``peer``-specific) hop.
    """
    if compress == "auto":
        from repro.core.memref import payload_nbytes
        from repro.core.placement import service as placement_service
        compress = placement_service().choose_compress(
            payload_nbytes(obj), peer)
    return pickle.dumps(_freeze(obj, bool(compress), consume),
                        protocol=pickle.HIGHEST_PROTOCOL)


def decode(data: bytes, *, device=None) -> Any:
    """Inverse of :func:`encode`: unpickle and land every ref on
    ``device`` (bare ``jax.Device``, runtime ``Device`` wrapper, or None
    for the process default)."""
    return _thaw(pickle.loads(data), device)


def encoded_size(obj: Any, *, compress: bool = False) -> int:
    """Wire bytes ``obj`` would occupy — measured **without** mutating
    any live ref (benchmarks compare raw vs int8-compressed spills)."""
    return len(encode(obj, compress=compress, consume=False))


# ----------------------------------------------------------------------------
# control-frame envelope
# ----------------------------------------------------------------------------
# The node transport separates the *envelope* (frame tag, request ids,
# actor ids — primitives only, plus user payloads as already-encoded
# ``bytes`` blobs) from the payloads themselves. The envelope always
# unpickles; a payload blob that does not (e.g. a spawn_remote behavior
# defined in the driver's ``__main__``, unimportable on the worker) fails
# only its own request with a clean error reply instead of tearing the
# connection down.
def encode_frame(frame: tuple) -> bytes:
    return pickle.dumps(frame, protocol=pickle.HIGHEST_PROTOCOL)


def decode_frame(data: bytes) -> tuple:
    return pickle.loads(data)


# ----------------------------------------------------------------------------
# frame I/O over a socket-like object
# ----------------------------------------------------------------------------
def write_frame(sock, data: bytes) -> None:
    sock.sendall(HEADER.pack(len(data)) + data)


def read_frame(sock, on_chunk: Optional[Any] = None) -> Optional[bytes]:
    """One length-prefixed frame, or ``None`` on clean EOF.

    ``on_chunk()`` (if given) is called after every successful ``recv`` —
    the node's liveness tracker counts arriving *bytes*, not complete
    frames, so a large frame mid-transfer never reads as a dead peer.
    """
    head = _read_exact(sock, HEADER.size, on_chunk)
    if head is None:
        return None
    (length,) = HEADER.unpack(head)
    if length > MAX_FRAME_BYTES:
        raise ConnectionError(f"oversized frame ({length} bytes)")
    body = _read_exact(sock, length, on_chunk)
    if body is None:
        raise ConnectionError("EOF mid-frame")
    return body


def _read_exact(sock, n: int, on_chunk=None) -> Optional[bytes]:
    buf = io.BytesIO()
    remaining = n
    while remaining:
        chunk = sock.recv(min(remaining, 1 << 20))
        if not chunk:
            return None
        if on_chunk is not None:
            on_chunk()
        buf.write(chunk)
        remaining -= len(chunk)
    return buf.getvalue()
